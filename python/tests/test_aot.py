"""AOT pipeline tests: lowering produces parseable HLO text and a coherent
manifest; the lowered module numerically matches the jit path."""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_produces_hlo():
    fn, args = aot.build_entry("lreg", dict(d=16, s=4, nc=64))
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # f32 shapes visible
    assert "f32[16,4]" in text.replace(" ", "")


def test_build_entry_kinds():
    for kind, dims in [
        ("lreg", dict(d=8, s=2, nc=64)),
        ("aopt", dict(d=8, nc=64)),
        ("logistic", dict(d=8, nc=64)),
    ]:
        fn, args = aot.build_entry(kind, dims)
        out = jax.jit(fn).lower(*args)
        assert out is not None
    try:
        aot.build_entry("bogus", {})
        raise AssertionError("should have raised")
    except ValueError:
        pass


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    argv = ["compile.aot", "--out", str(out), "--profile", "small"]
    old = sys.argv
    sys.argv = argv
    try:
        aot.main()
    finally:
        sys.argv = old
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) == 3
    for e in manifest["artifacts"]:
        assert (out / e["file"]).exists()
        assert e["dtype"] == "f32"
        assert e["kind"] in ("lreg", "aopt", "logistic")
        text = (out / e["file"]).read_text()
        assert "HloModule" in text


def test_lowered_module_matches_jit_numerics():
    """Execute the lowered+compiled module and compare against direct jit."""
    fn, _ = aot.build_entry("lreg", dict(d=16, s=4, nc=64))
    rng = np.random.default_rng(0)
    q = np.zeros((16, 4), dtype=np.float32)
    q[:, 0] = rng.standard_normal(16).astype(np.float32)
    q[:, 0] /= np.linalg.norm(q[:, 0])
    r = rng.standard_normal(16).astype(np.float32)
    xc = rng.standard_normal((16, 64)).astype(np.float32)
    direct = np.asarray(fn(jnp.array(q), jnp.array(r), jnp.array(xc))[0])
    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((16, 4), jnp.float32),
        jax.ShapeDtypeStruct((16,), jnp.float32),
        jax.ShapeDtypeStruct((16, 64), jnp.float32),
    ).compile()
    via_aot = np.asarray(compiled(jnp.array(q), jnp.array(r), jnp.array(xc))[0])
    np.testing.assert_allclose(direct, via_aot, rtol=1e-5)


def test_topm_variant_shapes():
    q = jnp.zeros((16, 4), dtype=jnp.float32)
    r = jnp.ones((16,), dtype=jnp.float32)
    xc = jnp.ones((16, 64), dtype=jnp.float32)
    gains, top_v, top_i = model.lreg_oracle_topm(q, r, xc, m_top=5)
    assert gains.shape == (64,)
    assert top_v.shape == (5,)
    assert top_i.shape == (5,)
    # all-equal columns: top values equal the max gain
    assert np.allclose(np.asarray(top_v), np.max(np.asarray(gains)))
