"""L1 correctness: Pallas kernels vs the pure-jnp reference oracles.

hypothesis sweeps shapes and seeds; every kernel must match ref.py to f32
tolerance, including the padding/masking edge cases the rust batcher
produces (zero-padded basis columns, zero-padded candidate columns).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.aopt_gains import aopt_gains
from compile.kernels.logistic_gains import logistic_gains
from compile.kernels.lreg_gains import lreg_gains

TILE = 64  # small tile for fast interpret-mode tests


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def orthonormal_basis(rng, d, s_true, s_pad):
    """d×s_pad basis with s_true real orthonormal columns, rest zero."""
    a = rand(rng, d, max(s_true, 1))
    q, _ = np.linalg.qr(a)
    out = np.zeros((d, s_pad), dtype=np.float32)
    out[:, :s_true] = q[:, :s_true]
    return out


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(8, 96),
    s_true=st.integers(0, 6),
    tiles=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_lreg_kernel_matches_ref(d, s_true, tiles, seed):
    rng = np.random.default_rng(seed)
    s_pad = 8
    nc = TILE * tiles
    q = orthonormal_basis(rng, d, s_true, s_pad)
    r = rand(rng, d)
    xc = rand(rng, d, nc)
    got = np.asarray(lreg_gains(jnp.array(q), jnp.array(r), jnp.array(xc), tile=TILE))
    want = np.asarray(ref.lreg_gains_ref(jnp.array(q), jnp.array(r), jnp.array(xc)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert got.shape == (nc,)
    assert np.all(got >= 0.0)


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(4, 48),
    tiles=st.integers(1, 3),
    sig=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_aopt_kernel_matches_ref(d, tiles, sig, seed):
    rng = np.random.default_rng(seed)
    nc = TILE * tiles
    b = rand(rng, d, d)
    m = (b @ b.T / d + np.eye(d)).astype(np.float32)  # SPD covariance
    xc = rand(rng, d, nc)
    sig_arr = jnp.array([sig], dtype=jnp.float32)
    got = np.asarray(aopt_gains(jnp.array(m), jnp.array(xc), sig_arr, tile=TILE))
    want = np.asarray(ref.aopt_gains_ref(jnp.array(m), jnp.array(xc), sig))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    assert np.all(got >= 0.0)


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(8, 96),
    tiles=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_logistic_kernel_matches_ref(d, tiles, seed):
    rng = np.random.default_rng(seed)
    nc = TILE * tiles
    xc = rand(rng, d, nc)
    p = rng.uniform(0.05, 0.95, d).astype(np.float32)
    y = (rng.uniform(0, 1, d) < 0.5).astype(np.float32)
    resid = y - p
    w = p * (1 - p)
    got = np.asarray(
        logistic_gains(jnp.array(xc), jnp.array(resid), jnp.array(w), tile=TILE)
    )
    want = np.asarray(
        ref.logistic_gains_ref(jnp.array(xc), jnp.array(resid), jnp.array(w))
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lreg_padded_candidates_zero_gain():
    """Zero-padded candidate columns (the rust batcher's padding) get 0."""
    rng = np.random.default_rng(0)
    d = 32
    q = orthonormal_basis(rng, d, 2, 4)
    r = rand(rng, d)
    xc = np.zeros((d, TILE), dtype=np.float32)
    xc[:, :3] = rand(rng, d, 3)
    gains = np.asarray(lreg_gains(jnp.array(q), jnp.array(r), jnp.array(xc), tile=TILE))
    assert np.all(gains[3:] == 0.0)
    assert np.all(gains[:3] >= 0.0)


def test_lreg_in_span_candidate_zero_gain():
    """A candidate inside span(Q) must get zero gain, not a 0/0 blowup."""
    rng = np.random.default_rng(1)
    d = 24
    q = orthonormal_basis(rng, d, 3, 4)
    r = rand(rng, d)
    xc = np.zeros((d, TILE), dtype=np.float32)
    xc[:, 0] = 2.5 * q[:, 0] - 1.0 * q[:, 2]  # in span
    xc[:, 1] = rand(rng, d)
    gains = np.asarray(lreg_gains(jnp.array(q), jnp.array(r), jnp.array(xc), tile=TILE))
    assert gains[0] == pytest.approx(0.0, abs=1e-3)
    assert np.isfinite(gains).all()


def test_lreg_empty_basis_matches_singleton_values():
    """With S = ∅ the gain is (xᵀy)²/‖x‖² — check against direct numpy."""
    rng = np.random.default_rng(2)
    d = 40
    q = np.zeros((d, 4), dtype=np.float32)
    y = rand(rng, d)
    xc = rand(rng, d, TILE)
    gains = np.asarray(lreg_gains(jnp.array(q), jnp.array(y), jnp.array(xc), tile=TILE))
    want = (xc.T @ y) ** 2 / np.sum(xc * xc, axis=0)
    np.testing.assert_allclose(gains, want, rtol=1e-4)


def test_aopt_gain_equals_trace_reduction():
    """Kernel gain == Tr(M) − Tr(M') after the Sherman–Morrison update."""
    rng = np.random.default_rng(3)
    d = 12
    beta_sq, sigma_sq = 1.0, 1.0
    m = np.eye(d, dtype=np.float32) / beta_sq
    xc = rand(rng, d, TILE)
    sig = jnp.array([1.0 / sigma_sq], dtype=jnp.float32)
    gains = np.asarray(aopt_gains(jnp.array(m), jnp.array(xc), sig, tile=TILE))
    for j in [0, 5, TILE - 1]:
        x = xc[:, j].astype(np.float64)
        m64 = m.astype(np.float64)
        a = np.linalg.inv(m64) + np.outer(x, x) / sigma_sq
        m_new = np.linalg.inv(a)
        want = np.trace(m64) - np.trace(m_new)
        assert gains[j] == pytest.approx(want, rel=1e-3)


def test_kernel_rejects_non_multiple_tile():
    rng = np.random.default_rng(4)
    q = orthonormal_basis(rng, 8, 1, 2)
    with pytest.raises(AssertionError):
        lreg_gains(jnp.array(q), jnp.zeros(8), jnp.zeros((8, TILE + 1)), tile=TILE)
