"""Test-harness bootstrap for the python/ tree.

Two jobs:

1. Put ``python/`` on ``sys.path`` so ``from compile import ...`` works no
   matter where pytest is invoked from (repo root, python/, CI).
2. Provide a deterministic fallback for ``hypothesis`` when it is not
   installed (the offline build image ships no dev extras). The shim
   implements the tiny slice the kernel tests use — ``given``,
   ``settings``, and ``strategies.integers/floats`` — by sampling a fixed
   number of seeded examples, so the property tests still sweep shapes
   offline while CI (which installs real hypothesis) gets full shrinking.
"""

import random
import sys
import types
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def _install_hypothesis_fallback():
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    _DEFAULT_MAX_EXAMPLES = 15

    def given(**strategies):
        def decorate(fn):
            def wrapper(*args, **kwargs):
                max_examples = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                for case in range(max_examples):
                    rng = random.Random(0xDA5E + 7919 * case)
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:  # annotate with the failing draw
                        raise AssertionError(
                            f"property failed on fallback case {case}: {drawn}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return decorate

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate

    st_module = types.ModuleType("hypothesis.strategies")
    st_module.integers = integers
    st_module.floats = floats
    st_module.booleans = booleans
    st_module.sampled_from = sampled_from

    hyp_module = types.ModuleType("hypothesis")
    hyp_module.given = given
    hyp_module.settings = settings
    hyp_module.strategies = st_module
    hyp_module.__offline_fallback__ = True

    sys.modules["hypothesis"] = hyp_module
    sys.modules["hypothesis.strategies"] = st_module


_install_hypothesis_fallback()
