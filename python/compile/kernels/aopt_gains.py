"""L1 Pallas kernel: batched Bayesian A-optimality gains.

Gain of adding stimulus x to the design: ``σ⁻²‖Mx‖² / (1 + σ⁻²xᵀMx)`` with
M the current posterior covariance. Batched over a candidate tile this is
the ``(d × d)·(d × TILE_N)`` matmul ``M·Xc`` plus two columnwise
reductions. The posterior block stays VMEM-resident across grid steps
(index_map pins it at (0,0)); candidate tiles stream. VMEM per step =
d² + 2·d·TILE_N floats — d = 256/385 and TILE_N = 256 keeps this ≤ 4 MB
in f32. ``interpret=True`` for the CPU PJRT path (see lreg_gains.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(m_ref, xc_ref, sig_ref, out_ref):
    m = m_ref[...]  # (d, d)
    xc = xc_ref[...]  # (d, tile)
    sig = sig_ref[0]  # scalar σ⁻²
    mx = m @ xc  # MXU matmul
    num = sig * jnp.sum(mx * mx, axis=0)
    den = 1.0 + sig * jnp.sum(xc * mx, axis=0)
    out_ref[...] = (num / den).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile",))
def aopt_gains(m, xc, sigma_sq_inv, *, tile=256):
    """Batched A-optimality gains via the Pallas kernel.

    m: (d, d) posterior covariance; xc: (d, nc), nc a multiple of ``tile``;
    sigma_sq_inv: (1,) array holding σ⁻². Returns (nc,) gains.
    """
    d = m.shape[0]
    nc = xc.shape[1]
    tile = min(tile, nc)  # shrink the tile for small batches
    assert nc % tile == 0, f"candidate count {nc} must be a multiple of {tile}"
    grid = (nc // tile,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, tile), lambda i: (0, i)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nc,), xc.dtype),
        interpret=True,
    )(m, xc, sigma_sq_inv)
