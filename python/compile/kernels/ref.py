"""Pure-jnp reference oracles — the correctness ground truth for the Pallas
kernels (L1) and, transitively, for the AOT-compiled artifacts the rust
runtime executes.

Each function computes batched marginal gains for one of the paper's
objectives given the *state summary* maintained by the rust coordinator:

- linear regression (Cor. 7): orthonormal basis ``q`` of the selected
  columns and residual ``r = y − QQᵀy``;
- Bayesian A-optimality (Cor. 9): posterior covariance ``m``;
- logistic regression (Cor. 8): working residual ``y − p`` and IRLS weights
  ``w = p(1−p)`` (one-step / score-test gains — the quadratic approximation
  of the refit gain at the current fit).

All math is f32 (the PJRT CPU artifact dtype); the rust native oracle keeps
f64 and the integration tests bound the drift.
"""

import jax.numpy as jnp

# Floor below which a candidate direction counts as linearly dependent.
DEN_FLOOR = 1e-10
# relative cutoff: candidates with residual direction below this fraction of
# their norm count as linearly dependent (f32 headroom)
REL_DEN_FLOOR = 1e-5


def lreg_gains_ref(q, r, xc):
    """Regression gains: ``(x_aᵀr)² / (‖x_a‖² − ‖Qᵀx_a‖²)`` per candidate.

    q:  (d, s)  orthonormal basis columns (zero-padded columns allowed)
    r:  (d,)    residual of the response
    xc: (d, nc) candidate feature columns
    returns (nc,) gains (unnormalized; the caller divides by ‖y‖²)

    The linear-dependence cutoff is *relative* to ‖x‖² — in f32 the
    cancellation ‖x‖² − ‖Qᵀx‖² of an in-span candidate leaves noise of
    order ε·‖x‖², which an absolute floor would amplify into huge gains.
    """
    num = jnp.square(xc.T @ r)  # (nc,)
    qx = q.T @ xc  # (s, nc)
    norm_sq = jnp.sum(xc * xc, axis=0)
    den = norm_sq - jnp.sum(qx * qx, axis=0)
    floor = REL_DEN_FLOOR * norm_sq + DEN_FLOOR
    return jnp.where(den > floor, num / jnp.maximum(den, DEN_FLOOR), 0.0)


def aopt_gains_ref(m, xc, sigma_sq_inv):
    """A-optimality gains: ``σ⁻²‖Mx‖² / (1 + σ⁻²xᵀMx)`` per candidate.

    m:  (d, d)  posterior covariance
    xc: (d, nc) candidate stimuli
    sigma_sq_inv: scalar σ⁻²
    returns (nc,) gains (unnormalized; caller divides by Tr(Λ⁻¹))
    """
    mx = m @ xc  # (d, nc)
    num = sigma_sq_inv * jnp.sum(mx * mx, axis=0)
    den = 1.0 + sigma_sq_inv * jnp.sum(xc * mx, axis=0)
    return num / den


def logistic_gains_ref(xc, resid, w):
    """Score-test logistic gains: ``(x_aᵀ(y−p))² / (2·x_aᵀ W x_a)``.

    xc:    (d, nc) candidate feature columns
    resid: (d,)    y − p at the current fit
    w:     (d,)    IRLS weights p(1−p)
    returns (nc,) one-step gain approximations (unnormalized log-likelihood
    units; caller divides by d·ln2)
    """
    num = jnp.square(xc.T @ resid)
    den = 2.0 * jnp.sum(w[:, None] * xc * xc, axis=0)
    return jnp.where(den > DEN_FLOOR, num / jnp.maximum(den, DEN_FLOOR), 0.0)
