"""L1 Pallas kernel: batched one-step (score-test) logistic gains.

``(x_aᵀ(y−p))² / (2·x_aᵀWx_a)`` per candidate — the quadratic expansion of
the log-likelihood refit gain at the current fit, the standard cheap oracle
for expensive-query regimes (paper Fig. 3f). Weighted column sweeps stream
candidate tiles through VMEM like lreg_gains; the working residual and IRLS
weight vectors stay resident. ``interpret=True`` for the CPU PJRT path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEN_FLOOR = 1e-10


def _kernel(xc_ref, resid_ref, w_ref, out_ref):
    xc = xc_ref[...]  # (d, tile)
    resid = resid_ref[...]  # (d,)
    w = w_ref[...]  # (d,)
    num = jnp.square(xc.T @ resid)
    den = 2.0 * jnp.sum(w[:, None] * xc * xc, axis=0)
    out_ref[...] = jnp.where(
        den > DEN_FLOOR, num / jnp.maximum(den, DEN_FLOOR), 0.0
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile",))
def logistic_gains(xc, resid, w, *, tile=256):
    """Batched score-test logistic gains via the Pallas kernel.

    xc: (d, nc) with nc a multiple of ``tile``; resid, w: (d,).
    Returns (nc,) gains.
    """
    d, nc = xc.shape
    tile = min(tile, nc)  # shrink the tile for small batches
    assert nc % tile == 0, f"candidate count {nc} must be a multiple of {tile}"
    grid = (nc // tile,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, tile), lambda i: (0, i)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nc,), xc.dtype),
        interpret=True,
    )(xc, resid, w)
