"""L1 Pallas kernel: batched regression marginal gains.

The hot loop of every round of DASH / greedy on the regression objective is
"score all candidate columns against the current solution" — a
matmul-shaped sweep. The kernel tiles the **candidate axis** with
``BlockSpec`` so each grid step streams one ``(d × TILE_N)`` candidate tile
from HBM into VMEM while the basis block ``(d × s)`` and residual stay
resident, drives the MXU with the ``(s × d)·(d × TILE_N)`` projection, and
reduces to per-candidate gains in VMEM.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the paper ran
multicore CPU Python; there is no kernel to port, so the BlockSpec schedule
below is *our* mapping of the oracle onto a systolic-array budget:
VMEM per step = d·s (basis) + d·TILE_N (tile) + s·TILE_N (projection)
floats. With d ≤ 1024, s ≤ 256, TILE_N = 256 and f32 that is ≤ 4 MB.
``interpret=True`` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls, so correctness runs through the interpreter and the same HLO
is what the rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEN_FLOOR = 1e-10
REL_DEN_FLOOR = 1e-5


def _kernel(q_ref, r_ref, xc_ref, out_ref):
    xc = xc_ref[...]  # (d, tile)
    r = r_ref[...]  # (d,)
    q = q_ref[...]  # (d, s)
    num = jnp.square(xc.T @ r)  # (tile,)
    qx = q.T @ xc  # (s, tile) — the MXU matmul
    norm_sq = jnp.sum(xc * xc, axis=0)
    den = norm_sq - jnp.sum(qx * qx, axis=0)
    # relative dependence cutoff — see kernels/ref.py
    floor = REL_DEN_FLOOR * norm_sq + DEN_FLOOR
    out_ref[...] = jnp.where(
        den > floor, num / jnp.maximum(den, DEN_FLOOR), 0.0
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile",))
def lreg_gains(q, r, xc, *, tile=256):
    """Batched regression gains via the Pallas kernel.

    q: (d, s) zero-padded orthonormal basis; r: (d,); xc: (d, nc) with
    nc a multiple of ``tile``. Returns (nc,) gains.
    """
    d, s = q.shape
    nc = xc.shape[1]
    tile = min(tile, nc)  # shrink the tile for small batches
    assert nc % tile == 0, f"candidate count {nc} must be a multiple of {tile}"
    grid = (nc // tile,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, s), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nc,), xc.dtype),
        interpret=True,
    )(q, r, xc)
