"""AOT lowering: JAX (L2+L1) → HLO **text** artifacts + manifest.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts land in ``artifacts/`` together with ``manifest.json`` describing
each module's shapes so the rust runtime (`runtime::artifact`) can pad its
batches without re-deriving anything. Run via ``make artifacts``; the make
rule skips the (slow) lowering when inputs are unchanged.

Usage:
    python -m compile.aot --out ../artifacts [--profile small|paper]
"""

import argparse
import json
import os

import jax

from compile import model


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Shape profiles. "small" compiles fast and serves tests + the quickstart
# examples; "paper" matches the figure workloads (D1/D2 regression, D1-ed /
# D2-ed design).  nc is the padded candidate-tile batch; s the padded basis.
PROFILES = {
    "small": [
        ("lreg", dict(d=256, s=64, nc=256)),
        ("aopt", dict(d=64, nc=256)),
        ("logistic", dict(d=256, nc=256)),
    ],
    "paper": [
        ("lreg", dict(d=1024, s=128, nc=512)),
        ("lreg", dict(d=4096, s=128, nc=512)),
        ("aopt", dict(d=256, nc=1024)),
        ("aopt", dict(d=512, nc=1024)),
        ("logistic", dict(d=1024, nc=512)),
        ("logistic", dict(d=4096, nc=2560)),
    ],
}


def build_entry(kind, dims):
    if kind == "lreg":
        args = model.lreg_example(dims["d"], dims["s"], dims["nc"])
        fn = model.lreg_oracle
    elif kind == "aopt":
        args = model.aopt_example(dims["d"], dims["nc"])
        fn = model.aopt_oracle
    elif kind == "logistic":
        args = model.logistic_example(dims["d"], dims["nc"])
        fn = model.logistic_oracle
    else:
        raise ValueError(f"unknown kind {kind}")
    return fn, args


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profile", default="small", choices=list(PROFILES) + ["all"])
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    profiles = list(PROFILES) if args.profile == "all" else [args.profile]
    seen = set()
    for prof in profiles:
        for kind, dims in PROFILES[prof]:
            key = (kind, tuple(sorted(dims.items())))
            if key in seen:
                continue
            seen.add(key)
            fn, ex_args = build_entry(kind, dims)
            lowered = jax.jit(fn).lower(*ex_args)
            hlo = to_hlo_text(lowered)
            dim_tag = "_".join(f"{k}{v}" for k, v in sorted(dims.items()))
            fname = f"{kind}_{dim_tag}.hlo.txt"
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(hlo)
            entries.append(
                {
                    "name": f"{kind}_{dim_tag}",
                    "kind": kind,
                    "file": fname,
                    "dims": dims,
                    "dtype": "f32",
                    "inputs": [list(a.shape) for a in ex_args],
                    "outputs": 1,
                }
            )
            print(f"wrote {path} ({len(hlo)} chars)")

    manifest = {"version": 1, "artifacts": entries}
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
