"""L2 — the JAX oracle graphs the rust coordinator executes.

Each entry point wraps an L1 Pallas kernel into the exact padded-shape
function that gets AOT-lowered to an HLO artifact. The rust side maintains
the objective *state* (orthonormal basis / posterior covariance / working
residuals, all O(d·s) or O(d²) incremental updates) and offloads the
O(d·n) candidate sweeps — the per-round hot path — to these graphs.

Build-time only: nothing in this package is imported at serving time.
"""

import jax.numpy as jnp

from compile.kernels.aopt_gains import aopt_gains
from compile.kernels.logistic_gains import logistic_gains
from compile.kernels.lreg_gains import lreg_gains


def lreg_oracle(q, r, xc):
    """Regression gains oracle. Output is a 1-tuple (AOT convention)."""
    return (lreg_gains(q, r, xc),)


def aopt_oracle(m, xc, sigma_sq_inv):
    """A-optimality gains oracle."""
    return (aopt_gains(m, xc, sigma_sq_inv),)


def logistic_oracle(xc, resid, w):
    """Score-test logistic gains oracle."""
    return (logistic_gains(xc, resid, w),)


def lreg_oracle_topm(q, r, xc, *, m_top):
    """Fused variant: gains plus the indices/values of the top-m candidates
    (saves shipping the full gain vector back when only the filter survivors
    matter). Returns (gains, top_values, top_indices)."""
    gains = lreg_gains(q, r, xc)
    top_v, top_i = jnp.sort(gains)[::-1][:m_top], jnp.argsort(-gains)[:m_top]
    return (gains, top_v, top_i.astype(jnp.int32))


# Example-input builders used by aot.py — shapes define the artifact.
def lreg_example(d, s, nc, dtype=jnp.float32):
    import jax

    return (
        jax.ShapeDtypeStruct((d, s), dtype),
        jax.ShapeDtypeStruct((d,), dtype),
        jax.ShapeDtypeStruct((d, nc), dtype),
    )


def aopt_example(d, nc, dtype=jnp.float32):
    import jax

    return (
        jax.ShapeDtypeStruct((d, d), dtype),
        jax.ShapeDtypeStruct((d, nc), dtype),
        jax.ShapeDtypeStruct((1,), dtype),
    )


def logistic_example(d, nc, dtype=jnp.float32):
    import jax

    return (
        jax.ShapeDtypeStruct((d, nc), dtype),
        jax.ShapeDtypeStruct((d,), dtype),
        jax.ShapeDtypeStruct((d,), dtype),
    )
