//! Bayesian A-optimal experimental design (the paper's Fig. 4 workload),
//! including the diversity-regularized variant `f_A-div = f_A-opt + d(S)`
//! of Corollary 9.
//!
//! ```bash
//! cargo run --release --offline --example experimental_design
//! ```

use dash_select::algorithms::{Dash, DashConfig, Greedy, GreedyConfig, RandomSelect, TopK};
use dash_select::data::synthetic;
use dash_select::objectives::{
    AOptimalityObjective, DiverseObjective, GroupSqrtDiversity, Objective,
};
use dash_select::rng::Pcg64;

fn main() {
    // 128-dim stimuli, 512 candidate experiments, covariance 0.8 (D1-ed)
    let mut rng = Pcg64::seed_from(11);
    let data = synthetic::design_d1(&mut rng, 128, 512, 0.8);
    let k = 40;

    println!(
        "experimental design: {} candidate stimuli in R^{}, selecting k = {k}\n",
        data.n(),
        data.d()
    );

    // --- plain A-optimality ---
    let obj = AOptimalityObjective::new(&data, 1.0, 1.0);
    println!("γ lower bound (Cor. 9): {:.6}", obj.gamma_bound());
    println!("\n--- f_A-opt (posterior variance reduction, normalized) ---");
    println!("{:<10} {:>10} {:>8} {:>10}", "algorithm", "f(S)", "rounds", "queries");
    let dash = Dash::new(DashConfig { k, ..Default::default() }).run(&obj, &mut rng);
    let greedy = Greedy::new(GreedyConfig { k, ..Default::default() }).run(&obj);
    let topk = TopK::new(k).run(&obj);
    let rnd = RandomSelect::new(k).run_mean(&obj, &mut rng, 5);
    for r in [&dash, &greedy, &topk, &rnd] {
        println!("{:<10} {:>10.5} {:>8} {:>10}", r.algorithm, r.value, r.rounds, r.queries);
    }

    // --- diversity-regularized (Cor. 9's f_A-div) ---
    // group stimuli into 8 batches (e.g. experimental sessions); d(S)
    // rewards spreading picks across sessions
    let div = GroupSqrtDiversity::round_robin(data.n(), 8, 0.002);
    let div_obj = DiverseObjective::new(AOptimalityObjective::new(&data, 1.0, 1.0), div);
    println!("\n--- f_A-div = f_A-opt + d(S) (diversity-regularized) ---");
    let dash_div = Dash::new(DashConfig { k, ..Default::default() }).run(&div_obj, &mut rng);
    let greedy_div = Greedy::new(GreedyConfig { k, ..Default::default() }).run(&div_obj);
    println!("{:<10} {:>10} {:>8} {:>10}", "algorithm", "f(S)+d(S)", "rounds", "queries");
    for r in [&dash_div, &greedy_div] {
        println!("{:<10} {:>10.5} {:>8} {:>10}", r.algorithm, r.value, r.rounds, r.queries);
    }

    // how many distinct sessions does each solution cover?
    let coverage = |set: &[usize]| {
        let mut seen = std::collections::HashSet::new();
        for &a in set {
            seen.insert(a % 8);
        }
        seen.len()
    };
    println!(
        "\nsession coverage: plain DASH {}/8, diversity-regularized DASH {}/8",
        coverage(&dash.set),
        coverage(&dash_div.set)
    );
    println!(
        "DASH ran {} adaptive rounds vs greedy's {} ({}× fewer).",
        dash.rounds,
        greedy.rounds,
        greedy.rounds / dash.rounds.max(1)
    );
    let _ = Objective::eval(&obj, &dash.set);
}
