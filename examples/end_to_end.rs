//! **End-to-end driver** (DESIGN.md §validation): exercises the full
//! three-layer stack on a real small workload, proving all layers compose:
//!
//! 1. loads the AOT artifacts (`make artifacts`) through the PJRT runtime —
//!    the L1 Pallas kernels lowered via the L2 JAX graphs;
//! 2. serves **batched gain requests** from the rust coordinator's hot
//!    loop (DASH's filter rounds are exactly batched-inference rounds),
//!    reporting request latency and throughput;
//! 3. runs the full selection workload (DASH + parallel greedy + baselines)
//!    against both the XLA and native backends, cross-checking values;
//! 4. logs the value-vs-round curve to `results/e2e_curve.csv`.
//!
//! Without `artifacts/` (CI smoke runs, fresh checkouts) the example
//! degrades to the native-only path: same workload, same selection table
//! and curve, XLA stages skipped with a notice instead of failing.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example end_to_end
//! ```

use dash_select::algorithms::{Dash, DashConfig, Greedy, GreedyConfig};
use dash_select::coordinator::{Backend, Leader, ObjectiveChoice, PlanSpec, ProblemSpec, SelectError};
use dash_select::data::synthetic;
use dash_select::objectives::Objective;
use dash_select::oracle::XlaLregObjective;
use dash_select::rng::Pcg64;
use dash_select::runtime::{default_artifacts_dir, Manifest, RuntimeClient};
use dash_select::util::csvio::CsvTable;
use dash_select::util::Timer;
use std::sync::Arc;

fn main() -> Result<(), SelectError> {
    // ---- 1. runtime + artifacts (optional: native-only fallback) ----
    // fall back to native-only ONLY when no artifacts were built at all; a
    // manifest that exists but fails to load is a real regression and errors
    let dir = default_artifacts_dir();
    let manifest = if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).map_err(SelectError::Backend)?)
    } else {
        println!(
            "artifacts not built (no manifest in {dir:?}); running the native-only \
             path (run `make artifacts` for the full XLA pass)"
        );
        None
    };
    if let Some(manifest) = &manifest {
        let client = RuntimeClient::global().map_err(|e| SelectError::Backend(e.to_string()))?;
        println!(
            "PJRT platform: {}; {} artifacts loaded from {:?}",
            client.platform().map_err(|e| SelectError::Backend(e.to_string()))?,
            manifest.artifacts.len(),
            manifest.dir
        );
        for a in &manifest.artifacts {
            println!(
                "  {:<28} kind={:<8} d={} s={} nc={}",
                a.name,
                a.kind.as_str(),
                a.d,
                a.s,
                a.nc
            );
        }
    }

    // ---- 2. workload sized to the "small" artifact profile ----
    // (d ≤ 256 samples, basis ≤ 64; 500 candidate features exercise the
    // chunked batching path: 500 = 2 chunks of nc = 256)
    let mut rng = Pcg64::seed_from(2024);
    let data = synthetic::regression_d1(&mut rng, 250, 500, 80, 0.4);
    let k = 48;
    println!(
        "\nworkload: {} ({} samples × {} features), k = {k}",
        data.name,
        data.d(),
        data.n()
    );

    // ---- batched request serving: measure oracle latency/throughput ----
    if let Some(manifest) = &manifest {
        let xla_obj =
            XlaLregObjective::new(&data, manifest, k).map_err(|e| SelectError::Backend(e.to_string()))?;
        let st = xla_obj.state_for(&[0, 7, 100, 320]);
        let all: Vec<usize> = (0..data.n()).collect();
        // warmup (compiles nothing new, fills caches)
        let _ = st.gains(&all);
        let reqs = 20;
        let t = Timer::start();
        for _ in 0..reqs {
            let g = st.gains(&all);
            assert_eq!(g.len(), data.n());
        }
        let dt = t.elapsed_s();
        println!(
            "\nbatched oracle serving: {reqs} requests × {} candidate gains\n  latency {:.3} ms/request, throughput {:.0} gains/s",
            data.n(),
            1e3 * dt / reqs as f64,
            reqs as f64 * data.n() as f64 / dt
        );
    }

    // ---- 3. full selection (both backends when artifacts exist) ----
    let leader = Leader::new();
    let backends: Vec<(Backend, &str)> = if manifest.is_some() {
        vec![(Backend::Xla, "xla"), (Backend::Native, "native")]
    } else {
        vec![(Backend::Native, "native")]
    };
    // the curve comes from the XLA dash run when available, native otherwise
    let curve_tag = if manifest.is_some() { "xla" } else { "native" };
    let mut rows: Vec<(String, f64, usize, usize, f64)> = Vec::new();
    let mut dash_history = Vec::new();
    // v1 builders: the plans are backend-independent; one validated
    // problem per backend pairs with each of them
    let dataset = Arc::new(data.clone());
    let plans = [
        (PlanSpec::dash().build()?, "dash"),
        (PlanSpec::parallel_greedy().threads(4).build()?, "parallel_sds_ma"),
        (PlanSpec::topk().build()?, "top_k"),
    ];
    for (backend, tag) in backends {
        let problem = ProblemSpec::builder(Arc::clone(&dataset))
            .objective(ObjectiveChoice::Lreg)
            .backend(backend)
            .k(k)
            .seed(5)
            .build()?;
        for (plan, name) in &plans {
            let name = *name;
            let report = leader.run(&problem.job(plan))?;
            if name == "dash" && tag == curve_tag {
                dash_history = report.result.history.clone();
            }
            rows.push((
                format!("{name}[{tag}]"),
                report.native_value,
                report.result.rounds,
                report.result.queries,
                report.result.wall_s,
            ));
        }
    }
    println!("\n{:<24} {:>9} {:>8} {:>10} {:>9}", "algorithm[backend]", "R²", "rounds", "queries", "wall(s)");
    for (name, v, rounds, queries, wall) in &rows {
        println!("{name:<24} {v:>9.4} {rounds:>8} {queries:>10} {wall:>9.3}");
    }

    // cross-check (XLA only): both backends land within a whisker
    if manifest.is_some() {
        let v = |needle: &str| rows.iter().find(|r| r.0 == needle).map(|r| r.1).unwrap_or(0.0);
        let diff = (v("dash[xla]") - v("dash[native]")).abs();
        println!("\nbackend cross-check: |R²(xla) − R²(native)| = {diff:.2e}");
        if diff > 0.05 {
            return Err(SelectError::Backend(format!("backend divergence too large: {diff}")));
        }
    }
    let greedy_r = Greedy::new(GreedyConfig { k, ..Default::default() })
        .run(&dash_select::objectives::LinearRegressionObjective::new(&data));
    let dash_r = match &manifest {
        Some(manifest) => Dash::new(DashConfig { k, ..Default::default() }).run(
            &XlaLregObjective::new(&data, manifest, k)
                .map_err(|e| SelectError::Backend(e.to_string()))?,
            &mut rng,
        ),
        None => Dash::new(DashConfig { k, ..Default::default() }).run(
            &dash_select::objectives::LinearRegressionObjective::new(&data),
            &mut rng,
        ),
    };
    println!(
        "paper shape check: DASH({curve_tag}) {:.4} vs greedy {:.4} ({:.0}% of greedy) in {} vs {} rounds",
        dash_r.value,
        greedy_r.value,
        100.0 * dash_r.value / greedy_r.value.max(1e-12),
        dash_r.rounds,
        greedy_r.rounds
    );

    // ---- 4. value-vs-round curve ----
    let mut curve = CsvTable::new(&["round", "value", "set_size", "queries"]);
    for rec in &dash_history {
        curve.push(vec![
            rec.round.to_string(),
            format!("{:.6}", rec.value),
            rec.set_size.to_string(),
            rec.queries.to_string(),
        ]);
    }
    let out = dash_select::experiments::results_dir().join("e2e_curve.csv");
    curve.save(&out).map_err(|e| SelectError::Backend(e.to_string()))?;
    println!(
        "\nwrote DASH({curve_tag}) value-vs-round curve to {out:?} ({} rounds)",
        curve.rows.len()
    );
    println!("end_to_end OK");
    Ok(())
}
