//! Clinical-style feature selection (the paper's D2 workload, Fig. 2 bottom
//! row): select predictive features from a block-correlated 385-feature
//! regression dataset, compare every §5 benchmark, and report the sampled
//! differential-submodularity ratio α = γ² that backs DASH's guarantee.
//!
//! ```bash
//! cargo run --release --offline --example feature_selection_clinical
//! ```

use dash_select::algorithms::{
    Dash, DashConfig, Greedy, GreedyConfig, Lasso, LassoConfig, RandomSelect, TopK,
};
use dash_select::data::clinical_sim::{clinical_d2, ClinicalConfig};
use dash_select::objectives::{spectra, LinearRegressionObjective, Objective, R2Objective};
use dash_select::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::seed_from(42);
    let cfg = ClinicalConfig { samples: 2000, ..Default::default() };
    let data = clinical_d2(&mut rng, &cfg);
    let obj = LinearRegressionObjective::new(&data);
    let r2 = R2Objective::new(&data);
    let k = 40;

    // spectral diagnostics: the paper's γ (Cor. 7) sampled from the data
    let gamma = spectra::regression_gamma(&data.x, k, 6, &mut rng);
    println!(
        "dataset {} ({} samples × {} features)\nsampled γ = {:.4} → α = γ² = {:.4}; \
         DASH guarantee ≥ (1 − 1/e^α² − ε)·OPT = {:.3}·OPT\n",
        data.name,
        data.d(),
        data.n(),
        gamma,
        gamma * gamma,
        (1.0 - (-(gamma * gamma).powi(2)).exp() - 0.1_f64).max(0.0),
    );

    println!(
        "{:<12} {:>8} {:>8} {:>10} {:>10} {:>14}",
        "algorithm", "R²", "rounds", "queries", "wall(s)", "true-support%"
    );
    let support_hit = |set: &[usize]| {
        if data.true_support.is_empty() {
            return 0.0;
        }
        100.0 * set.iter().filter(|a| data.true_support.contains(a)).count() as f64
            / set.len().max(1) as f64
    };
    let mut print_row = |name: &str, set: &[usize], rounds: usize, queries: usize, wall: f64| {
        println!(
            "{:<12} {:>8.4} {:>8} {:>10} {:>10.3} {:>13.0}%",
            name,
            r2.eval(set),
            rounds,
            queries,
            wall,
            support_hit(set)
        );
    };

    let dash = Dash::new(DashConfig { k, ..Default::default() }).run(&obj, &mut rng);
    print_row("dash", &dash.set, dash.rounds, dash.queries, dash.wall_s);

    let greedy = Greedy::new(GreedyConfig { k, ..Default::default() }).run(&obj);
    print_row("sds_ma", &greedy.set, greedy.rounds, greedy.queries, greedy.wall_s);

    let topk = TopK::new(k).run(&obj);
    print_row("top_k", &topk.set, topk.rounds, topk.queries, topk.wall_s);

    let rnd = RandomSelect::new(k).run_mean(&obj, &mut rng, 5);
    print_row("random", &rnd.set, rnd.rounds, rnd.queries, rnd.wall_s);

    let lasso = Lasso::new(LassoConfig::default()).run_for_k(&data.x, &data.y, k);
    print_row("lasso", &lasso.set, lasso.rounds, lasso.queries, lasso.wall_s);

    println!(
        "\nDASH: {} rounds vs greedy's {} — on a 16-core machine the modeled parallel \
         time ratio is {:.1}×.",
        dash.rounds,
        greedy.rounds,
        greedy.modeled_parallel_s(Some(16)) / dash.modeled_parallel_s(Some(16)).max(1e-12)
    );
}
