//! Quickstart: select features with DASH and compare against greedy.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use dash_select::prelude::*;

fn main() {
    // 1. a synthetic regression workload: 300 samples × 200 features,
    //    40 informative, pairwise feature correlation 0.4 (paper's D1 shape)
    let mut rng = Pcg64::seed_from(7);
    let data = synthetic::regression_d1(&mut rng, 300, 200, 40, 0.4);
    let objective = LinearRegressionObjective::new(&data);

    // 2. run DASH (the paper's parallel algorithm) ...
    let k = 25;
    let dash = Dash::new(DashConfig { k, ..Default::default() }).run(&objective, &mut rng);

    // 3. ... and the sequential greedy baseline (SDS_MA)
    let greedy = Greedy::new(GreedyConfig { k, ..Default::default() }).run(&objective);

    println!("workload: {} ({} samples x {} features, k = {k})", data.name, data.d(), data.n());
    println!();
    println!(
        "{:<10} {:>10} {:>8} {:>10} {:>12}",
        "algorithm", "f(S)=R2", "rounds", "queries", "wall"
    );
    for r in [&dash, &greedy] {
        println!(
            "{:<10} {:>10.4} {:>8} {:>10} {:>11.3}s",
            r.algorithm, r.value, r.rounds, r.queries, r.wall_s
        );
    }
    println!();
    println!(
        "DASH reached {:.1}% of greedy's value in {} adaptive rounds vs greedy's {} \
         (the paper's headline: comparable value, exponentially fewer rounds).",
        100.0 * dash.value / greedy.value.max(1e-12),
        dash.rounds,
        greedy.rounds
    );
}
