//! Serving-front walkthrough: many clients, few pooled oracle rounds.
//!
//! Opens a `SessionServer` through the leader — one greedy-driven session,
//! one DASH-driven session, and one ad-hoc session — and serves them to
//! concurrent clients over cloneable `SessionClient` handles. Shows the
//! three serving invariants:
//!
//! 1. **determinism** — driving an algorithm through the server is
//!    byte-identical to running it solo (`Leader::run`);
//! 2. **coalescing** — concurrent same-generation sweep requests collapse
//!    into fewer pooled oracle rounds (the paper's few-adaptive-rounds
//!    discipline applied to request traffic);
//! 3. **generation stamps** — an insert bumps the generation, every sweep
//!    reply says which generation its gains describe, and a client's own
//!    writes are always visible to its later reads.
//!
//! ```bash
//! cargo run --release --offline --example serving
//! ```

use dash_select::coordinator::{Leader, PlanSpec, ProblemSpec, ServeConfig, ServeSpec};
use dash_select::data::synthetic;
use dash_select::rng::Pcg64;
use std::sync::Arc;

fn main() {
    let mut rng = Pcg64::seed_from(7);
    let data = Arc::new(synthetic::regression_d1(&mut rng, 150, 120, 30, 0.4));
    let n = data.n();
    let k = 8;
    println!(
        "serving workload: {} ({} samples × {n} features), k = {k}\n",
        data.name,
        data.d()
    );

    let leader = Leader::new();
    // the v1 builders: one validated problem (dataset, k, seed; objective
    // defaults to Lreg for a regression task), one plan per lane
    let problem = ProblemSpec::builder(Arc::clone(&data))
        .k(k)
        .seed(3)
        .build()
        .expect("problem spec");
    let greedy_job = problem.job(&PlanSpec::greedy().build().expect("greedy plan"));
    let specs = vec![
        ServeSpec::driven(greedy_job.clone()),
        ServeSpec::driven(problem.job(&PlanSpec::dash().build().expect("dash plan"))),
        ServeSpec::adhoc(problem.job(&PlanSpec::topk().build().expect("topk plan"))),
    ];

    // two stepper clients drive the algorithm sessions while three reader
    // clients hammer the ad-hoc lane with overlapping sweeps; reader 0
    // also grows the ad-hoc set, so the others race a moving generation
    let ((greedy_served, dash_served, reader_gens), summary) = leader
        .serve(&specs, ServeConfig::default(), move |clients| {
            let adhoc = clients[2].clone();
            std::thread::scope(|s| {
                let g = {
                    let c = clients[0].clone();
                    s.spawn(move || c.drive().expect("greedy lane"))
                };
                let d = {
                    let c = clients[1].clone();
                    s.spawn(move || c.drive().expect("dash lane"))
                };
                let readers: Vec<_> = (0..3usize)
                    .map(|t| {
                        let c = adhoc.clone();
                        s.spawn(move || {
                            let cand: Vec<usize> = (0..n).collect();
                            let mut gens = Vec::new();
                            for i in 0..12 {
                                let sw = c.sweep(&cand).expect("ad-hoc sweep");
                                assert_eq!(sw.gains.len(), n);
                                gens.push(sw.generation);
                                if t == 0 && i % 4 == 3 {
                                    c.insert(i).expect("ad-hoc insert");
                                }
                            }
                            gens
                        })
                    })
                    .collect();
                let gens: Vec<Vec<u64>> =
                    readers.into_iter().map(|h| h.join().expect("reader")).collect();
                (g.join().expect("greedy"), d.join().expect("dash"), gens)
            })
        })
        .expect("serve");

    // 1. determinism: served greedy == solo run, byte for byte
    let solo = leader.run(&greedy_job).expect("solo greedy").result;
    assert_eq!(solo.set, greedy_served.set);
    assert_eq!(solo.value.to_bits(), greedy_served.value.to_bits());
    assert_eq!(solo.queries, greedy_served.queries);
    println!(
        "greedy through the server: f(S) = {:.5}, |S| = {}, {} queries — byte-identical to solo",
        greedy_served.value,
        greedy_served.set.len(),
        greedy_served.queries
    );
    println!(
        "dash through the server:   f(S) = {:.5} in {} adaptive rounds",
        dash_served.value, dash_served.rounds
    );

    // 2. coalescing
    let m = &summary.metrics;
    println!(
        "\ncoalescing: {} sweep requests served by {} pooled rounds \
         ({:.2} sweeps/round) across {} turns",
        m.sweep_requests,
        m.coalesced_rounds,
        m.sweep_requests as f64 / m.coalesced_rounds.max(1) as f64,
        m.turns
    );

    // 3. generation stamps: monotone per client (no reply is ever staler
    // than one the client already saw), ad-hoc lane ended at generation 3
    for gens in &reader_gens {
        assert!(gens.windows(2).all(|w| w[0] <= w[1]), "stale reply: {gens:?}");
    }
    let adhoc_snap = &summary.sessions[2];
    assert_eq!(adhoc_snap.generation.0, 3);
    println!(
        "generations observed per reader (first → last, all monotone): {:?}; \
         ad-hoc lane finished at generation {} with S = {:?}",
        reader_gens
            .iter()
            .map(|g| (g.first().copied().unwrap_or(0), g.last().copied().unwrap_or(0)))
            .collect::<Vec<_>>(),
        adhoc_snap.generation.0,
        adhoc_snap.set
    );
    println!("\nserving OK");
}
