//! Figure 2 regeneration bench: linear-regression feature selection on D1
//! (synthetic) and D2-sim (clinical substitute) — all six panels.
//!
//! Prints the paper's series (value per round, accuracy per k, time per k)
//! and the headline speedup. `DASH_SCALE=paper` for full-size runs.

use dash_select::experiments::figs::{run_figure, speedup_summary, FigureConfig, FigureId, Panel};
use dash_select::experiments::Scale;

fn main() {
    let scale = match std::env::var("DASH_SCALE").as_deref() {
        Ok("paper") => Scale::Paper,
        _ => Scale::Quick,
    };
    dash_select::util::logging::set_level(dash_select::util::logging::Level::Info);
    let cfg = FigureConfig {
        figure: FigureId::Fig2,
        scale,
        panel: Panel::All,
        seed: 1,
        algo_budget_s: 120.0,
        ..Default::default()
    };
    let out = run_figure(&cfg);
    for (label, table) in &out.tables {
        println!("\n=== {label} ===");
        println!("{}", table.to_pretty());
        if label.ends_with("_time") {
            if let Some(s) = speedup_summary(table) {
                println!("fig2 adaptivity speedup (greedy rounds / dash rounds @ max k): {s:.2}x");
            }
        }
    }
}
