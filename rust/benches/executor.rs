//! BatchExecutor micro-benchmarks: blocked-vs-scalar sweep kernels,
//! zero-clone vs clone-per-shard sharding, sequential vs pool-sharded
//! sweeps, and the GainCache memo path. Records everything to
//! `BENCH_executor.json` at the repository root (uploaded as a CI artifact
//! per run) so sweep throughput is tracked across PRs.
//!
//! The `objectives` entries are the acceptance record for the level-3
//! sweep kernels: blocked throughput vs the scalar per-candidate path at
//! the reference shape d=512, n=2048, |S|=32 for lreg and A-opt.
//!
//! Run: `cargo bench --offline --bench executor` (DASH_BENCH_FAST=1 for a
//! quick pass; DASH_THREADS=N to pin the pool size).

use dash_select::bench::Bench;
use dash_select::coordinator::session::SelectionSession;
use dash_select::coordinator::{
    AlgorithmChoice, ApiReply, ApiRequest, Backend, Leader, NetConfig, NetServer, ObjectiveChoice,
    RetryPolicy, Router, RouterConfig, SelectionJob, ServeConfig, ServeSpec, SessionStore,
    StdioServer, WireClient, WirePlan, WireProblem,
};
use dash_select::data::gene_sim::{gene_d4, GeneConfig};
use dash_select::data::synthetic;
use dash_select::linalg::{self, simd, Matrix};
use dash_select::objectives::{
    AOptimalityObjective, DiverseObjective, GroupSqrtDiversity, LinearRegressionObjective,
    Objective, ObjectiveState, OvrSoftmaxObjective,
};
use dash_select::oracle::{BatchExecutor, GainCache};
use dash_select::rng::Pcg64;
use dash_select::util::json::Json;
use dash_select::util::threadpool::ThreadPool;
use std::path::PathBuf;
use std::sync::Arc;

/// The pre-refactor sharding shape: fork the state per shard via
/// `clone_box`, then run scalar per-candidate gains. Kept here (only) as
/// the baseline the zero-clone engine is measured against.
fn clone_shard_gains(pool: &ThreadPool, st: &dyn ObjectiveState, cand: &[usize]) -> Vec<f64> {
    let n = cand.len();
    let shards = pool.size().min(n).max(1);
    let chunk = n.div_ceil(shards);
    let parts: Vec<Vec<f64>> = pool.scoped_map(shards, |s| {
        let lo = s * chunk;
        let hi = ((s + 1) * chunk).min(n);
        if lo >= hi {
            return Vec::new();
        }
        let fork = st.clone_box();
        cand[lo..hi].iter().map(|&a| fork.gain(a)).collect()
    });
    let mut out = Vec::with_capacity(n);
    for p in parts {
        out.extend(p);
    }
    out
}

struct SweepCase {
    objective: &'static str,
    d: usize,
    n: usize,
    set_size: usize,
    scalar_s: f64,
    blocked_s: f64,
    clone_shard_s: f64,
    zero_clone_shard_s: f64,
    blocked_scalar_s: f64,
    blocked_simd_s: f64,
}

/// Run the blocked sequential sweep once under the forced-scalar kernel
/// table and once under auto dispatch; returns (scalar_s, simd_s). The
/// override is process-wide, so this only runs from the single-threaded
/// bench main, and auto dispatch is always restored before returning.
fn blocked_scalar_vs_simd(
    bench: &mut Bench,
    label: &str,
    st: &dyn ObjectiveState,
    cand: &[usize],
) -> (f64, f64) {
    let seq = BatchExecutor::sequential();
    assert!(simd::set_override(Some(simd::SimdLevel::Scalar)));
    let scalar_s = bench
        .run(&format!("{label} blocked forced-scalar"), || seq.gains(st, cand))
        .mean_s;
    simd::set_override(None);
    let simd_s = bench
        .run(&format!("{label} blocked {}", simd::active_name()), || seq.gains(st, cand))
        .mean_s;
    (scalar_s, simd_s)
}

/// Measure one objective at the acceptance shape: scalar per-candidate vs
/// blocked sequential sweep, and clone-per-shard vs zero-clone sharding.
fn sweep_case(
    bench: &mut Bench,
    objective: &'static str,
    st: &dyn ObjectiveState,
    d: usize,
    n: usize,
    set_size: usize,
    pool: &Arc<ThreadPool>,
) -> SweepCase {
    let cand: Vec<usize> = (0..n).collect();
    let seq = BatchExecutor::sequential();
    let par = BatchExecutor::with_pool(Arc::clone(pool)).with_min_parallel(2);
    let label = format!("{objective} d={d} n={n} |S|={set_size}");
    let scalar_s = bench
        .run(&format!("{label} scalar per-candidate"), || {
            cand.iter().map(|&a| st.gain(a)).collect::<Vec<f64>>()
        })
        .mean_s;
    let blocked_s = bench
        .run(&format!("{label} blocked sequential"), || seq.gains(st, &cand))
        .mean_s;
    let clone_shard_s = bench
        .run(&format!("{label} clone-per-shard x{}", pool.size()), || {
            clone_shard_gains(pool, st, &cand)
        })
        .mean_s;
    let zero_clone_shard_s = bench
        .run(&format!("{label} zero-clone sharded x{}", pool.size()), || {
            par.gains(st, &cand)
        })
        .mean_s;
    let (blocked_scalar_s, blocked_simd_s) = blocked_scalar_vs_simd(bench, &label, st, &cand);
    SweepCase {
        objective,
        d,
        n,
        set_size,
        scalar_s,
        blocked_s,
        clone_shard_s,
        zero_clone_shard_s,
        blocked_scalar_s,
        blocked_simd_s,
    }
}

fn main() {
    let mut bench = Bench::new("executor");
    let mut rng = Pcg64::seed_from(1);
    let threads = ThreadPool::default_size();
    println!("executor bench: {threads} worker threads (DASH_THREADS to override)\n");

    let pool = Arc::new(ThreadPool::new(threads));
    let seq = BatchExecutor::sequential();
    let par = BatchExecutor::with_pool(Arc::clone(&pool)).with_min_parallel(2);

    // ---- acceptance shape: blocked vs scalar, clone vs zero-clone ----
    // lreg: d samples, n candidate features, |S| = 32 selected
    let (d, n, s) = (512usize, 2048usize, 32usize);
    let ds_big = synthetic::regression_d1(&mut rng, d, n, 128, 0.4);
    let lreg_big = LinearRegressionObjective::new(&ds_big);
    let lreg_set: Vec<usize> = (0..s).collect();
    let lreg_st = lreg_big.state_for(&lreg_set);
    let mut cases = Vec::new();
    cases.push(sweep_case(&mut bench, "lreg", &*lreg_st, d, n, s, &pool));

    // aopt: d×d posterior covariance, n candidate stimuli
    let ds_aopt = synthetic::design_d1(&mut rng, d, n, 0.5);
    let aopt_big = AOptimalityObjective::new(&ds_aopt, 1.0, 1.0);
    let aopt_st = aopt_big.state_for(&lreg_set);
    cases.push(sweep_case(&mut bench, "aopt", &*aopt_st, d, n, s, &pool));

    // ---- SIMD speedup record at the acceptance shape (ISSUE 8) ----
    // diversity and softmax skip the scalar-per-candidate / clone-shard
    // baselines (a Newton refit per candidate at n=2048 would dominate the
    // suite); they record only blocked forced-scalar vs dispatched SIMD
    let mut simd_cases: Vec<(&'static str, usize, usize, usize, f64, f64)> = Vec::new();
    let cand_big: Vec<usize> = (0..n).collect();
    let div_big = DiverseObjective::new(
        LinearRegressionObjective::new(&ds_big),
        GroupSqrtDiversity::round_robin(n, 16, 0.1),
    );
    let div_st = div_big.state_for(&lreg_set);
    let (div_scalar_s, div_simd_s) = blocked_scalar_vs_simd(
        &mut bench,
        &format!("lreg+div d={d} n={n} |S|={s}"),
        &*div_st,
        &cand_big,
    );
    simd_cases.push(("lreg+div", d, n, s, div_scalar_s, div_simd_s));
    let ds_sm = gene_d4(
        &mut rng,
        &GeneConfig {
            samples: d,
            genes: n,
            classes: 3,
            informative_per_class: 16,
            ..Default::default()
        },
    );
    let sm_big = OvrSoftmaxObjective::new(&ds_sm).expect("classification dataset");
    let sm_st = sm_big.state_for(&lreg_set);
    let (sm_scalar_s, sm_simd_s) = blocked_scalar_vs_simd(
        &mut bench,
        &format!("ovr-softmax d={d} n={n} |S|={s}"),
        &*sm_st,
        &cand_big,
    );
    simd_cases.push(("ovr-softmax", d, n, s, sm_scalar_s, sm_simd_s));

    // ---- roofline: per-kernel GFLOP/s, forced-scalar vs dispatched ----
    // flops are the exact multiply+add counts of each kernel; bytes are
    // the compulsory traffic (operands read once + results written once),
    // so ai = flops/bytes is the arithmetic intensity the roofline model
    // plots against. gemm should sit in the compute-bound regime (ai ~ 8
    // at the acceptance shape), dot/axpy pin the memory-bound floor.
    struct RoofCell {
        kernel: &'static str,
        d: usize,
        n: usize,
        flops: f64,
        bytes: f64,
        scalar_s: f64,
        simd_s: f64,
    }
    let mut roof: Vec<RoofCell> = Vec::new();
    let simd_level = simd::active_name();
    for &(rd, rn) in &[(64usize, 256usize), (256, 1024), (512, 2048)] {
        let len = rd * rn;
        let xv: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
        let yv: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
        let mut ra = Matrix::zeros(rd, rn);
        for j in 0..rn {
            for i in 0..rd {
                ra.set(i, j, rng.next_gaussian());
            }
        }
        let mut rb = Matrix::zeros(rn, 32);
        for j in 0..32 {
            for i in 0..rn {
                rb.set(i, j, rng.next_gaussian());
            }
        }
        let mut rat = Matrix::zeros(rd, 32);
        for j in 0..32 {
            for i in 0..rd {
                rat.set(i, j, rng.next_gaussian());
            }
        }
        let gx: Vec<f64> = (0..rn).map(|_| rng.next_gaussian()).collect();
        let mut gy = vec![0.0f64; rd];
        let mut rc = Matrix::zeros(rd, 32);
        let mut rt = Matrix::zeros(32, 32);
        let mut kernel_cells: Vec<(&'static str, f64, f64)> = Vec::new();
        let mut measure = |bench: &mut Bench, forced: bool| {
            let tag = if forced { "scalar" } else { simd_level };
            let grid = format!("d={rd} n={rn} {tag}");
            let dot_s = bench
                .run(&format!("roofline dot len={len} {tag}"), || linalg::dot(&xv, &yv))
                .mean_s;
            let mut axpy_dst = yv.clone();
            let axpy_s = bench
                .run(&format!("roofline axpy len={len} {tag}"), || {
                    linalg::axpy(1.0000001, &xv, &mut axpy_dst)
                })
                .mean_s;
            let gemv_s = bench
                .run(&format!("roofline gemv {grid}"), || linalg::gemv(&ra, &gx, &mut gy))
                .mean_s;
            let gemm_s = bench
                .run(&format!("roofline gemm {grid} c=32"), || {
                    linalg::gemm_into(&ra, &rb, &mut rc)
                })
                .mean_s;
            let tn_s = bench
                .run(&format!("roofline gemm_tn {grid} p=q=32"), || {
                    linalg::gemm_tn_into(&rat, &rat, &mut rt)
                })
                .mean_s;
            [dot_s, axpy_s, gemv_s, gemm_s, tn_s]
        };
        assert!(simd::set_override(Some(simd::SimdLevel::Scalar)));
        let sc = measure(&mut bench, true);
        simd::set_override(None);
        let si = measure(&mut bench, false);
        let fl = len as f64;
        let (df, dn) = (rd as f64, rn as f64);
        kernel_cells.push(("dot", 2.0 * fl, 16.0 * fl));
        kernel_cells.push(("axpy", 2.0 * fl, 24.0 * fl));
        kernel_cells.push(("gemv", 2.0 * df * dn, 8.0 * (df * dn + dn + 2.0 * df)));
        kernel_cells.push((
            "gemm",
            2.0 * df * dn * 32.0,
            8.0 * (df * dn + 32.0 * dn + 2.0 * 32.0 * df),
        ));
        kernel_cells.push((
            "gemm_tn",
            2.0 * df * 32.0 * 32.0,
            8.0 * (df * 32.0 + 2.0 * 32.0 * 32.0),
        ));
        for (i, (kernel, flops, bytes)) in kernel_cells.into_iter().enumerate() {
            roof.push(RoofCell {
                kernel,
                d: rd,
                n: rn,
                flops,
                bytes,
                scalar_s: sc[i],
                simd_s: si[i],
            });
        }
    }

    // ---- regression oracle sweeps (QR-projection gains) ----
    let ds = synthetic::regression_d1(&mut rng, 250, 500, 80, 0.4);
    let lreg = LinearRegressionObjective::new(&ds);
    let cand: Vec<usize> = (0..500).collect();
    let mut pairs: Vec<(String, f64, f64)> = Vec::new();
    for s in [0usize, 16, 48] {
        let set: Vec<usize> = (0..s).collect();
        let st = lreg.state_for(&set);
        let a = bench
            .run(&format!("lreg sweep n=500 |S|={s} sequential"), || seq.gains(&*st, &cand))
            .mean_s;
        let b = bench
            .run(&format!("lreg sweep n=500 |S|={s} parallel x{threads}"), || {
                par.gains(&*st, &cand)
            })
            .mean_s;
        pairs.push((format!("lreg_s{s}"), a, b));
    }

    // ---- A-optimality oracle sweeps (M·X_C gains) ----
    let dsd = synthetic::design_d1(&mut rng, 64, 256, 0.6);
    let aopt = AOptimalityObjective::new(&dsd, 1.0, 1.0);
    let candd: Vec<usize> = (0..256).collect();
    let sta = aopt.state_for(&[1, 5, 9, 100]);
    let a = bench
        .run("aopt sweep n=256 d=64 sequential", || seq.gains(&*sta, &candd))
        .mean_s;
    let b = bench
        .run(&format!("aopt sweep n=256 d=64 parallel x{threads}"), || {
            par.gains(&*sta, &candd)
        })
        .mean_s;
    pairs.push(("aopt".to_string(), a, b));

    // ---- memoized repeat sweep (DASH filter-iteration shape) ----
    let st = lreg.state_for(&[0, 1, 2, 3]);
    bench.run("lreg repeat sweep uncached", || seq.gains(&*st, &cand));
    bench.run("lreg repeat sweep via GainCache", || {
        // fresh cache each iteration, two sweeps: the second is all hits —
        // this is one filter iteration followed by a re-sweep of survivors
        let mut cache = GainCache::new(lreg.n());
        let (first, _) = seq.cached_gains(&mut cache, &*st, &cand);
        let (second, fresh) = seq.cached_gains(&mut cache, &*st, &cand);
        assert_eq!(fresh, 0);
        (first, second)
    });

    // ---- serial vs prefix-parallel prefix walk (adaptive sequencing) ----
    // one iteration's round 2: |seq| prefix marginals on top of |S| = 32
    let prefix_seq: Vec<usize> = (64..64 + 96).collect();
    let prefix_serial_s = bench
        .run("prefix walk |seq|=96 serial", || {
            let mut s = SelectionSession::new(&lreg_big, BatchExecutor::sequential());
            s.commit(&lreg_set);
            s.prefix_gains_serial(&prefix_seq)
        })
        .mean_s;
    let prefix_parallel_s = bench
        .run(&format!("prefix walk |seq|=96 blocked x{threads}"), || {
            let mut s =
                SelectionSession::new(&lreg_big, BatchExecutor::with_pool(Arc::clone(&pool)));
            s.commit(&lreg_set);
            s.prefix_gains(&prefix_seq)
        })
        .mean_s;

    // ---- session throughput: inserts/sec, warm vs invalidated cache ----
    // warm: repeated sweeps at a fixed generation are pure cache hits;
    // invalidated: each insert bumps the generation, so every sweep
    // re-queries — the steady-state cost of a stepwise greedy session
    let session_cand: Vec<usize> = (0..500).collect();
    let mut warm_session =
        SelectionSession::new(&lreg, BatchExecutor::with_pool(Arc::clone(&pool)));
    let _ = warm_session.sweep(&session_cand); // populate the generation cache
    let warm_sweep_s = bench
        .run("session warm re-sweep n=500 (cache hits)", || {
            let sw = warm_session.sweep(&session_cand);
            assert_eq!(sw.fresh, 0);
            sw.gains
        })
        .mean_s;
    let insert_rounds = 8usize;
    let insert_sweep_s = bench
        .run("session insert+sweep n=500 (invalidated cache)", || {
            let mut s = SelectionSession::new(&lreg, BatchExecutor::with_pool(Arc::clone(&pool)));
            for a in 0..insert_rounds {
                let sw = s.sweep(&session_cand);
                assert_eq!(sw.fresh, session_cand.len());
                s.insert(a);
            }
            s.metrics.inserts
        })
        .mean_s;
    let inserts_per_s =
        if insert_sweep_s > 0.0 { insert_rounds as f64 / insert_sweep_s } else { 0.0 };

    // ---- serving front: request throughput + sweep coalescing ----
    // concurrent clients hammer one ad-hoc session through Leader::serve;
    // the server coalesces same-generation sweeps into pooled rounds, so
    // rounds-per-sweep < 1 is the coalescing win
    let fast = std::env::var("DASH_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let serve_clients = 4usize;
    let serve_sweeps = if fast { 32usize } else { 160 };
    let serve_ds = synthetic::regression_d1(&mut rng, 120, 400, 40, 0.3);
    let serve_n = serve_ds.n();
    let serve_leader = Leader::with_threads(threads);
    let serve_spec = ServeSpec::adhoc(SelectionJob {
        dataset: Arc::new(serve_ds),
        objective: ObjectiveChoice::Lreg,
        backend: Backend::Native,
        algorithm: AlgorithmChoice::TopK,
        k: 16,
        seed: 1,
    });
    let serve_t0 = std::time::Instant::now();
    let ((), serve_summary) = serve_leader
        .serve(&[serve_spec], ServeConfig::default(), move |clients| {
            let handle = clients[0].clone();
            std::thread::scope(|s| {
                for t in 0..serve_clients {
                    let c = handle.clone();
                    s.spawn(move || {
                        let cand: Vec<usize> = (0..serve_n).collect();
                        for i in 0..serve_sweeps {
                            let sw = c.sweep(&cand).expect("bench sweep");
                            assert_eq!(sw.gains.len(), serve_n);
                            if t == 0 && i % 8 == 7 {
                                c.insert((i * 13) % serve_n).expect("bench insert");
                            }
                        }
                    });
                }
            });
        })
        .expect("serve bench");
    let serve_elapsed = serve_t0.elapsed().as_secs_f64().max(1e-12);
    let sm = &serve_summary.metrics;
    let serve_rps = sm.requests as f64 / serve_elapsed;
    let rounds_per_sweep = if sm.sweep_requests > 0 {
        sm.coalesced_rounds as f64 / sm.sweep_requests as f64
    } else {
        0.0
    };

    // ---- v1 wire codec: per-frame encode/decode overhead ----
    // the shape a sweep-heavy wire client pays per request: one n=500
    // sweep request frame out, one 500-gain reply frame back
    let api_n = 500usize;
    let api_req = ApiRequest::Sweep { session: 0, candidates: (0..api_n).collect() };
    let api_req_line = api_req.encode(1);
    let api_reply = ApiReply::Swept {
        gains: (0..api_n).map(|i| i as f64 * 0.1253 + 0.5).collect(),
        generation: 3,
        fresh: api_n,
    };
    let api_reply_line = api_reply.encode(1);
    let api_encode_request_s =
        bench.run("api encode sweep request n=500", || api_req.encode(1)).mean_s;
    let api_decode_request_s = bench
        .run("api decode sweep request n=500", || {
            ApiRequest::decode(&api_req_line).expect("bench frame decodes")
        })
        .mean_s;
    let api_encode_reply_s =
        bench.run("api encode swept reply n=500", || api_reply.encode(1)).mean_s;
    let api_decode_reply_s = bench
        .run("api decode swept reply n=500", || {
            ApiReply::decode(&api_reply_line).expect("bench frame decodes")
        })
        .mean_s;
    let api_round_trip_s = api_encode_request_s
        + api_decode_request_s
        + api_encode_reply_s
        + api_decode_reply_s;
    let api_frames_per_s =
        if api_round_trip_s > 0.0 { 1.0 / api_round_trip_s } else { 0.0 };

    // ---- session lifecycle: open/close churn + evict/restore latency ----
    // churn: open_spec + close through an 8-slot budget — the admission
    // and retirement cost of one wire session (the dataset build is
    // amortized by the front's cache, so this isolates lifecycle cost)
    let lc_problem = WireProblem::new("d1", 5, 3);
    let lc_plan = WirePlan::new("greedy");
    let mut churn_server = StdioServer::new(Leader::with_threads(1)).with_max_sessions(8);
    let warm = churn_server.open_spec(&lc_problem, &lc_plan, false, None, None).expect("bench open");
    churn_server.close_session(warm).expect("bench close");
    let churn_cycles = if fast { 16usize } else { 64 };
    let churn_batch_s = bench
        .run("lifecycle open+close churn (8-slot budget)", || {
            for _ in 0..churn_cycles {
                let s = churn_server
                    .open_spec(&lc_problem, &lc_plan, false, None, None)
                    .expect("bench open");
                churn_server.close_session(s).expect("bench close");
            }
        })
        .mean_s;
    let open_close_s = churn_batch_s / churn_cycles as f64;
    let opens_per_s = if open_close_s > 0.0 { 1.0 / open_close_s } else { 0.0 };

    // evict/restore: a one-slot budget over a session store makes every
    // touch of the cold session one full snapshot→persist→restore round
    // trip (restoring it evicts the other session)
    let lc_dir =
        std::env::temp_dir().join(format!("dash-bench-lifecycle-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&lc_dir);
    let mut swap_server = StdioServer::new(Leader::with_threads(1))
        .with_max_sessions(1)
        .with_store(SessionStore::open(&lc_dir).expect("bench store"));
    let swap_a = swap_server.open_spec(&lc_problem, &lc_plan, false, None, None).expect("bench open");
    let swap_b = swap_server.open_spec(&lc_problem, &lc_plan, false, None, None).expect("bench open");
    let mut cold = swap_a;
    let evict_restore_s = bench
        .run("lifecycle evict+restore swap (one-slot budget)", || {
            match swap_server.handle(ApiRequest::Metrics { session: cold }).expect("bench swap") {
                ApiReply::Snapshot { .. } => {}
                other => panic!("unexpected {other:?}"),
            }
            cold = if cold == swap_a { swap_b } else { swap_a };
        })
        .mean_s;
    let lifecycle_restores = swap_server.restores;
    let _ = std::fs::remove_dir_all(&lc_dir);

    // ---- socket front: requests/s + reconnect-and-restore latency ----
    // a real WireClient sweeping over a real socket measures the full
    // per-request stack (codec + kernel + supervision); the second half
    // drains the server, restarts it on the same store and socket path,
    // and times one request through redial + store restore
    let net_dir = std::env::temp_dir().join(format!("dash-bench-net-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&net_dir);
    std::fs::create_dir_all(&net_dir).expect("bench net dir");
    let net_sock = format!("unix:{}", net_dir.join("bench.sock").display());
    let net_store = net_dir.join("store");
    let net_config = NetConfig { poll_tick: std::time::Duration::from_millis(1), ..NetConfig::default() };
    let start_net_server = |stop: &'static std::sync::atomic::AtomicBool| {
        let sock = net_sock.clone();
        let store = net_store.clone();
        let server = NetServer::bind(&sock)
            .expect("bench net bind")
            .with_config(net_config)
            .with_stop_flag(stop);
        std::thread::spawn(move || {
            server
                .serve(
                    StdioServer::new(Leader::with_threads(1))
                        .with_store(SessionStore::open(&store).expect("bench net store"))
                        .into_core(),
                )
                .expect("bench net serve")
        })
    };
    let net_stop: &'static std::sync::atomic::AtomicBool =
        Box::leak(Box::new(std::sync::atomic::AtomicBool::new(false)));
    let net_handle = start_net_server(net_stop);
    let mut net_client = WireClient::connect(&net_sock, 7).with_policy(RetryPolicy {
        max_attempts: 200,
        base_backoff: std::time::Duration::from_millis(1),
        max_backoff: std::time::Duration::from_millis(20),
    });
    let net_session = net_client
        .open(WireProblem::new("d1", 5, 3), WirePlan::new("greedy"), false, None)
        .expect("bench net open");
    let net_cand: Vec<usize> = (0..64).collect();
    let net_requests = if fast { 64usize } else { 512 };
    let net_t0 = std::time::Instant::now();
    for _ in 0..net_requests {
        net_client.sweep(net_session, net_cand.clone()).expect("bench net sweep");
    }
    let net_elapsed = net_t0.elapsed().as_secs_f64().max(1e-12);
    let net_rps = net_requests as f64 / net_elapsed;
    // drain, restart on the same socket + store, and time the resume
    net_stop.store(true, std::sync::atomic::Ordering::SeqCst);
    net_handle.join().expect("bench net drain");
    let net_stop2: &'static std::sync::atomic::AtomicBool =
        Box::leak(Box::new(std::sync::atomic::AtomicBool::new(false)));
    let net_handle2 = start_net_server(net_stop2);
    let reconnect_t0 = std::time::Instant::now();
    let snap = net_client.metrics(net_session).expect("bench net resume");
    let reconnect_restore_s = reconnect_t0.elapsed().as_secs_f64();
    assert_eq!(snap.generation.0, 0, "resumed session must be the stored one");
    net_stop2.store(true, std::sync::atomic::Ordering::SeqCst);
    net_handle2.join().expect("bench net drain 2");
    let _ = std::fs::remove_dir_all(&net_dir);

    // ---- cluster front: concurrent clients through the router ----
    // serve_net above is one sequential client against one worker, so its
    // req/s is bounded by round-trip latency; here hundreds of concurrent
    // clients push sweeps through one router over two workers — the number
    // that must beat net_rps for the router hop to pay for itself
    let cluster_dir =
        std::env::temp_dir().join(format!("dash-bench-cluster-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cluster_dir);
    std::fs::create_dir_all(&cluster_dir).expect("bench cluster dir");
    let cluster_store = cluster_dir.join("store");
    let cluster_workers = 2usize;
    let worker_socks: Vec<String> = (0..cluster_workers)
        .map(|w| format!("unix:{}", cluster_dir.join(format!("w{w}.sock")).display()))
        .collect();
    let mut worker_stops = Vec::new();
    let mut worker_handles = Vec::new();
    for sock in &worker_socks {
        let stop: &'static std::sync::atomic::AtomicBool =
            Box::leak(Box::new(std::sync::atomic::AtomicBool::new(false)));
        let server = NetServer::bind(sock)
            .expect("bench worker bind")
            .with_config(net_config)
            .with_stop_flag(stop);
        let store = cluster_store.clone();
        worker_stops.push(stop);
        worker_handles.push(std::thread::spawn(move || {
            server
                .serve(
                    StdioServer::new(Leader::with_threads(1))
                        // budget above clients/worker: measure the request
                        // stack, not evict/restore churn
                        .with_max_sessions(256)
                        .with_store(SessionStore::open(&store).expect("bench worker store"))
                        .into_core(),
                )
                .expect("bench worker serve")
        }));
    }
    let router_sock = format!("unix:{}", cluster_dir.join("router.sock").display());
    let router_stop: &'static std::sync::atomic::AtomicBool =
        Box::leak(Box::new(std::sync::atomic::AtomicBool::new(false)));
    let worker_refs: Vec<&str> = worker_socks.iter().map(|s| s.as_str()).collect();
    let router = Router::bind(&router_sock, &worker_refs)
        .expect("bench router bind")
        .with_config(RouterConfig { net: net_config, ..RouterConfig::default() })
        .with_stop_flag(router_stop);
    let router_handle = std::thread::spawn(move || router.serve().expect("bench router serve"));
    let cluster_clients = if fast { 16usize } else { 200 };
    let cluster_sweeps = if fast { 4usize } else { 8 };
    let cluster_t0 = std::time::Instant::now();
    let client_threads: Vec<_> = (0..cluster_clients)
        .map(|c| {
            let addr = router_sock.clone();
            std::thread::spawn(move || {
                let mut client =
                    WireClient::connect(&addr, 900 + c as u64).with_policy(RetryPolicy {
                        max_attempts: 200,
                        base_backoff: std::time::Duration::from_millis(1),
                        max_backoff: std::time::Duration::from_millis(20),
                    });
                let session = client
                    .open(WireProblem::new("d1", 5, 3), WirePlan::new("greedy"), false, None)
                    .expect("bench cluster open");
                let cand: Vec<usize> = (0..64).collect();
                for _ in 0..cluster_sweeps {
                    client.sweep(session, cand.clone()).expect("bench cluster sweep");
                }
                1 + cluster_sweeps // requests this client pushed through
            })
        })
        .collect();
    let cluster_requests: usize =
        client_threads.into_iter().map(|h| h.join().expect("bench cluster client")).sum();
    let cluster_elapsed = cluster_t0.elapsed().as_secs_f64().max(1e-12);
    let cluster_rps = cluster_requests as f64 / cluster_elapsed;
    router_stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let router_summary = router_handle.join().expect("bench router drain");
    assert_eq!(router_summary.worker_deaths, 0, "bench fleet must stay healthy");
    for stop in &worker_stops {
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    for h in worker_handles {
        h.join().expect("bench worker drain");
    }
    let _ = std::fs::remove_dir_all(&cluster_dir);

    // ---- report ----
    println!();
    let mut obj_entries = Vec::new();
    for c in &cases {
        let blocked_speedup = if c.blocked_s > 0.0 { c.scalar_s / c.blocked_s } else { 0.0 };
        let shard_speedup = if c.zero_clone_shard_s > 0.0 {
            c.clone_shard_s / c.zero_clone_shard_s
        } else {
            0.0
        };
        let simd_speedup =
            if c.blocked_simd_s > 0.0 { c.blocked_scalar_s / c.blocked_simd_s } else { 0.0 };
        println!(
            "{} d={} n={} |S|={}: scalar {:.6}s, blocked {:.6}s ({blocked_speedup:.2}x); \
             clone-shard {:.6}s, zero-clone-shard {:.6}s ({shard_speedup:.2}x); \
             blocked scalar-dispatch {:.6}s vs {simd_level} {:.6}s ({simd_speedup:.2}x)",
            c.objective, c.d, c.n, c.set_size, c.scalar_s, c.blocked_s, c.clone_shard_s,
            c.zero_clone_shard_s, c.blocked_scalar_s, c.blocked_simd_s,
        );
        obj_entries.push(Json::obj(vec![
            ("objective", c.objective.into()),
            ("d", c.d.into()),
            ("n", c.n.into()),
            ("set_size", c.set_size.into()),
            ("scalar_s", c.scalar_s.into()),
            ("blocked_s", c.blocked_s.into()),
            ("blocked_speedup", blocked_speedup.into()),
            ("clone_shard_s", c.clone_shard_s.into()),
            ("zero_clone_shard_s", c.zero_clone_shard_s.into()),
            ("shard_speedup", shard_speedup.into()),
            ("blocked_scalar_s", c.blocked_scalar_s.into()),
            ("blocked_simd_s", c.blocked_simd_s.into()),
            ("simd_speedup", simd_speedup.into()),
        ]));
    }
    for &(objective, cd, cn, cs, scalar_s, simd_s) in &simd_cases {
        let simd_speedup = if simd_s > 0.0 { scalar_s / simd_s } else { 0.0 };
        println!(
            "{objective} d={cd} n={cn} |S|={cs}: blocked scalar-dispatch {scalar_s:.6}s \
             vs {simd_level} {simd_s:.6}s ({simd_speedup:.2}x)"
        );
        obj_entries.push(Json::obj(vec![
            ("objective", objective.into()),
            ("d", cd.into()),
            ("n", cn.into()),
            ("set_size", cs.into()),
            ("blocked_scalar_s", scalar_s.into()),
            ("blocked_simd_s", simd_s.into()),
            ("simd_speedup", simd_speedup.into()),
        ]));
    }
    let mut roof_entries = Vec::new();
    for r in &roof {
        let ai = if r.bytes > 0.0 { r.flops / r.bytes } else { 0.0 };
        let gf_scalar = if r.scalar_s > 0.0 { r.flops / r.scalar_s / 1e9 } else { 0.0 };
        let gf_simd = if r.simd_s > 0.0 { r.flops / r.simd_s / 1e9 } else { 0.0 };
        let speedup = if r.simd_s > 0.0 { r.scalar_s / r.simd_s } else { 0.0 };
        println!(
            "roofline {:<8} d={:<4} n={:<5} ai={ai:>6.3} flop/byte: scalar \
             {gf_scalar:>7.2} GF/s, {simd_level} {gf_simd:>7.2} GF/s ({speedup:.2}x)",
            r.kernel, r.d, r.n
        );
        roof_entries.push(Json::obj(vec![
            ("kernel", r.kernel.into()),
            ("d", r.d.into()),
            ("n", r.n.into()),
            ("flops", r.flops.into()),
            ("arithmetic_intensity", ai.into()),
            ("scalar_s", r.scalar_s.into()),
            ("simd_s", r.simd_s.into()),
            ("gflops_scalar", gf_scalar.into()),
            ("gflops_simd", gf_simd.into()),
            ("simd_speedup", speedup.into()),
        ]));
    }
    let mut entries = Vec::new();
    for (name, s, p) in &pairs {
        let speedup = if *p > 0.0 { s / p } else { 0.0 };
        println!("{name}: sequential {s:.6}s, parallel {p:.6}s, speedup {speedup:.2}x");
        entries.push(Json::obj(vec![
            ("name", name.as_str().into()),
            ("sequential_s", (*s).into()),
            ("parallel_s", (*p).into()),
            ("speedup", speedup.into()),
        ]));
    }
    // ---- sync wrapper overhead: uncontended hot path vs raw std::sync ----
    // the release wrappers must be zero-cost: poison recovery is a cold
    // branch, and the lock-order tracker compiles out entirely without
    // debug_assertions / the `lock-order` feature
    let sync_iters = 100_000usize;
    let raw_mutex = std::sync::Mutex::new(0u64);
    let sync_raw_mutex_s = bench
        .run("sync raw std mutex lock/unlock x100k", || {
            for _ in 0..sync_iters {
                *raw_mutex.lock().unwrap_or_else(|e| e.into_inner()) += 1;
            }
        })
        .mean_s;
    let wrapped_mutex = dash_select::util::sync::Mutex::new(0u64);
    let sync_wrapped_mutex_s = bench
        .run("sync wrapper mutex lock/unlock x100k", || {
            for _ in 0..sync_iters {
                *wrapped_mutex.lock() += 1;
            }
        })
        .mean_s;
    let raw_rwlock = std::sync::RwLock::new(0u64);
    let sync_raw_rwlock_s = bench
        .run("sync raw std rwlock read x100k", || {
            let mut acc = 0u64;
            for _ in 0..sync_iters {
                acc = acc.wrapping_add(*raw_rwlock.read().unwrap_or_else(|e| e.into_inner()));
            }
            acc
        })
        .mean_s;
    let wrapped_rwlock = dash_select::util::sync::RwLock::new(0u64);
    let sync_wrapped_rwlock_s = bench
        .run("sync wrapper rwlock read x100k", || {
            let mut acc = 0u64;
            for _ in 0..sync_iters {
                acc = acc.wrapping_add(*wrapped_rwlock.read());
            }
            acc
        })
        .mean_s;
    let sync_tracker = dash_select::util::sync::lock_order_enabled();
    let sync_mutex_ratio = if sync_raw_mutex_s > 0.0 {
        sync_wrapped_mutex_s / sync_raw_mutex_s
    } else {
        0.0
    };
    let sync_rwlock_ratio = if sync_raw_rwlock_s > 0.0 {
        sync_wrapped_rwlock_s / sync_raw_rwlock_s
    } else {
        0.0
    };
    println!(
        "sync wrappers (lock-order tracker {}): mutex {sync_mutex_ratio:.2}x raw, \
         rwlock read {sync_rwlock_ratio:.2}x raw over {sync_iters} uncontended ops",
        if sync_tracker { "ON" } else { "off" }
    );

    let reports: Vec<Json> = bench
        .reports
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", r.name.as_str().into()),
                ("iters", r.iters.into()),
                ("mean_s", r.mean_s.into()),
                ("p50_s", r.p50_s.into()),
                ("p95_s", r.p95_s.into()),
            ])
        })
        .collect();
    let prefix_speedup =
        if prefix_parallel_s > 0.0 { prefix_serial_s / prefix_parallel_s } else { 0.0 };
    println!(
        "prefix walk |seq|=96: serial {prefix_serial_s:.6}s, \
         blocked {prefix_parallel_s:.6}s, speedup {prefix_speedup:.2}x"
    );
    println!(
        "session: warm re-sweep {warm_sweep_s:.6}s, insert+sweep {insert_sweep_s:.6}s \
         ({inserts_per_s:.1} inserts/s with invalidated cache)"
    );
    println!(
        "serve: {} requests from {serve_clients} clients in {serve_elapsed:.3}s \
         ({serve_rps:.0} req/s); {} sweeps → {} pooled rounds \
         ({rounds_per_sweep:.3} rounds/sweep)",
        sm.requests, sm.sweep_requests, sm.coalesced_rounds
    );
    println!(
        "api wire codec (n=500): encode req {api_encode_request_s:.6}s, decode req \
         {api_decode_request_s:.6}s, encode reply {api_encode_reply_s:.6}s, decode reply \
         {api_decode_reply_s:.6}s ({api_frames_per_s:.0} round-trips/s; {}+{} bytes/frame)",
        api_req_line.len(),
        api_reply_line.len()
    );
    println!(
        "lifecycle: open+close {open_close_s:.6}s ({opens_per_s:.0} opens/s through an \
         8-slot budget); evict+restore swap {evict_restore_s:.6}s \
         ({lifecycle_restores} restores measured)"
    );
    println!(
        "serve_net: {net_requests} socket sweeps in {net_elapsed:.3}s ({net_rps:.0} req/s); \
         reconnect+restore after restart {reconnect_restore_s:.6}s"
    );
    println!(
        "serve_cluster: {cluster_requests} requests from {cluster_clients} clients through \
         the router over {cluster_workers} workers in {cluster_elapsed:.3}s \
         ({cluster_rps:.0} req/s, {:.2}x serve_net)",
        if net_rps > 0.0 { cluster_rps / net_rps } else { 0.0 }
    );
    let doc = Json::obj(vec![
        ("suite", "executor".into()),
        ("threads", threads.into()),
        ("simd_level", simd_level.into()),
        ("objectives", Json::Arr(obj_entries)),
        ("roofline", Json::Arr(roof_entries)),
        ("sweeps", Json::Arr(entries)),
        (
            "prefix",
            Json::obj(vec![
                ("seq_len", 96usize.into()),
                ("set_size", 32usize.into()),
                ("serial_s", prefix_serial_s.into()),
                ("parallel_s", prefix_parallel_s.into()),
                ("speedup", prefix_speedup.into()),
            ]),
        ),
        (
            "session",
            Json::obj(vec![
                ("n", 500usize.into()),
                ("warm_sweep_s", warm_sweep_s.into()),
                ("insert_sweep_s", insert_sweep_s.into()),
                ("inserts_per_s", inserts_per_s.into()),
            ]),
        ),
        (
            "serve",
            Json::obj(vec![
                ("clients", serve_clients.into()),
                ("n", serve_n.into()),
                ("requests", sm.requests.into()),
                ("sweep_requests", sm.sweep_requests.into()),
                ("coalesced_rounds", sm.coalesced_rounds.into()),
                ("inserts", sm.inserts.into()),
                ("elapsed_s", serve_elapsed.into()),
                ("requests_per_s", serve_rps.into()),
                ("rounds_per_sweep", rounds_per_sweep.into()),
            ]),
        ),
        (
            "api",
            Json::obj(vec![
                ("candidates", api_n.into()),
                ("encode_request_s", api_encode_request_s.into()),
                ("decode_request_s", api_decode_request_s.into()),
                ("encode_reply_s", api_encode_reply_s.into()),
                ("decode_reply_s", api_decode_reply_s.into()),
                ("round_trip_s", api_round_trip_s.into()),
                ("frames_per_s", api_frames_per_s.into()),
                ("request_bytes", api_req_line.len().into()),
                ("reply_bytes", api_reply_line.len().into()),
            ]),
        ),
        (
            "lifecycle",
            Json::obj(vec![
                ("churn_cycles", churn_cycles.into()),
                ("open_close_s", open_close_s.into()),
                ("opens_per_s", opens_per_s.into()),
                ("evict_restore_s", evict_restore_s.into()),
                ("restores", lifecycle_restores.into()),
            ]),
        ),
        (
            "serve_net",
            Json::obj(vec![
                ("requests", net_requests.into()),
                ("candidates", 64usize.into()),
                ("elapsed_s", net_elapsed.into()),
                ("requests_per_s", net_rps.into()),
                ("reconnect_restore_s", reconnect_restore_s.into()),
            ]),
        ),
        (
            "serve_cluster",
            Json::obj(vec![
                ("workers", cluster_workers.into()),
                ("clients", cluster_clients.into()),
                ("requests", cluster_requests.into()),
                ("elapsed_s", cluster_elapsed.into()),
                ("requests_per_s", cluster_rps.into()),
            ]),
        ),
        (
            "sync",
            Json::obj(vec![
                ("iters", sync_iters.into()),
                ("tracker_enabled", sync_tracker.into()),
                ("raw_mutex_s", sync_raw_mutex_s.into()),
                ("wrapper_mutex_s", sync_wrapped_mutex_s.into()),
                ("mutex_overhead_x", sync_mutex_ratio.into()),
                ("raw_rwlock_read_s", sync_raw_rwlock_s.into()),
                ("wrapper_rwlock_read_s", sync_wrapped_rwlock_s.into()),
                ("rwlock_read_overhead_x", sync_rwlock_ratio.into()),
            ]),
        ),
        ("reports", Json::Arr(reports)),
    ]);
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_executor.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_executor.json"));
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path:?}"),
        Err(e) => eprintln!("\ncould not write {path:?}: {e}"),
    }
}
