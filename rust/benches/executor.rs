//! BatchExecutor micro-benchmarks: sequential vs pool-sharded gain sweeps
//! on the regression and A-optimality oracles, plus the GainCache memo
//! path. Records the sweep throughput comparison to `BENCH_executor.json`
//! at the repository root so the speedup is tracked across PRs.
//!
//! Run: `cargo bench --offline --bench executor` (DASH_BENCH_FAST=1 for a
//! quick pass; DASH_THREADS=N to pin the pool size).

use dash_select::bench::Bench;
use dash_select::data::synthetic;
use dash_select::objectives::{AOptimalityObjective, LinearRegressionObjective, Objective};
use dash_select::oracle::{BatchExecutor, GainCache};
use dash_select::rng::Pcg64;
use dash_select::util::json::Json;
use dash_select::util::threadpool::ThreadPool;
use std::path::PathBuf;

fn main() {
    let mut bench = Bench::new("executor");
    let mut rng = Pcg64::seed_from(1);
    let threads = ThreadPool::default_size();
    println!("executor bench: {threads} worker threads (DASH_THREADS to override)\n");

    let seq = BatchExecutor::sequential();
    let par = BatchExecutor::new(threads).with_min_parallel(2);

    // ---- regression oracle sweeps (QR-projection gains) ----
    let ds = synthetic::regression_d1(&mut rng, 250, 500, 80, 0.4);
    let lreg = LinearRegressionObjective::new(&ds);
    let cand: Vec<usize> = (0..500).collect();
    let mut pairs: Vec<(String, f64, f64)> = Vec::new();
    for s in [0usize, 16, 48] {
        let set: Vec<usize> = (0..s).collect();
        let st = lreg.state_for(&set);
        let a = bench
            .run(&format!("lreg sweep n=500 |S|={s} sequential"), || seq.gains(&*st, &cand))
            .mean_s;
        let b = bench
            .run(&format!("lreg sweep n=500 |S|={s} parallel x{threads}"), || {
                par.gains(&*st, &cand)
            })
            .mean_s;
        pairs.push((format!("lreg_s{s}"), a, b));
    }

    // ---- A-optimality oracle sweeps (M·x gains) ----
    let dsd = synthetic::design_d1(&mut rng, 64, 256, 0.6);
    let aopt = AOptimalityObjective::new(&dsd, 1.0, 1.0);
    let candd: Vec<usize> = (0..256).collect();
    let sta = aopt.state_for(&[1, 5, 9, 100]);
    let a = bench
        .run("aopt sweep n=256 d=64 sequential", || seq.gains(&*sta, &candd))
        .mean_s;
    let b = bench
        .run(&format!("aopt sweep n=256 d=64 parallel x{threads}"), || {
            par.gains(&*sta, &candd)
        })
        .mean_s;
    pairs.push(("aopt".to_string(), a, b));

    // ---- memoized repeat sweep (DASH filter-iteration shape) ----
    let st = lreg.state_for(&[0, 1, 2, 3]);
    bench.run("lreg repeat sweep uncached", || seq.gains(&*st, &cand));
    bench.run("lreg repeat sweep via GainCache", || {
        // fresh cache each iteration, two sweeps: the second is all hits —
        // this is one filter iteration followed by a re-sweep of survivors
        let mut cache = GainCache::new(lreg.n());
        let (first, _) = seq.cached_gains(&mut cache, &*st, &cand);
        let (second, fresh) = seq.cached_gains(&mut cache, &*st, &cand);
        assert_eq!(fresh, 0);
        (first, second)
    });

    // ---- report ----
    println!();
    let mut entries = Vec::new();
    for (name, s, p) in &pairs {
        let speedup = if *p > 0.0 { s / p } else { 0.0 };
        println!("{name}: sequential {s:.6}s, parallel {p:.6}s, speedup {speedup:.2}x");
        entries.push(Json::obj(vec![
            ("name", name.as_str().into()),
            ("sequential_s", (*s).into()),
            ("parallel_s", (*p).into()),
            ("speedup", speedup.into()),
        ]));
    }
    let reports: Vec<Json> = bench
        .reports
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", r.name.as_str().into()),
                ("iters", r.iters.into()),
                ("mean_s", r.mean_s.into()),
                ("p50_s", r.p50_s.into()),
                ("p95_s", r.p95_s.into()),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("suite", "executor".into()),
        ("threads", threads.into()),
        ("sweeps", Json::Arr(entries)),
        ("reports", Json::Arr(reports)),
    ]);
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_executor.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_executor.json"));
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path:?}"),
        Err(e) => eprintln!("\ncould not write {path:?}: {e}"),
    }
}
