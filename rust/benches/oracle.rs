//! Oracle micro-benchmarks: the per-round hot path (batched candidate
//! gains) on native vs XLA backends, plus the core linalg kernels they sit
//! on. These are the numbers the §Perf iteration log in EXPERIMENTS.md
//! tracks.
//!
//! Run: `cargo bench --offline --bench oracle` (DASH_BENCH_FAST=1 for a
//! quick pass).

use dash_select::bench::Bench;
use dash_select::data::synthetic;
use dash_select::linalg::{chol_rank1_update, cholesky, gemm_tn, Matrix};
use dash_select::objectives::{
    AOptimalityObjective, LinearRegressionObjective, Objective,
};
use dash_select::oracle::{XlaAoptObjective, XlaLregObjective};
use dash_select::rng::Pcg64;
use dash_select::runtime::{default_artifacts_dir, Manifest};

fn main() {
    let mut bench = Bench::new("oracle");
    let mut rng = Pcg64::seed_from(1);

    // ---- linalg substrate ----
    let a = random_matrix(&mut rng, 256, 64);
    let b = random_matrix(&mut rng, 256, 256);
    bench.run("gemm_tn 64x256 * 256x256", || gemm_tn(&a, &b));

    let spd = {
        let mut s = dash_select::linalg::syrk(&random_matrix(&mut rng, 128, 128));
        for i in 0..128 {
            s.add_at(i, i, 128.0);
        }
        s
    };
    bench.run("cholesky 128", || cholesky(&spd).unwrap());
    let f = cholesky(&spd).unwrap();
    bench.run("chol_rank1_update 128", || {
        let mut l = f.l.clone();
        let mut x: Vec<f64> = (0..128).map(|i| (i as f64).sin()).collect();
        chol_rank1_update(&mut l, &mut x);
        l
    });

    // ---- native batched gains (the round hot path) ----
    let ds = synthetic::regression_d1(&mut rng, 250, 500, 80, 0.4);
    let lreg = LinearRegressionObjective::new(&ds);
    let cand: Vec<usize> = (0..500).collect();
    for s in [0usize, 16, 48] {
        let set: Vec<usize> = (0..s).collect();
        let st = lreg.state_for(&set);
        bench.run(&format!("lreg native gains n=500 |S|={s}"), || st.gains(&cand));
    }

    let dsd = synthetic::design_d1(&mut rng, 64, 256, 0.6);
    let aopt = AOptimalityObjective::new(&dsd, 1.0, 1.0);
    let candd: Vec<usize> = (0..256).collect();
    let std_ = aopt.state_for(&[1, 5, 9, 100]);
    bench.run("aopt native gains n=256 d=64", || std_.gains(&candd));

    // ---- XLA batched gains (needs artifacts) ----
    let dir = default_artifacts_dir();
    if let Ok(manifest) = Manifest::load(&dir) {
        if let Ok(xla) = XlaLregObjective::new(&ds, &manifest, 48) {
            for s in [0usize, 16, 48] {
                let set: Vec<usize> = (0..s).collect();
                let st = xla.state_for(&set);
                let _ = st.gains(&cand); // warm compile path
                bench.run(&format!("lreg XLA gains n=500 |S|={s}"), || st.gains(&cand));
            }
        }
        if let Ok(xla) = XlaAoptObjective::new(&dsd, &manifest, 1.0, 1.0) {
            let st = xla.state_for(&[1, 5, 9, 100]);
            let _ = st.gains(&candd);
            bench.run("aopt XLA gains n=256 d=64", || st.gains(&candd));
        }
    } else {
        println!("(XLA benches skipped: run `make artifacts`)");
    }

    println!("\n{} benchmarks complete", bench.reports.len());
}

fn random_matrix(rng: &mut Pcg64, r: usize, c: usize) -> Matrix {
    let mut m = Matrix::zeros(r, c);
    for j in 0..c {
        for i in 0..r {
            m.set(i, j, rng.next_gaussian());
        }
    }
    m
}
