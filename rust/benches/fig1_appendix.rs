//! Figure 1 + Appendix A/J regeneration bench: the marginal-contribution
//! sandwich scatter, the A.2 counterexample head-to-head, and the TOP-k
//! worst-case bound audit.

use dash_select::experiments::{appendix, fig1};

fn main() {
    dash_select::util::logging::set_level(dash_select::util::logging::Level::Info);

    // --- Figure 1 ---
    let out = fig1::run_fig1(&fig1::Fig1Config::default());
    println!(
        "fig1: {} scatter points; sampled gamma = {:.4}, alpha = gamma^2 = {:.4}",
        out.scatter.rows.len(),
        out.gamma,
        out.alpha
    );
    println!(
        "Thm. 6 sandwich: sum-singles/set-gain ratio observed in [{:.3}, {:.3}]",
        out.ratio_lo, out.ratio_hi
    );

    // --- Appendix A.2 ---
    for k in [2usize, 4, 8] {
        let r = appendix::run_appendix_a2(k, 7);
        println!(
            "appendix A.2 k={k}: plain adaptive sampling failed={} (value {:.1}/{}), \
             DASH failed={} (value {:.1}, rounds {})",
            r.plain_failed, r.plain_value, r.opt, r.dash_failed, r.dash_value, r.dash_rounds
        );
    }

    // --- Appendix J ---
    let (table, violations) = appendix::run_topk_bound(20, 31);
    println!("\nappendix J (TOP-k >= gamma^2 * OPT) over 20 instances:");
    println!("{}", table.to_pretty());
    println!("violations: {violations}");
}
