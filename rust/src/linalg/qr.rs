//! QR factorizations.
//!
//! [`IncrementalQr`] is the workhorse state of the regression objective: it
//! maintains an orthonormal basis `Q` of the selected feature columns with
//! O(d·|S|) per appended column (modified Gram–Schmidt with one
//! reorthogonalization pass — numerically safe for the condition numbers the
//! datasets here produce). [`qr_thin`] is a one-shot Householder-free thin QR
//! built on the same primitive.

use super::blas::{axpy, dot, nrm2};
use super::Matrix;

/// Incrementally grown thin QR of a column set.
///
/// The basis is stored as one dense column-major `d × rank` [`Matrix`]
/// (appending a column is an O(d) `Vec` extend), so blocked sweep kernels
/// can hand it straight to the level-3 `gemm_tn` path — the `Qᵀ·X_C`
/// product of the regression oracle — without gathering a `Vec<Vec<f64>>`.
#[derive(Debug, Clone)]
pub struct IncrementalQr {
    d: usize,
    /// orthonormal basis, d × rank, grown by `push_col`
    q: Matrix,
    /// threshold below which a column counts as linearly dependent
    dep_tol: f64,
}

impl IncrementalQr {
    pub fn new(d: usize) -> Self {
        IncrementalQr { d, q: Matrix::zeros(d, 0), dep_tol: 1e-10 }
    }

    /// Number of basis vectors (rank of the pushed set).
    pub fn rank(&self) -> usize {
        self.q.cols()
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// The orthonormal basis as a dense `d × rank` matrix.
    pub fn basis(&self) -> &Matrix {
        &self.q
    }

    /// One basis vector (contiguous column slice).
    pub fn basis_col(&self, j: usize) -> &[f64] {
        self.q.col(j)
    }

    /// Orthogonalize `x` against the current basis (in place, two MGS
    /// passes); returns the residual norm.
    pub fn orthogonalize(&self, x: &mut [f64]) -> f64 {
        assert_eq!(x.len(), self.d);
        for _pass in 0..2 {
            for j in 0..self.q.cols() {
                let q = self.q.col(j);
                let c = dot(q, x);
                axpy(-c, q, x);
            }
        }
        nrm2(x)
    }

    /// Append a column to the factorization. Returns `true` if it added a
    /// new basis direction, `false` if (numerically) dependent.
    pub fn push_col(&mut self, x: &[f64]) -> bool {
        let scale = nrm2(x).max(1e-300);
        let mut v = x.to_vec();
        let r = self.orthogonalize(&mut v);
        if r <= self.dep_tol * scale {
            return false;
        }
        let inv = 1.0 / r;
        for vi in &mut v {
            *vi *= inv;
        }
        self.q.push_col(&v);
        true
    }

    /// `‖Qᵀ y‖²` — the squared norm of the projection of `y` onto the span.
    /// For the regression objective this *is* `f(S)` (variance reduction).
    pub fn proj_sq_norm(&self, y: &[f64]) -> f64 {
        (0..self.q.cols())
            .map(|j| {
                let c = dot(self.q.col(j), y);
                c * c
            })
            .sum()
    }

    /// Residual `y − Q Qᵀ y`.
    pub fn residual(&self, y: &[f64]) -> Vec<f64> {
        let mut r = y.to_vec();
        for j in 0..self.q.cols() {
            let q = self.q.col(j);
            let c = dot(q, &r);
            axpy(-c, q, &mut r);
        }
        r
    }

    /// Squared residual component of `x` outside the span:
    /// `‖x‖² − ‖Qᵀx‖²`, clamped at 0.
    pub fn residual_sq(&self, x: &[f64]) -> f64 {
        let total = dot(x, x);
        (total - self.proj_sq_norm(x)).max(0.0)
    }
}

/// One-shot thin QR: returns `(q, r)` with `a = q · r`, `q: d×rank`
/// orthonormal, `r: rank×n` upper trapezoidal. Rank-revealing in the weak
/// sense that dependent columns contribute no q-column (their r column is
/// still filled with projection coefficients).
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let d = a.rows();
    let n = a.cols();
    let mut inc = IncrementalQr::new(d);
    let mut coeffs: Vec<Vec<f64>> = Vec::with_capacity(n); // per column, len rank_at_time+1
    for j in 0..n {
        let x = a.col(j);
        // compute projection coefficients against current basis
        let mut v = x.to_vec();
        let mut cs = Vec::with_capacity(inc.rank() + 1);
        for qi in 0..inc.rank() {
            let q = inc.basis_col(qi);
            let c = dot(q, &v);
            axpy(-c, q, &mut v);
            cs.push(c);
        }
        // second pass for stability, folding corrections into cs
        for qi in 0..inc.rank() {
            let q = inc.basis_col(qi);
            let c = dot(q, &v);
            axpy(-c, q, &mut v);
            cs[qi] += c;
        }
        let r = nrm2(&v);
        let scale = nrm2(x).max(1e-300);
        if r > 1e-10 * scale {
            let inv = 1.0 / r;
            let q_new: Vec<f64> = v.iter().map(|vi| vi * inv).collect();
            inc.q.push_col(&q_new);
            cs.push(r);
        }
        coeffs.push(cs);
    }
    let rank = inc.rank();
    let q = inc.q; // move: `inc` is done growing
    let mut r = Matrix::zeros(rank, n);
    for (j, cs) in coeffs.iter().enumerate() {
        for (i, c) in cs.iter().enumerate() {
            if i < rank {
                r.set(i, j, *c);
            }
        }
    }
    (q, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::gemm;
    use crate::rng::Pcg64;

    fn random(rng: &mut Pcg64, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        for j in 0..c {
            for i in 0..r {
                m.set(i, j, rng.next_gaussian());
            }
        }
        m
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::seed_from(1);
        let a = random(&mut rng, 10, 6);
        let (q, r) = qr_thin(&a);
        assert_eq!(q.cols(), 6);
        let qr = gemm(&q, &r);
        assert!(qr.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg64::seed_from(2);
        let a = random(&mut rng, 15, 7);
        let (q, _) = qr_thin(&a);
        let qtq = crate::linalg::blas::gemm_tn(&q, &q);
        assert!(qtq.max_abs_diff(&Matrix::identity(7)) < 1e-12);
    }

    #[test]
    fn rank_deficient_detected() {
        let mut rng = Pcg64::seed_from(3);
        let mut a = random(&mut rng, 8, 3);
        // add a duplicate column
        let dup: Vec<f64> = a.col(0).to_vec();
        let mut cols: Vec<&[f64]> = (0..3).map(|j| a.col(j)).collect();
        cols.push(&dup);
        let a2 = Matrix::from_cols(8, &cols);
        let (q, r) = qr_thin(&a2);
        assert_eq!(q.cols(), 3); // rank 3
        let qr = gemm(&q, &r);
        assert!(qr.max_abs_diff(&a2) < 1e-10);
        let _ = &mut a;
    }

    #[test]
    fn incremental_matches_batch() {
        let mut rng = Pcg64::seed_from(4);
        let a = random(&mut rng, 12, 5);
        let mut inc = IncrementalQr::new(12);
        for j in 0..5 {
            assert!(inc.push_col(a.col(j)));
        }
        assert_eq!(inc.rank(), 5);
        // projection of a random vector must equal batch-Q projection
        let y: Vec<f64> = (0..12).map(|_| rng.next_gaussian()).collect();
        let (q, _) = qr_thin(&a);
        let mut qty = vec![0.0; q.cols()];
        crate::linalg::blas::gemv_t(&q, &y, &mut qty);
        let batch: f64 = qty.iter().map(|c| c * c).sum();
        assert!((inc.proj_sq_norm(&y) - batch).abs() < 1e-10);
    }

    #[test]
    fn dependent_push_rejected() {
        let mut inc = IncrementalQr::new(3);
        assert!(inc.push_col(&[1.0, 0.0, 0.0]));
        assert!(!inc.push_col(&[2.0, 0.0, 0.0]));
        assert_eq!(inc.rank(), 1);
        assert!(inc.push_col(&[1.0, 1.0, 0.0]));
        assert_eq!(inc.rank(), 2);
    }

    #[test]
    fn residual_orthogonal_to_span() {
        let mut rng = Pcg64::seed_from(5);
        let a = random(&mut rng, 10, 4);
        let mut inc = IncrementalQr::new(10);
        for j in 0..4 {
            inc.push_col(a.col(j));
        }
        let y: Vec<f64> = (0..10).map(|_| rng.next_gaussian()).collect();
        let r = inc.residual(&y);
        for j in 0..4 {
            assert!(dot(&r, a.col(j)).abs() < 1e-10);
        }
        // pythagoras: ||y||² = ||proj||² + ||res||²
        let total = dot(&y, &y);
        let split = inc.proj_sq_norm(&y) + dot(&r, &r);
        assert!((total - split).abs() < 1e-10);
    }

    #[test]
    fn residual_sq_clamps() {
        let mut inc = IncrementalQr::new(2);
        inc.push_col(&[1.0, 0.0]);
        inc.push_col(&[0.0, 1.0]);
        // any vector is fully in span; residual_sq must be ~0, never negative
        let v = inc.residual_sq(&[0.3, -0.7]);
        assert!(v >= 0.0 && v < 1e-12);
    }
}
