//! Triangular, SPD and least-squares solves.

use super::{cholesky, Matrix};

/// Solve `L y = b` with `L` lower triangular (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = b.to_vec();
    for j in 0..n {
        y[j] /= l.get(j, j);
        let yj = y[j];
        let col = l.col(j);
        for i in (j + 1)..n {
            y[i] -= col[i] * yj;
        }
    }
    y
}

/// Solve `Lᵀ x = b` with `L` lower triangular (back substitution on the
/// transpose, reading L's columns contiguously).
pub fn solve_lower_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for j in (0..n).rev() {
        let col = l.col(j);
        let mut s = x[j];
        for i in (j + 1)..n {
            s -= col[i] * x[i];
        }
        x[j] = s / col[j];
    }
    x
}

/// Solve `U x = b` with `U` upper triangular.
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= u.get(i, j) * x[j];
        }
        x[i] = s / u.get(i, i);
    }
    x
}

/// Solve SPD system `A x = b` via Cholesky. Returns `None` if not SPD.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    cholesky(a).map(|f| f.solve(b))
}

/// Least squares `min_w ‖y − A w‖₂` via normal equations with a tiny ridge
/// fallback for rank deficiency. `a: d × n` (d ≥ n typical).
pub fn solve_lstsq(a: &Matrix, y: &[f64]) -> Vec<f64> {
    assert_eq!(y.len(), a.rows());
    let n = a.cols();
    if n == 0 {
        return Vec::new();
    }
    let mut g = super::blas::syrk(a); // AᵀA
    let mut rhs = vec![0.0; n];
    super::blas::gemv_t(a, y, &mut rhs); // Aᵀy
    // try plain, then escalating ridge
    let mut ridge = 0.0;
    for _ in 0..6 {
        let mut g2 = g.clone();
        if ridge > 0.0 {
            for i in 0..n {
                g2.add_at(i, i, ridge);
            }
        }
        if let Some(w) = solve_spd(&g2, &rhs) {
            if w.iter().all(|v| v.is_finite()) {
                return w;
            }
        }
        ridge = if ridge == 0.0 { 1e-10 * (g.trace() / n as f64).max(1.0) } else { ridge * 100.0 };
        // keep g unchanged; ridge added on the copy
        let _ = &mut g;
    }
    vec![0.0; n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::gemv;
    use crate::rng::Pcg64;

    #[test]
    fn lower_solves() {
        let l = Matrix::from_rows(2, 2, &[2.0, 0.0, 1.0, 3.0]);
        let y = solve_lower(&l, &[4.0, 11.0]);
        assert_eq!(y, vec![2.0, 3.0]); // 2*2=4; 1*2+3*3=11
        let x = solve_lower_t(&l, &[7.0, 6.0]); // L^T = [[2,1],[0,3]]
        assert_eq!(x, vec![2.5, 2.0]);
    }

    #[test]
    fn upper_solve() {
        let u = Matrix::from_rows(2, 2, &[2.0, 1.0, 0.0, 4.0]);
        let x = solve_upper(&u, &[5.0, 8.0]);
        assert_eq!(x, vec![1.5, 2.0]);
    }

    #[test]
    fn spd_solve_round_trip() {
        let a = Matrix::from_rows(2, 2, &[4.0, 1.0, 1.0, 3.0]);
        let x = solve_spd(&a, &[1.0, 2.0]).unwrap();
        let mut b = vec![0.0; 2];
        gemv(&a, &x, &mut b);
        assert!((b[0] - 1.0).abs() < 1e-12 && (b[1] - 2.0).abs() < 1e-12);
        assert!(solve_spd(&Matrix::zeros(2, 2), &[1.0, 1.0]).is_none());
    }

    #[test]
    fn lstsq_recovers_exact_solution() {
        let mut rng = Pcg64::seed_from(1);
        let d = 30;
        let n = 5;
        let mut a = Matrix::zeros(d, n);
        for j in 0..n {
            for i in 0..d {
                a.set(i, j, rng.next_gaussian());
            }
        }
        let w_true = [1.0, -2.0, 0.5, 3.0, -0.25];
        let mut y = vec![0.0; d];
        gemv(&a, &w_true, &mut y);
        let w = solve_lstsq(&a, &y);
        for (wi, ti) in w.iter().zip(&w_true) {
            assert!((wi - ti).abs() < 1e-8, "{wi} vs {ti}");
        }
    }

    #[test]
    fn lstsq_rank_deficient_does_not_blow_up() {
        // duplicate column -> singular normal equations; ridge fallback
        let a = Matrix::from_cols(3, &[&[1.0, 0.0, 0.0], &[1.0, 0.0, 0.0]]);
        let w = solve_lstsq(&a, &[2.0, 0.0, 0.0]);
        assert!(w.iter().all(|v| v.is_finite()));
        // fitted value should reproduce y on the span
        let fit = w[0] + w[1];
        assert!((fit - 2.0).abs() < 1e-3);
    }

    #[test]
    fn lstsq_empty() {
        let a = Matrix::zeros(3, 0);
        assert!(solve_lstsq(&a, &[1.0, 2.0, 3.0]).is_empty());
    }
}
