//! Symmetric eigensolver (cyclic Jacobi) and extreme-eigenvalue helpers.
//!
//! Used to estimate the paper's spectral quantities: λmin/λmax of k-sparse
//! feature covariance matrices (Cor. 7), ‖X‖² for the A-optimality γ
//! (Cor. 9), and the differential-submodularity ratio α = γ² reported in
//! experiment metadata.

use super::Matrix;

/// Eigendecomposition of a symmetric matrix via cyclic Jacobi rotations.
/// Returns `(eigenvalues_ascending, eigenvectors)` with eigenvector `i`
/// in column `i`.
pub fn jacobi_eigh(a: &Matrix) -> (Vec<f64>, Matrix) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh of non-square");
    let mut m = a.clone();
    let mut v = Matrix::identity(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let mut eig: Vec<(f64, usize)> = (0..n).map(|i| (m.get(i, i), i)).collect();
    eig.sort_by(|a, b| a.0.total_cmp(&b.0));
    let vals: Vec<f64> = eig.iter().map(|e| e.0).collect();
    let mut vecs = Matrix::zeros(n, n);
    for (new_j, (_, old_j)) in eig.iter().enumerate() {
        vecs.col_mut(new_j).copy_from_slice(v.col(*old_j));
    }
    (vals, vecs)
}

/// (λmin, λmax) of a symmetric matrix. Uses Jacobi for small `n`; power /
/// inverse-free Rayleigh bounds would be overkill here — covariance blocks
/// in the experiments stay ≤ a few hundred.
pub fn sym_extreme_eigs(a: &Matrix) -> (f64, f64) {
    let (vals, _) = jacobi_eigh(a);
    // vals is empty only for a 0×0 matrix; (0, 0) is the sensible answer
    match (vals.first(), vals.last()) {
        (Some(&lo), Some(&hi)) => (lo, hi),
        _ => (0.0, 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm, gemm_tn};
    use crate::rng::Pcg64;

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_rows(3, 3, &[3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let (vals, _) = jacobi_eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigs 1, 3
        let a = Matrix::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let (vals, vecs) = jacobi_eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
        // eigenvector check: A v = λ v
        for j in 0..2 {
            let v: Vec<f64> = vecs.col(j).to_vec();
            let mut av = vec![0.0; 2];
            crate::linalg::blas::gemv(&a, &v, &mut av);
            for i in 0..2 {
                assert!((av[i] - vals[j] * v[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn reconstruction_random_spd() {
        let mut rng = Pcg64::seed_from(1);
        let n = 12;
        let mut b = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                b.set(i, j, rng.next_gaussian());
            }
        }
        let a = crate::linalg::blas::syrk(&b);
        let (vals, vecs) = jacobi_eigh(&a);
        // A = V diag(vals) V^T
        let mut vd = vecs.clone();
        for j in 0..n {
            crate::linalg::blas::scal(vals[j], vd.col_mut(j));
        }
        let recon = gemm(&vd, &vecs.transpose());
        assert!(recon.max_abs_diff(&a) < 1e-8);
        // eigenvalues of A = BᵀB are ≥ 0
        assert!(vals[0] > -1e-10);
        // orthonormal eigenvectors
        let vtv = gemm_tn(&vecs, &vecs);
        assert!(vtv.max_abs_diff(&Matrix::identity(n)) < 1e-10);
    }

    #[test]
    fn extreme_eigs() {
        let a = Matrix::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let (lo, hi) = sym_extreme_eigs(&a);
        assert!((lo - 1.0).abs() < 1e-12);
        assert!((hi - 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Pcg64::seed_from(2);
        let n = 8;
        let mut b = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                b.set(i, j, rng.next_gaussian());
            }
        }
        let a = crate::linalg::blas::syrk(&b);
        let (vals, _) = jacobi_eigh(&a);
        assert!((vals.iter().sum::<f64>() - a.trace()).abs() < 1e-8);
    }
}
