//! Dense linear algebra substrate (no external BLAS/LAPACK available):
//! column-major [`Matrix`], blocked GEMM/GEMV with runtime-dispatched
//! SIMD inner kernels ([`simd`]), Cholesky with rank-1 updates,
//! Householder QR with incremental column appends, triangular solves,
//! and a Jacobi symmetric eigensolver.
//!
//! Feature matrices are stored **column-major** (`d × n`, one contiguous
//! slice per feature column) because every objective in the paper sweeps
//! candidate *columns*.

mod matrix;
mod blas;
mod cholesky;
mod qr;
mod solve;
mod eigen;
pub mod simd;

pub use matrix::Matrix;
pub use blas::{
    axpy, dot, dot2, gemm, gemm_into, gemm_tn, gemm_tn_into, gemv, gemv_t, nrm2, pack_f32, scal,
    syrk,
};
pub use cholesky::{cholesky, cholesky_in_place, chol_rank1_update, CholeskyFactor};
pub use qr::{qr_thin, IncrementalQr};
pub use solve::{solve_lower, solve_upper, solve_lower_t, solve_spd, solve_lstsq};
pub use eigen::{jacobi_eigh, sym_extreme_eigs};
