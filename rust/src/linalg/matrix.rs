//! Column-major dense matrix.

use std::fmt;

/// Dense `rows × cols` matrix of f64, column-major: element `(i, j)` lives
/// at `data[j * rows + i]`; column `j` is the contiguous slice
/// `data[j*rows .. (j+1)*rows]`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a column-major data vec.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from row-major data (e.g. literals in tests).
    pub fn from_rows(rows: usize, cols: usize, row_major: &[f64]) -> Self {
        assert_eq!(row_major.len(), rows * cols, "data length mismatch");
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.set(i, j, row_major[i * cols + j]);
            }
        }
        m
    }

    /// Build from a list of columns (each of length `rows`).
    pub fn from_cols(rows: usize, cols: &[&[f64]]) -> Self {
        let mut m = Self::zeros(rows, cols.len());
        for (j, c) in cols.iter().enumerate() {
            assert_eq!(c.len(), rows);
            m.col_mut(j).copy_from_slice(c);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] += v;
    }

    /// Contiguous column slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Copy of row `i` (non-contiguous).
    pub fn row(&self, i: usize) -> Vec<f64> {
        (0..self.cols).map(|j| self.get(i, j)).collect()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Reshape to `rows × cols` in place, reusing the allocation (the
    /// sweep-scratch arenas resize every block). Prior contents are
    /// unspecified afterwards; callers must overwrite whatever they read.
    pub fn resize_uninit(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Append a column (column-major ⇒ amortized O(rows)). Grows `cols`
    /// by 1; the incremental QR basis is built this way.
    pub fn push_col(&mut self, col: &[f64]) {
        assert_eq!(col.len(), self.rows, "column length mismatch");
        self.data.extend_from_slice(col);
        self.cols += 1;
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            let c = self.col(j);
            for i in 0..self.rows {
                t.set(j, i, c[i]);
            }
        }
        t
    }

    /// Submatrix of the given columns (in the given order).
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(self.rows, idx.len());
        for (jj, &j) in idx.iter().enumerate() {
            m.col_mut(jj).copy_from_slice(self.col(j));
        }
        m
    }

    /// Submatrix of the given rows (in the given order).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(idx.len(), self.cols);
        for j in 0..self.cols {
            let c = self.col(j);
            for (ii, &i) in idx.iter().enumerate() {
                m.set(ii, j, c[i]);
            }
        }
        m
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Trace (square matrices).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols, "trace of non-square");
        (0..self.rows).map(|i| self.get(i, i)).sum()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// `self += alpha * other`.
    pub fn axpy_mat(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale all entries.
    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Check symmetry to tolerance.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self.get(i, j) - self.get(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(8);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self.get(i, j))?;
            }
            if show_c < self.cols {
                write!(f, "…")?;
            }
            writeln!(f)?;
        }
        if show_r < self.rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.col(1), &[2.0, 5.0]);
        assert_eq!(m.row(0), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn col_major_layout() {
        let m = Matrix::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.data(), &[1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn select_cols_rows() {
        let m = Matrix::from_rows(3, 3, &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let c = m.select_cols(&[2, 0]);
        assert_eq!(c.col(0), &[3.0, 6.0, 9.0]);
        assert_eq!(c.col(1), &[1.0, 4.0, 7.0]);
        let r = m.select_rows(&[1]);
        assert_eq!(r.row(0), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn identity_trace_fro() {
        let i3 = Matrix::identity(3);
        assert_eq!(i3.trace(), 3.0);
        assert!((i3.fro_norm() - 3f64.sqrt()).abs() < 1e-15);
        assert!(i3.is_symmetric(0.0));
    }

    #[test]
    fn axpy_scale_diff() {
        let mut a = Matrix::identity(2);
        let b = Matrix::identity(2);
        a.axpy_mat(2.0, &b);
        assert_eq!(a.get(0, 0), 3.0);
        a.scale(0.5);
        assert_eq!(a.get(1, 1), 1.5);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn from_cols_builder() {
        let m = Matrix::from_cols(2, &[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.cols(), 2);
    }

    #[test]
    #[should_panic]
    fn bad_data_length_panics() {
        let _ = Matrix::from_col_major(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn resize_uninit_reuses_and_reshapes() {
        let mut m = Matrix::zeros(3, 2);
        m.set(2, 1, 9.0);
        m.resize_uninit(2, 4);
        assert_eq!((m.rows(), m.cols()), (2, 4));
        assert_eq!(m.data().len(), 8);
        m.col_mut(3).copy_from_slice(&[1.0, 2.0]);
        assert_eq!(m.col(3), &[1.0, 2.0]);
        m.resize_uninit(1, 1);
        assert_eq!(m.data().len(), 1);
    }

    #[test]
    fn push_col_grows() {
        let mut m = Matrix::zeros(2, 0);
        m.push_col(&[1.0, 2.0]);
        m.push_col(&[3.0, 4.0]);
        assert_eq!((m.rows(), m.cols()), (2, 2));
        assert_eq!(m.col(0), &[1.0, 2.0]);
        assert_eq!(m.col(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "column length mismatch")]
    fn push_col_wrong_length_panics() {
        let mut m = Matrix::zeros(2, 0);
        m.push_col(&[1.0]);
    }
}
