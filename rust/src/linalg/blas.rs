//! BLAS-like kernels: dot/axpy/norm (level 1), gemv (level 2), blocked
//! gemm/syrk (level 3). The inner loops dispatch through the runtime-
//! selected SIMD table in [`super::simd`] (AVX2+FMA / SSE2 / scalar,
//! chosen once per process; `DASH_FORCE_SCALAR=1` pins scalar). The
//! blocking structure — 4-column gemm panels, 4×4 gemm_tn tiles, KB-sized
//! k-blocks — lives here; the per-block arithmetic lives in the table.

use super::simd;
use super::Matrix;

/// `xᵀy`; eight independent accumulators reduced by a fixed sum tree.
/// Every SIMD level preserves that accumulation layout exactly, so the
/// result is bit-identical regardless of dispatch (see [`super::simd`]).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    (simd::kernels().dot)(x, y)
}

/// `(xᵀy, yᵀy)` in one pass — the fused tail reduction of the aopt sweep
/// (`x = X_C` column, `y = M·x`). Each component is bit-identical to the
/// corresponding [`dot`] at every SIMD level.
#[inline]
pub fn dot2(x: &[f64], y: &[f64]) -> (f64, f64) {
    debug_assert_eq!(x.len(), y.len());
    (simd::kernels().dot2)(x, y)
}

/// `y += alpha * x`. Elementwise mul+add at every SIMD level —
/// bit-identical regardless of dispatch.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    (simd::kernels().axpy)(alpha, x, y)
}

/// Narrow `src` into `dst` (`as f32` semantics, round-to-nearest) — the
/// f64→f32 padding step of the XLA executor. Bit-identical at every SIMD
/// level.
#[inline]
pub fn pack_f32(src: &[f64], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    (simd::kernels().pack_f32)(src, dst)
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `y = A x` for column-major `A` (`rows × cols`), accumulating per column
/// (axpy formulation keeps memory access contiguous).
pub fn gemv(a: &Matrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    y.fill(0.0);
    for j in 0..a.cols() {
        let xj = x[j];
        if xj != 0.0 {
            axpy(xj, a.col(j), y);
        }
    }
}

/// `y = Aᵀ x` (each output element is a contiguous column dot).
pub fn gemv_t(a: &Matrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.rows());
    assert_eq!(y.len(), a.cols());
    for j in 0..a.cols() {
        y[j] = dot(a.col(j), x);
    }
}

/// `C = A · B` (allocating wrapper over [`gemm_into`]).
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c);
    c
}

/// `C = A · B` written into a caller-owned `c` (zeroed first — scratch
/// arenas hand in reused, stale buffers).
///
/// Register-tiled micro-kernel: B/C are processed in panels of 4 columns
/// with 4 unrolled accumulator columns, so each streamed column of A is
/// loaded once per *four* outputs instead of once per output — the memory
/// traffic that dominates `M·X_C`-shaped products (d×d posterior times a
/// candidate block) drops ~4×. K is additionally blocked for cache reuse.
/// The per-block arithmetic dispatches through [`super::simd`]; remainder
/// columns run the 1-column kernel with the identical per-element op
/// sequence as the panels (zero weights multiply through — no skip), so
/// panel and remainder columns agree bit-for-bit within one dispatch
/// level.
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dim");
    assert_eq!(c.rows(), a.rows(), "gemm output rows");
    assert_eq!(c.cols(), b.cols(), "gemm output cols");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    c.data_mut().fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    const KB: usize = 64;
    let ks = simd::kernels();
    let adata = a.data();
    let cdata = c.data_mut();
    let mut j = 0;
    // 4-column panels: one pass over A updates four accumulating C columns
    while j + 4 <= n {
        let panel = &mut cdata[j * m..(j + 4) * m];
        let (c0, rest) = panel.split_at_mut(m);
        let (c1, rest) = rest.split_at_mut(m);
        let (c2, c3) = rest.split_at_mut(m);
        let (b0, b1, b2, b3) = (b.col(j), b.col(j + 1), b.col(j + 2), b.col(j + 3));
        let mut p = 0;
        while p < k {
            let pe = (p + KB).min(k);
            // columns p..pe of column-major A are one contiguous slab
            (ks.gemm_panel4)(
                &adata[p * m..pe * m],
                m,
                [&b0[p..pe], &b1[p..pe], &b2[p..pe], &b3[p..pe]],
                [&mut c0[..], &mut c1[..], &mut c2[..], &mut c3[..]],
            );
            p = pe;
        }
        j += 4;
    }
    // remainder columns: same kernel structure, one accumulator column
    while j < n {
        let bcol = b.col(j);
        let ccol = &mut cdata[j * m..(j + 1) * m];
        let mut p = 0;
        while p < k {
            let pe = (p + KB).min(k);
            (ks.gemm_col1)(&adata[p * m..pe * m], m, &bcol[p..pe], &mut ccol[..]);
            p = pe;
        }
        j += 1;
    }
}

/// `C = Aᵀ · B` (allocating wrapper over [`gemm_tn_into`]).
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), b.cols());
    gemm_tn_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` (`a: m×p`, `b: m×q` → `p×q`) written into a caller-owned
/// `c` (fully overwritten).
///
/// 4×4 register tiles: sixteen accumulators share each streamed row chunk,
/// so every A and B column is loaded once per four outputs instead of once
/// per output — the `Qᵀ·X_C` product of the regression sweep kernel is
/// exactly this tall-skinny shape. Remainder rows/columns fall back to
/// contiguous column dots.
pub fn gemm_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows(), b.rows(), "gemm_tn inner dim");
    assert_eq!(c.rows(), a.cols(), "gemm_tn output rows");
    assert_eq!(c.cols(), b.cols(), "gemm_tn output cols");
    let (_m, p, q) = (a.rows(), a.cols(), b.cols());
    let ks = simd::kernels();
    let mut i = 0;
    while i + 4 <= p {
        let (a0, a1, a2, a3) = (a.col(i), a.col(i + 1), a.col(i + 2), a.col(i + 3));
        let mut j = 0;
        while j + 4 <= q {
            let acc = (ks.tn_tile4)(
                [a0, a1, a2, a3],
                [b.col(j), b.col(j + 1), b.col(j + 2), b.col(j + 3)],
            );
            for (ci, row) in acc.iter().enumerate() {
                for (cj, &v) in row.iter().enumerate() {
                    c.set(i + ci, j + cj, v);
                }
            }
            j += 4;
        }
        while j < q {
            let bj = b.col(j);
            c.set(i, j, dot(a0, bj));
            c.set(i + 1, j, dot(a1, bj));
            c.set(i + 2, j, dot(a2, bj));
            c.set(i + 3, j, dot(a3, bj));
            j += 1;
        }
        i += 4;
    }
    while i < p {
        let ai = a.col(i);
        for j in 0..q {
            c.set(i, j, dot(ai, b.col(j)));
        }
        i += 1;
    }
}

/// Symmetric rank-k: `C = Aᵀ A` (`a: m×n` → `n×n`), computing only the upper
/// triangle and mirroring.
pub fn syrk(a: &Matrix) -> Matrix {
    let n = a.cols();
    let mut c = Matrix::zeros(n, n);
    for j in 0..n {
        let aj = a.col(j);
        for i in 0..=j {
            let v = dot(a.col(i), aj);
            c.set(i, j, v);
            c.set(j, i, v);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..13).map(|i| i as f64).collect();
        let y: Vec<f64> = (0..13).map(|i| (i * 2) as f64).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-12);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_scal_nrm2() {
        let mut y = vec![1.0, 2.0];
        axpy(3.0, &[1.0, 1.0], &mut y);
        assert_eq!(y, vec![4.0, 5.0]);
        scal(2.0, &mut y);
        assert_eq!(y, vec![8.0, 10.0]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn gemv_both_orientations() {
        let a = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 2];
        gemv(&a, &[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
        let mut z = vec![0.0; 3];
        gemv_t(&a, &[1.0, 1.0], &mut z);
        assert_eq!(z, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn gemm_small_known() {
        let a = Matrix::from_rows(2, 2, &[1., 2., 3., 4.]);
        let b = Matrix::from_rows(2, 2, &[5., 6., 7., 8.]);
        let c = gemm(&a, &b);
        assert_eq!(c, Matrix::from_rows(2, 2, &[19., 22., 43., 50.]));
    }

    #[test]
    fn gemm_identity() {
        let a = Matrix::from_rows(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let c = gemm(&Matrix::identity(3), &a);
        assert!(c.max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let mut rng = crate::rng::Pcg64::seed_from(1);
        let a = random(&mut rng, 7, 4);
        let b = random(&mut rng, 7, 5);
        let c1 = gemm_tn(&a, &b);
        let c2 = gemm(&a.transpose(), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = crate::rng::Pcg64::seed_from(2);
        let a = random(&mut rng, 6, 4);
        let c1 = syrk(&a);
        let c2 = gemm(&a.transpose(), &a);
        assert!(c1.max_abs_diff(&c2) < 1e-12);
        assert!(c1.is_symmetric(1e-14));
    }

    #[test]
    fn gemm_blocked_matches_naive_larger() {
        let mut rng = crate::rng::Pcg64::seed_from(3);
        // k > KB exercises the blocking loop
        let a = random(&mut rng, 9, 130);
        let b = random(&mut rng, 130, 8);
        let c = gemm(&a, &b);
        // naive reference
        let mut r = Matrix::zeros(9, 8);
        for i in 0..9 {
            for j in 0..8 {
                let mut s = 0.0;
                for l in 0..130 {
                    s += a.get(i, l) * b.get(l, j);
                }
                r.set(i, j, s);
            }
        }
        assert!(c.max_abs_diff(&r) < 1e-10);
    }

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut r = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for l in 0..a.cols() {
                    s += a.get(i, l) * b.get(l, j);
                }
                r.set(i, j, s);
            }
        }
        r
    }

    #[test]
    fn tiled_paths_match_naive_all_remainder_shapes() {
        let mut rng = crate::rng::Pcg64::seed_from(7);
        // exercise full tiles plus every remainder combination
        for (m, k, n) in [(5, 9, 11), (8, 12, 8), (3, 3, 3), (16, 70, 13), (1, 1, 1)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            assert!(gemm(&a, &b).max_abs_diff(&naive_gemm(&a, &b)) < 1e-10, "gemm {m}x{k}x{n}");
            let at = random(&mut rng, k, m);
            let bt = random(&mut rng, k, n);
            let tn = gemm_tn(&at, &bt);
            assert!(
                tn.max_abs_diff(&naive_gemm(&at.transpose(), &bt)) < 1e-10,
                "gemm_tn {k}x{m}x{n}"
            );
        }
    }

    #[test]
    fn into_variants_overwrite_stale_buffers() {
        let mut rng = crate::rng::Pcg64::seed_from(8);
        let a = random(&mut rng, 6, 7);
        let b = random(&mut rng, 7, 9);
        let mut c = Matrix::zeros(6, 9);
        for cell in c.data_mut() {
            *cell = 123.0; // stale scratch contents must not leak
        }
        gemm_into(&a, &b, &mut c);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-10);
        let mut t = Matrix::zeros(6, 9);
        for cell in t.data_mut() {
            *cell = -55.0;
        }
        gemm_tn_into(&a.transpose(), &b, &mut t);
        assert!(t.max_abs_diff(&naive_gemm(&a, &b)) < 1e-10);
    }

    #[test]
    fn gemm_into_zero_dims() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 2);
        let mut c = Matrix::zeros(0, 2);
        gemm_into(&a, &b, &mut c); // must not panic
        let a2 = Matrix::zeros(2, 0);
        let b2 = Matrix::zeros(0, 2);
        let mut c2 = Matrix::zeros(2, 2);
        c2.set(0, 0, 4.0);
        gemm_into(&a2, &b2, &mut c2);
        assert_eq!(c2.get(0, 0), 0.0, "k=0 product is the zero matrix");
    }

    #[test]
    fn dot2_components_bit_identical_to_dot() {
        let mut rng = crate::rng::Pcg64::seed_from(21);
        for n in [0usize, 1, 5, 8, 13, 64, 100] {
            let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let (xy, yy) = dot2(&x, &y);
            assert_eq!(xy.to_bits(), dot(&x, &y).to_bits(), "n={n}");
            assert_eq!(yy.to_bits(), dot(&y, &y).to_bits(), "n={n}");
        }
    }

    #[test]
    fn pack_f32_matches_as_cast() {
        let mut rng = crate::rng::Pcg64::seed_from(22);
        for n in [0usize, 1, 3, 4, 7, 64, 101] {
            let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian() * 1e3).collect();
            let mut out = vec![0.0f32; n];
            pack_f32(&x, &mut out);
            for i in 0..n {
                assert_eq!(out[i].to_bits(), (x[i] as f32).to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn remainder_column_bitwise_matches_panel_column_with_zero_weights() {
        // b has 5 columns: 0..4 go through the 4-column panel kernel,
        // column 4 through the remainder kernel. Column 4 duplicates
        // column 0 — with exact zeros sprinkled in — so the remainder
        // path must reproduce the panel path bit-for-bit (ISSUE 8
        // satellite 1: the old remainder path skipped zero weights and
        // diverged from the panel flop pattern).
        let mut rng = crate::rng::Pcg64::seed_from(23);
        for (m, k) in [(7, 9), (16, 70), (5, 64), (1, 1)] {
            let a = random(&mut rng, m, k);
            let mut b = Matrix::zeros(k, 5);
            for j in 0..4 {
                for l in 0..k {
                    let w = if (l + j) % 3 == 0 { 0.0 } else { rng.next_gaussian() };
                    b.set(l, j, w);
                }
            }
            for l in 0..k {
                let v = b.get(l, 0);
                b.set(l, 4, v);
            }
            let c = gemm(&a, &b);
            for i in 0..m {
                assert_eq!(
                    c.get(i, 4).to_bits(),
                    c.get(i, 0).to_bits(),
                    "m={m} k={k} row {i}: remainder column diverged from panel"
                );
            }
        }
    }

    fn random(rng: &mut crate::rng::Pcg64, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        for j in 0..c {
            for i in 0..r {
                m.set(i, j, rng.next_gaussian());
            }
        }
        m
    }
}
