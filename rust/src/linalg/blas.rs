//! BLAS-like kernels: dot/axpy/norm (level 1), gemv (level 2), blocked
//! gemm/syrk (level 3). Plain safe Rust, written so the autovectorizer can
//! do its job (contiguous column access, 4-way unrolled dot).

use super::Matrix;

/// `xᵀy`; 8-way unrolled over slice chunks so the autovectorizer emits
/// wide FMA sequences without bounds checks (perf iteration 3, see
/// EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let rx = xc.remainder();
    let ry = yc.remainder();
    for (a, b) in xc.zip(yc) {
        for l in 0..8 {
            acc[l] += a[l] * b[l];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (a, b) in rx.iter().zip(ry) {
        s += a * b;
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `y = A x` for column-major `A` (`rows × cols`), accumulating per column
/// (axpy formulation keeps memory access contiguous).
pub fn gemv(a: &Matrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    y.fill(0.0);
    for j in 0..a.cols() {
        let xj = x[j];
        if xj != 0.0 {
            axpy(xj, a.col(j), y);
        }
    }
}

/// `y = Aᵀ x` (each output element is a contiguous column dot).
pub fn gemv_t(a: &Matrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.rows());
    assert_eq!(y.len(), a.cols());
    for j in 0..a.cols() {
        y[j] = dot(a.col(j), x);
    }
}

/// `C = A · B`, blocked over K for cache reuse. Column-major everywhere:
/// for each column of B we accumulate a linear combination of A's columns.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "gemm inner dim");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    // process B in column panels; accumulate axpy over A's columns
    const KB: usize = 64;
    for j in 0..n {
        let bcol = b.col(j);
        let ccol = c.col_mut(j);
        let mut p = 0;
        while p < k {
            let pe = (p + KB).min(k);
            for l in p..pe {
                let w = bcol[l];
                if w != 0.0 {
                    axpy(w, a.col(l), ccol);
                }
            }
            p = pe;
        }
    }
    c
}

/// `C = Aᵀ · B` (`a: m×p`, `b: m×q` → `p×q`); every entry is a contiguous
/// column-column dot, which is the fastest pattern for tall-skinny factors.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "gemm_tn inner dim");
    let (p, q) = (a.cols(), b.cols());
    let mut c = Matrix::zeros(p, q);
    for j in 0..q {
        let bj = b.col(j);
        let cj = c.col_mut(j);
        for i in 0..p {
            cj[i] = dot(a.col(i), bj);
        }
    }
    c
}

/// Symmetric rank-k: `C = Aᵀ A` (`a: m×n` → `n×n`), computing only the upper
/// triangle and mirroring.
pub fn syrk(a: &Matrix) -> Matrix {
    let n = a.cols();
    let mut c = Matrix::zeros(n, n);
    for j in 0..n {
        let aj = a.col(j);
        for i in 0..=j {
            let v = dot(a.col(i), aj);
            c.set(i, j, v);
            c.set(j, i, v);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..13).map(|i| i as f64).collect();
        let y: Vec<f64> = (0..13).map(|i| (i * 2) as f64).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-12);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_scal_nrm2() {
        let mut y = vec![1.0, 2.0];
        axpy(3.0, &[1.0, 1.0], &mut y);
        assert_eq!(y, vec![4.0, 5.0]);
        scal(2.0, &mut y);
        assert_eq!(y, vec![8.0, 10.0]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn gemv_both_orientations() {
        let a = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 2];
        gemv(&a, &[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
        let mut z = vec![0.0; 3];
        gemv_t(&a, &[1.0, 1.0], &mut z);
        assert_eq!(z, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn gemm_small_known() {
        let a = Matrix::from_rows(2, 2, &[1., 2., 3., 4.]);
        let b = Matrix::from_rows(2, 2, &[5., 6., 7., 8.]);
        let c = gemm(&a, &b);
        assert_eq!(c, Matrix::from_rows(2, 2, &[19., 22., 43., 50.]));
    }

    #[test]
    fn gemm_identity() {
        let a = Matrix::from_rows(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let c = gemm(&Matrix::identity(3), &a);
        assert!(c.max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let mut rng = crate::rng::Pcg64::seed_from(1);
        let a = random(&mut rng, 7, 4);
        let b = random(&mut rng, 7, 5);
        let c1 = gemm_tn(&a, &b);
        let c2 = gemm(&a.transpose(), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = crate::rng::Pcg64::seed_from(2);
        let a = random(&mut rng, 6, 4);
        let c1 = syrk(&a);
        let c2 = gemm(&a.transpose(), &a);
        assert!(c1.max_abs_diff(&c2) < 1e-12);
        assert!(c1.is_symmetric(1e-14));
    }

    #[test]
    fn gemm_blocked_matches_naive_larger() {
        let mut rng = crate::rng::Pcg64::seed_from(3);
        // k > KB exercises the blocking loop
        let a = random(&mut rng, 9, 130);
        let b = random(&mut rng, 130, 8);
        let c = gemm(&a, &b);
        // naive reference
        let mut r = Matrix::zeros(9, 8);
        for i in 0..9 {
            for j in 0..8 {
                let mut s = 0.0;
                for l in 0..130 {
                    s += a.get(i, l) * b.get(l, j);
                }
                r.set(i, j, s);
            }
        }
        assert!(c.max_abs_diff(&r) < 1e-10);
    }

    fn random(rng: &mut crate::rng::Pcg64, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        for j in 0..c {
            for i in 0..r {
                m.set(i, j, rng.next_gaussian());
            }
        }
        m
    }
}
