//! Runtime-dispatched SIMD micro-kernels for the sweep hot path.
//!
//! One [`Kernels`] table per instruction-set level (scalar, SSE2, AVX2+FMA)
//! holds function pointers for the level-1 primitives (`dot`, `dot2`,
//! `axpy`, `pack_f32`) and the level-3 inner kernels consumed by
//! [`gemm_into`](super::gemm_into) (4-column panels + 1-column remainder)
//! and [`gemm_tn_into`](super::gemm_tn_into) (4×4 tiles). The active table
//! is chosen **once per process**:
//!
//! - `DASH_FORCE_SCALAR=1` in the environment pins the scalar table
//!   (read at first use, cached for the process lifetime);
//! - otherwise `is_x86_feature_detected!` picks AVX2+FMA when both are
//!   present, falling back to SSE2 (the x86_64 baseline), falling back to
//!   scalar on non-x86_64 targets.
//!
//! Benches and the dedicated SIMD test binary may additionally force a
//! level in-process via [`set_override`]; because dispatch is a single
//! process-wide constant during normal operation, the engine's
//! shard-count bit-identity contract (`tests/sweep_kernels.rs`) is
//! unaffected by which level runs.
//!
//! # Determinism contract
//!
//! Two tiers, pinned by tests in this file and in `tests/simd_kernels.rs`:
//!
//! - **Bit-identical across levels**: `dot`, `dot2`, `axpy`, and
//!   `pack_f32` preserve the scalar accumulation layout exactly. The
//!   vector `dot` keeps the scalar kernel's eight independent
//!   accumulators (two 4-lane registers on AVX2, four 2-lane registers on
//!   SSE2), uses separate multiply and add (never FMA), and reduces with
//!   the same `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))` tree, so every
//!   level returns the same bits. `axpy` and `pack_f32` are elementwise.
//! - **Tolerance across levels**: the gemm panel/tile kernels use FMA on
//!   AVX2, which changes rounding versus scalar (tighter, one rounding
//!   per multiply-add). Agreement with the scalar path is ≤1e-9 per the
//!   sweep-kernel contract. *Within* one level, the 4-column panel and
//!   the 1-column remainder kernel perform the identical per-element
//!   operation sequence (ascending `l`, same op kind), so panel and
//!   remainder columns agree bit-for-bit — including for zero weights,
//!   which multiply through instead of being skipped.
//!
//! # Safety
//!
//! This module contains the crate's only `unsafe` SIMD code and is built
//! with `deny(unsafe_op_in_unsafe_fn)`: every unsafe operation sits in an
//! explicit `unsafe` block with a SAFETY comment. The contract common to
//! all kernels:
//!
//! - raw pointer reads/writes are guarded by loop bounds checked against
//!   the slice lengths taken *from the safe references* (`i + LANES <= n`
//!   before touching lanes `i..i+LANES`);
//! - `#[target_feature]` functions are reachable only through their
//!   `*_entry` wrappers, which are stored exclusively in the table for
//!   that level, and a table is only selectable when the feature check
//!   for its level has passed (SSE2 is unconditionally part of the
//!   x86_64 baseline).

#![deny(unsafe_op_in_unsafe_fn)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Instruction-set level of a kernel table.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdLevel {
    /// Portable scalar Rust (the autovectorizer may still use SIMD).
    Scalar,
    /// 128-bit SSE2 (x86_64 baseline), mul+add only — bit-identical to
    /// scalar for every kernel.
    Sse2,
    /// 256-bit AVX2 with FMA in the gemm kernels.
    Avx2,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2+fma",
        }
    }
}

/// Dispatch table: one entry per kernel the sweep path consumes.
///
/// `gemm_panel4(ablock, m, w, c)` accumulates `c[t] += A_block · w[t]` for
/// four output columns at once, where `ablock` is the contiguous
/// column-major slab of `kk = w[0].len()` A-columns of height `m` and each
/// `c[t]` has length `m`. `gemm_col1` is the single-column remainder with
/// the identical per-element operation sequence. `tn_tile4(a, b)` returns
/// the 4×4 tile of dot products `a[i]ᵀ b[j]`. `dot2(x, y)` returns
/// `(x·y, y·y)` with each component bit-identical to `dot`. `pack_f32`
/// narrows f64 → f32 with round-to-nearest (identical to `as f32`).
pub struct Kernels {
    pub level: SimdLevel,
    pub dot: fn(&[f64], &[f64]) -> f64,
    pub dot2: fn(&[f64], &[f64]) -> (f64, f64),
    pub axpy: fn(f64, &[f64], &mut [f64]),
    pub gemm_panel4: fn(&[f64], usize, [&[f64]; 4], [&mut [f64]; 4]),
    pub gemm_col1: fn(&[f64], usize, &[f64], &mut [f64]),
    pub tn_tile4: fn([&[f64]; 4], [&[f64]; 4]) -> [[f64; 4]; 4],
    pub pack_f32: fn(&[f64], &mut [f32]),
}

// ---------------------------------------------------------------------------
// dispatch

/// 0 = auto (detected once), 1 = scalar, 2 = sse2, 3 = avx2.
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);
static AUTO: OnceLock<&'static Kernels> = OnceLock::new();

fn force_scalar_env() -> bool {
    std::env::var("DASH_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false)
}

#[cfg(target_arch = "x86_64")]
fn best_table() -> &'static Kernels {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        &AVX2_KERNELS
    } else {
        &SSE2_KERNELS
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn best_table() -> &'static Kernels {
    &SCALAR_KERNELS
}

fn detect() -> &'static Kernels {
    if force_scalar_env() {
        return &SCALAR_KERNELS;
    }
    best_table()
}

/// The active kernel table. Reads one atomic (the test/bench override)
/// and the once-cached detection result; callers may hold the reference
/// for the duration of an operation.
#[inline]
pub fn kernels() -> &'static Kernels {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => &SCALAR_KERNELS,
        #[cfg(target_arch = "x86_64")]
        2 => &SSE2_KERNELS,
        #[cfg(target_arch = "x86_64")]
        3 => &AVX2_KERNELS,
        _ => AUTO.get_or_init(detect),
    }
}

/// Whether `level`'s table can run on this host.
pub fn is_available(level: SimdLevel) -> bool {
    match level {
        SimdLevel::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => true,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => false,
    }
}

/// Every level runnable on this host, scalar first.
pub fn available_levels() -> Vec<SimdLevel> {
    [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2]
        .into_iter()
        .filter(|&l| is_available(l))
        .collect()
}

/// The table for `level`, if the host supports it (for direct
/// level-vs-level comparisons in tests/benches without touching global
/// dispatch).
pub fn table_for(level: SimdLevel) -> Option<&'static Kernels> {
    if !is_available(level) {
        return None;
    }
    match level {
        SimdLevel::Scalar => Some(&SCALAR_KERNELS),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => Some(&SSE2_KERNELS),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => Some(&AVX2_KERNELS),
        #[cfg(not(target_arch = "x86_64"))]
        _ => None,
    }
}

/// Force dispatch to `level` process-wide (`None` restores auto
/// detection). Returns `false` (leaving dispatch unchanged) if the host
/// cannot run `level`.
///
/// Benches and the dedicated SIMD test binary use this to compare paths
/// in one process. It mutates global state: callers in multi-threaded
/// test binaries must serialize around it (see `tests/simd_kernels.rs`),
/// and production code must never call it.
pub fn set_override(level: Option<SimdLevel>) -> bool {
    let code = match level {
        None => 0,
        Some(l) => {
            if !is_available(l) {
                return false;
            }
            match l {
                SimdLevel::Scalar => 1,
                SimdLevel::Sse2 => 2,
                SimdLevel::Avx2 => 3,
            }
        }
    };
    OVERRIDE.store(code, Ordering::Relaxed);
    true
}

/// Name of the active level ("scalar", "sse2", "avx2+fma") — recorded by
/// the roofline bench and useful in logs.
pub fn active_name() -> &'static str {
    kernels().level.name()
}

// ---------------------------------------------------------------------------
// scalar kernels (the reference semantics every other level is pinned to)

fn dot_scalar(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let rx = xc.remainder();
    let ry = yc.remainder();
    for (a, b) in xc.zip(yc) {
        for l in 0..8 {
            acc[l] += a[l] * b[l];
        }
    }
    let mut s =
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (a, b) in rx.iter().zip(ry) {
        s += a * b;
    }
    s
}

fn dot2_scalar(x: &[f64], y: &[f64]) -> (f64, f64) {
    debug_assert_eq!(x.len(), y.len());
    let mut axy = [0.0f64; 8];
    let mut ayy = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let rx = xc.remainder();
    let ry = yc.remainder();
    for (a, b) in xc.zip(yc) {
        for l in 0..8 {
            axy[l] += a[l] * b[l];
            ayy[l] += b[l] * b[l];
        }
    }
    let mut sxy =
        ((axy[0] + axy[1]) + (axy[2] + axy[3])) + ((axy[4] + axy[5]) + (axy[6] + axy[7]));
    let mut syy =
        ((ayy[0] + ayy[1]) + (ayy[2] + ayy[3])) + ((ayy[4] + ayy[5]) + (ayy[6] + ayy[7]));
    for (a, b) in rx.iter().zip(ry) {
        sxy += a * b;
        syy += b * b;
    }
    (sxy, syy)
}

fn axpy_scalar(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

fn gemm_panel4_scalar(ablock: &[f64], m: usize, w: [&[f64]; 4], c: [&mut [f64]; 4]) {
    let [w0, w1, w2, w3] = w;
    let [c0, c1, c2, c3] = c;
    let kk = w0.len();
    debug_assert!(ablock.len() >= kk * m);
    for l in 0..kk {
        let al = &ablock[l * m..(l + 1) * m];
        let (b0, b1, b2, b3) = (w0[l], w1[l], w2[l], w3[l]);
        for i in 0..m {
            let ai = al[i];
            c0[i] += ai * b0;
            c1[i] += ai * b1;
            c2[i] += ai * b2;
            c3[i] += ai * b3;
        }
    }
}

fn gemm_col1_scalar(ablock: &[f64], m: usize, w: &[f64], c: &mut [f64]) {
    debug_assert!(ablock.len() >= w.len() * m);
    for (l, &wl) in w.iter().enumerate() {
        let al = &ablock[l * m..(l + 1) * m];
        // zero weights multiply through (no skip): the per-element op
        // sequence must match the panel kernel's exactly
        for (ci, &ai) in c.iter_mut().zip(al) {
            *ci += ai * wl;
        }
    }
}

fn tn_tile4_scalar(a: [&[f64]; 4], b: [&[f64]; 4]) -> [[f64; 4]; 4] {
    let m = a[0].len();
    let mut acc = [[0.0f64; 4]; 4];
    for r in 0..m {
        let av = [a[0][r], a[1][r], a[2][r], a[3][r]];
        let bv = [b[0][r], b[1][r], b[2][r], b[3][r]];
        for (ci, &avi) in av.iter().enumerate() {
            for (cj, &bvj) in bv.iter().enumerate() {
                acc[ci][cj] += avi * bvj;
            }
        }
    }
    acc
}

fn pack_f32_scalar(src: &[f64], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as f32;
    }
}

static SCALAR_KERNELS: Kernels = Kernels {
    level: SimdLevel::Scalar,
    dot: dot_scalar,
    dot2: dot2_scalar,
    axpy: axpy_scalar,
    gemm_panel4: gemm_panel4_scalar,
    gemm_col1: gemm_col1_scalar,
    tn_tile4: tn_tile4_scalar,
    pack_f32: pack_f32_scalar,
};

// ---------------------------------------------------------------------------
// SSE2 kernels (x86_64 baseline; mul+add only — bit-identical to scalar)

#[cfg(target_arch = "x86_64")]
mod sse2 {
    use std::arch::x86_64::*;

    pub(super) fn dot(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        // SAFETY: SSE2 is unconditionally available on x86_64; every
        // pointer read is guarded by `i + 8 <= n` (lanes i..i+8) against
        // the lengths of the borrowed slices.
        unsafe {
            let (xp, yp) = (x.as_ptr(), y.as_ptr());
            // four 2-lane accumulators = the scalar kernel's acc[0..8]
            let mut a01 = _mm_setzero_pd();
            let mut a23 = _mm_setzero_pd();
            let mut a45 = _mm_setzero_pd();
            let mut a67 = _mm_setzero_pd();
            let mut i = 0;
            while i + 8 <= n {
                a01 = _mm_add_pd(a01, _mm_mul_pd(_mm_loadu_pd(xp.add(i)), _mm_loadu_pd(yp.add(i))));
                a23 = _mm_add_pd(
                    a23,
                    _mm_mul_pd(_mm_loadu_pd(xp.add(i + 2)), _mm_loadu_pd(yp.add(i + 2))),
                );
                a45 = _mm_add_pd(
                    a45,
                    _mm_mul_pd(_mm_loadu_pd(xp.add(i + 4)), _mm_loadu_pd(yp.add(i + 4))),
                );
                a67 = _mm_add_pd(
                    a67,
                    _mm_mul_pd(_mm_loadu_pd(xp.add(i + 6)), _mm_loadu_pd(yp.add(i + 6))),
                );
                i += 8;
            }
            let mut acc = [0.0f64; 8];
            _mm_storeu_pd(acc.as_mut_ptr(), a01);
            _mm_storeu_pd(acc.as_mut_ptr().add(2), a23);
            _mm_storeu_pd(acc.as_mut_ptr().add(4), a45);
            _mm_storeu_pd(acc.as_mut_ptr().add(6), a67);
            let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
                + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
            while i < n {
                s += x[i] * y[i];
                i += 1;
            }
            s
        }
    }

    pub(super) fn dot2(x: &[f64], y: &[f64]) -> (f64, f64) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        // SAFETY: as in `dot` — baseline feature, reads guarded by
        // `i + 8 <= n` against the borrowed slice lengths.
        unsafe {
            let (xp, yp) = (x.as_ptr(), y.as_ptr());
            let mut xy01 = _mm_setzero_pd();
            let mut xy23 = _mm_setzero_pd();
            let mut xy45 = _mm_setzero_pd();
            let mut xy67 = _mm_setzero_pd();
            let mut yy01 = _mm_setzero_pd();
            let mut yy23 = _mm_setzero_pd();
            let mut yy45 = _mm_setzero_pd();
            let mut yy67 = _mm_setzero_pd();
            let mut i = 0;
            while i + 8 <= n {
                let (x0, y0) = (_mm_loadu_pd(xp.add(i)), _mm_loadu_pd(yp.add(i)));
                let (x2, y2) = (_mm_loadu_pd(xp.add(i + 2)), _mm_loadu_pd(yp.add(i + 2)));
                let (x4, y4) = (_mm_loadu_pd(xp.add(i + 4)), _mm_loadu_pd(yp.add(i + 4)));
                let (x6, y6) = (_mm_loadu_pd(xp.add(i + 6)), _mm_loadu_pd(yp.add(i + 6)));
                xy01 = _mm_add_pd(xy01, _mm_mul_pd(x0, y0));
                yy01 = _mm_add_pd(yy01, _mm_mul_pd(y0, y0));
                xy23 = _mm_add_pd(xy23, _mm_mul_pd(x2, y2));
                yy23 = _mm_add_pd(yy23, _mm_mul_pd(y2, y2));
                xy45 = _mm_add_pd(xy45, _mm_mul_pd(x4, y4));
                yy45 = _mm_add_pd(yy45, _mm_mul_pd(y4, y4));
                xy67 = _mm_add_pd(xy67, _mm_mul_pd(x6, y6));
                yy67 = _mm_add_pd(yy67, _mm_mul_pd(y6, y6));
                i += 8;
            }
            let mut axy = [0.0f64; 8];
            let mut ayy = [0.0f64; 8];
            _mm_storeu_pd(axy.as_mut_ptr(), xy01);
            _mm_storeu_pd(axy.as_mut_ptr().add(2), xy23);
            _mm_storeu_pd(axy.as_mut_ptr().add(4), xy45);
            _mm_storeu_pd(axy.as_mut_ptr().add(6), xy67);
            _mm_storeu_pd(ayy.as_mut_ptr(), yy01);
            _mm_storeu_pd(ayy.as_mut_ptr().add(2), yy23);
            _mm_storeu_pd(ayy.as_mut_ptr().add(4), yy45);
            _mm_storeu_pd(ayy.as_mut_ptr().add(6), yy67);
            let mut sxy = ((axy[0] + axy[1]) + (axy[2] + axy[3]))
                + ((axy[4] + axy[5]) + (axy[6] + axy[7]));
            let mut syy = ((ayy[0] + ayy[1]) + (ayy[2] + ayy[3]))
                + ((ayy[4] + ayy[5]) + (ayy[6] + ayy[7]));
            while i < n {
                sxy += x[i] * y[i];
                syy += y[i] * y[i];
                i += 1;
            }
            (sxy, syy)
        }
    }

    pub(super) fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        // SAFETY: baseline feature; reads/writes guarded by `i + 2 <= n`
        // against the borrowed slice lengths; x and y cannot alias
        // (&/&mut borrows).
        unsafe {
            let va = _mm_set1_pd(alpha);
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let mut i = 0;
            while i + 2 <= n {
                let v = _mm_add_pd(_mm_loadu_pd(yp.add(i)), _mm_mul_pd(va, _mm_loadu_pd(xp.add(i))));
                _mm_storeu_pd(yp.add(i), v);
                i += 2;
            }
            while i < n {
                *yp.add(i) += alpha * *xp.add(i);
                i += 1;
            }
        }
    }

    pub(super) fn gemm_panel4(ablock: &[f64], m: usize, w: [&[f64]; 4], c: [&mut [f64]; 4]) {
        let [w0, w1, w2, w3] = w;
        let [c0, c1, c2, c3] = c;
        let kk = w0.len();
        debug_assert!(ablock.len() >= kk * m);
        debug_assert!(c0.len() == m && c1.len() == m && c2.len() == m && c3.len() == m);
        // SAFETY: baseline feature; A reads stay inside `ablock[..kk*m]`
        // (l < kk, i + 2 <= m), C reads/writes inside the four disjoint
        // &mut slices of length m.
        unsafe {
            let ap = ablock.as_ptr();
            let (p0, p1, p2, p3) =
                (c0.as_mut_ptr(), c1.as_mut_ptr(), c2.as_mut_ptr(), c3.as_mut_ptr());
            let mut i = 0;
            while i + 2 <= m {
                let mut v0 = _mm_loadu_pd(p0.add(i));
                let mut v1 = _mm_loadu_pd(p1.add(i));
                let mut v2 = _mm_loadu_pd(p2.add(i));
                let mut v3 = _mm_loadu_pd(p3.add(i));
                for l in 0..kk {
                    let va = _mm_loadu_pd(ap.add(l * m + i));
                    v0 = _mm_add_pd(v0, _mm_mul_pd(va, _mm_set1_pd(*w0.get_unchecked(l))));
                    v1 = _mm_add_pd(v1, _mm_mul_pd(va, _mm_set1_pd(*w1.get_unchecked(l))));
                    v2 = _mm_add_pd(v2, _mm_mul_pd(va, _mm_set1_pd(*w2.get_unchecked(l))));
                    v3 = _mm_add_pd(v3, _mm_mul_pd(va, _mm_set1_pd(*w3.get_unchecked(l))));
                }
                _mm_storeu_pd(p0.add(i), v0);
                _mm_storeu_pd(p1.add(i), v1);
                _mm_storeu_pd(p2.add(i), v2);
                _mm_storeu_pd(p3.add(i), v3);
                i += 2;
            }
            while i < m {
                let (mut t0, mut t1, mut t2, mut t3) =
                    (*p0.add(i), *p1.add(i), *p2.add(i), *p3.add(i));
                for l in 0..kk {
                    let ai = *ap.add(l * m + i);
                    t0 += ai * *w0.get_unchecked(l);
                    t1 += ai * *w1.get_unchecked(l);
                    t2 += ai * *w2.get_unchecked(l);
                    t3 += ai * *w3.get_unchecked(l);
                }
                *p0.add(i) = t0;
                *p1.add(i) = t1;
                *p2.add(i) = t2;
                *p3.add(i) = t3;
                i += 1;
            }
        }
    }

    pub(super) fn gemm_col1(ablock: &[f64], m: usize, w: &[f64], c: &mut [f64]) {
        let kk = w.len();
        debug_assert!(ablock.len() >= kk * m);
        debug_assert_eq!(c.len(), m);
        // SAFETY: baseline feature; A reads inside `ablock[..kk*m]`,
        // C reads/writes guarded by `i + 2 <= m` / `i < m`.
        unsafe {
            let ap = ablock.as_ptr();
            let cp = c.as_mut_ptr();
            let mut i = 0;
            while i + 2 <= m {
                let mut v = _mm_loadu_pd(cp.add(i));
                for l in 0..kk {
                    v = _mm_add_pd(
                        v,
                        _mm_mul_pd(_mm_loadu_pd(ap.add(l * m + i)), _mm_set1_pd(*w.get_unchecked(l))),
                    );
                }
                _mm_storeu_pd(cp.add(i), v);
                i += 2;
            }
            while i < m {
                let mut t = *cp.add(i);
                for l in 0..kk {
                    t += *ap.add(l * m + i) * *w.get_unchecked(l);
                }
                *cp.add(i) = t;
                i += 1;
            }
        }
    }

    pub(super) fn tn_tile4(a: [&[f64]; 4], b: [&[f64]; 4]) -> [[f64; 4]; 4] {
        let m = a[0].len();
        debug_assert!(a.iter().chain(b.iter()).all(|s| s.len() == m));
        // SAFETY: baseline feature; reads guarded by `r + 2 <= m` /
        // `r < m` against the common column length m.
        unsafe {
            let ap = [a[0].as_ptr(), a[1].as_ptr(), a[2].as_ptr(), a[3].as_ptr()];
            let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
            let mut acc = [[_mm_setzero_pd(); 4]; 4];
            let mut r = 0;
            while r + 2 <= m {
                let va = [
                    _mm_loadu_pd(ap[0].add(r)),
                    _mm_loadu_pd(ap[1].add(r)),
                    _mm_loadu_pd(ap[2].add(r)),
                    _mm_loadu_pd(ap[3].add(r)),
                ];
                let vb = [
                    _mm_loadu_pd(bp[0].add(r)),
                    _mm_loadu_pd(bp[1].add(r)),
                    _mm_loadu_pd(bp[2].add(r)),
                    _mm_loadu_pd(bp[3].add(r)),
                ];
                for ci in 0..4 {
                    for cj in 0..4 {
                        acc[ci][cj] = _mm_add_pd(acc[ci][cj], _mm_mul_pd(va[ci], vb[cj]));
                    }
                }
                r += 2;
            }
            let mut out = [[0.0f64; 4]; 4];
            for ci in 0..4 {
                for cj in 0..4 {
                    let mut lanes = [0.0f64; 2];
                    _mm_storeu_pd(lanes.as_mut_ptr(), acc[ci][cj]);
                    out[ci][cj] = lanes[0] + lanes[1];
                }
            }
            while r < m {
                for ci in 0..4 {
                    let av = *ap[ci].add(r);
                    for cj in 0..4 {
                        out[ci][cj] += av * *bp[cj].add(r);
                    }
                }
                r += 1;
            }
            out
        }
    }
}

#[cfg(target_arch = "x86_64")]
static SSE2_KERNELS: Kernels = Kernels {
    level: SimdLevel::Sse2,
    dot: sse2::dot,
    dot2: sse2::dot2,
    axpy: sse2::axpy,
    gemm_panel4: sse2::gemm_panel4,
    gemm_col1: sse2::gemm_col1,
    tn_tile4: sse2::tn_tile4,
    // f64→f32 narrowing is elementwise and exact under round-to-nearest
    // either way; the scalar loop is already optimal at 128 bits
    pack_f32: pack_f32_scalar,
};

// ---------------------------------------------------------------------------
// AVX2+FMA kernels. Each `#[target_feature] unsafe fn` is wrapped by a safe
// `*_entry` that is stored only in AVX2_KERNELS, which is only selectable
// after `is_x86_feature_detected!("avx2")` && `("fma")` both passed.

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    // SAFETY: callable only when AVX2+FMA are present — the sole callers
    // are the `*_entry` wrappers gated by runtime feature detection.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_impl(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        // SAFETY: the caller (entry wrapper) guarantees AVX2; every
        // pointer read is guarded by `i + 8 <= n` (lanes i..i+8) against
        // the borrowed slice lengths.
        unsafe {
            let (xp, yp) = (x.as_ptr(), y.as_ptr());
            // two 4-lane accumulators = the scalar kernel's acc[0..8];
            // mul+add (not FMA) keeps every lane bit-identical to scalar
            let mut lo = _mm256_setzero_pd();
            let mut hi = _mm256_setzero_pd();
            let mut i = 0;
            while i + 8 <= n {
                lo = _mm256_add_pd(
                    lo,
                    _mm256_mul_pd(_mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i))),
                );
                hi = _mm256_add_pd(
                    hi,
                    _mm256_mul_pd(_mm256_loadu_pd(xp.add(i + 4)), _mm256_loadu_pd(yp.add(i + 4))),
                );
                i += 8;
            }
            let mut acc = [0.0f64; 8];
            _mm256_storeu_pd(acc.as_mut_ptr(), lo);
            _mm256_storeu_pd(acc.as_mut_ptr().add(4), hi);
            let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
                + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
            while i < n {
                s += x[i] * y[i];
                i += 1;
            }
            s
        }
    }

    pub(super) fn dot_entry(x: &[f64], y: &[f64]) -> f64 {
        // SAFETY: this entry is reachable only through AVX2_KERNELS, which
        // dispatch hands out only after the avx2+fma feature checks passed.
        unsafe { dot_impl(x, y) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot2_impl(x: &[f64], y: &[f64]) -> (f64, f64) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        // SAFETY: as in `dot_impl` — reads guarded by `i + 8 <= n`.
        unsafe {
            let (xp, yp) = (x.as_ptr(), y.as_ptr());
            let mut xy_lo = _mm256_setzero_pd();
            let mut xy_hi = _mm256_setzero_pd();
            let mut yy_lo = _mm256_setzero_pd();
            let mut yy_hi = _mm256_setzero_pd();
            let mut i = 0;
            while i + 8 <= n {
                let (x0, y0) = (_mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i)));
                let (x4, y4) = (_mm256_loadu_pd(xp.add(i + 4)), _mm256_loadu_pd(yp.add(i + 4)));
                xy_lo = _mm256_add_pd(xy_lo, _mm256_mul_pd(x0, y0));
                yy_lo = _mm256_add_pd(yy_lo, _mm256_mul_pd(y0, y0));
                xy_hi = _mm256_add_pd(xy_hi, _mm256_mul_pd(x4, y4));
                yy_hi = _mm256_add_pd(yy_hi, _mm256_mul_pd(y4, y4));
                i += 8;
            }
            let mut axy = [0.0f64; 8];
            let mut ayy = [0.0f64; 8];
            _mm256_storeu_pd(axy.as_mut_ptr(), xy_lo);
            _mm256_storeu_pd(axy.as_mut_ptr().add(4), xy_hi);
            _mm256_storeu_pd(ayy.as_mut_ptr(), yy_lo);
            _mm256_storeu_pd(ayy.as_mut_ptr().add(4), yy_hi);
            let mut sxy = ((axy[0] + axy[1]) + (axy[2] + axy[3]))
                + ((axy[4] + axy[5]) + (axy[6] + axy[7]));
            let mut syy = ((ayy[0] + ayy[1]) + (ayy[2] + ayy[3]))
                + ((ayy[4] + ayy[5]) + (ayy[6] + ayy[7]));
            while i < n {
                sxy += x[i] * y[i];
                syy += y[i] * y[i];
                i += 1;
            }
            (sxy, syy)
        }
    }

    pub(super) fn dot2_entry(x: &[f64], y: &[f64]) -> (f64, f64) {
        // SAFETY: see `dot_entry`.
        unsafe { dot2_impl(x, y) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn axpy_impl(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        // SAFETY: reads/writes guarded by `i + 4 <= n` / `i < n` against
        // the borrowed slice lengths; x and y cannot alias (&/&mut).
        unsafe {
            // elementwise mul+add (not FMA): bit-identical to scalar
            let va = _mm256_set1_pd(alpha);
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= n {
                let v = _mm256_add_pd(
                    _mm256_loadu_pd(yp.add(i)),
                    _mm256_mul_pd(va, _mm256_loadu_pd(xp.add(i))),
                );
                _mm256_storeu_pd(yp.add(i), v);
                i += 4;
            }
            while i < n {
                *yp.add(i) += alpha * *xp.add(i);
                i += 1;
            }
        }
    }

    pub(super) fn axpy_entry(alpha: f64, x: &[f64], y: &mut [f64]) {
        // SAFETY: see `dot_entry`.
        unsafe { axpy_impl(alpha, x, y) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn gemm_panel4_impl(ablock: &[f64], m: usize, w: [&[f64]; 4], c: [&mut [f64]; 4]) {
        let [w0, w1, w2, w3] = w;
        let [c0, c1, c2, c3] = c;
        let kk = w0.len();
        debug_assert!(ablock.len() >= kk * m);
        debug_assert!(c0.len() == m && c1.len() == m && c2.len() == m && c3.len() == m);
        // SAFETY: A reads stay inside `ablock[..kk*m]` (l < kk, lanes
        // i..i+4 with i + 4 <= m), C reads/writes inside the four disjoint
        // &mut slices of length m; weight reads are l < kk per the
        // debug-asserted common length.
        unsafe {
            let ap = ablock.as_ptr();
            let (p0, p1, p2, p3) =
                (c0.as_mut_ptr(), c1.as_mut_ptr(), c2.as_mut_ptr(), c3.as_mut_ptr());
            let mut i = 0;
            while i + 4 <= m {
                let mut v0 = _mm256_loadu_pd(p0.add(i));
                let mut v1 = _mm256_loadu_pd(p1.add(i));
                let mut v2 = _mm256_loadu_pd(p2.add(i));
                let mut v3 = _mm256_loadu_pd(p3.add(i));
                for l in 0..kk {
                    let va = _mm256_loadu_pd(ap.add(l * m + i));
                    v0 = _mm256_fmadd_pd(va, _mm256_set1_pd(*w0.get_unchecked(l)), v0);
                    v1 = _mm256_fmadd_pd(va, _mm256_set1_pd(*w1.get_unchecked(l)), v1);
                    v2 = _mm256_fmadd_pd(va, _mm256_set1_pd(*w2.get_unchecked(l)), v2);
                    v3 = _mm256_fmadd_pd(va, _mm256_set1_pd(*w3.get_unchecked(l)), v3);
                }
                _mm256_storeu_pd(p0.add(i), v0);
                _mm256_storeu_pd(p1.add(i), v1);
                _mm256_storeu_pd(p2.add(i), v2);
                _mm256_storeu_pd(p3.add(i), v3);
                i += 4;
            }
            // row tail: f64::mul_add keeps the op sequence fused like the
            // vector body, so all rows of a column agree bit-for-bit
            while i < m {
                let (mut t0, mut t1, mut t2, mut t3) =
                    (*p0.add(i), *p1.add(i), *p2.add(i), *p3.add(i));
                for l in 0..kk {
                    let ai = *ap.add(l * m + i);
                    t0 = ai.mul_add(*w0.get_unchecked(l), t0);
                    t1 = ai.mul_add(*w1.get_unchecked(l), t1);
                    t2 = ai.mul_add(*w2.get_unchecked(l), t2);
                    t3 = ai.mul_add(*w3.get_unchecked(l), t3);
                }
                *p0.add(i) = t0;
                *p1.add(i) = t1;
                *p2.add(i) = t2;
                *p3.add(i) = t3;
                i += 1;
            }
        }
    }

    pub(super) fn gemm_panel4_entry(ablock: &[f64], m: usize, w: [&[f64]; 4], c: [&mut [f64]; 4]) {
        // SAFETY: see `dot_entry`.
        unsafe { gemm_panel4_impl(ablock, m, w, c) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn gemm_col1_impl(ablock: &[f64], m: usize, w: &[f64], c: &mut [f64]) {
        let kk = w.len();
        debug_assert!(ablock.len() >= kk * m);
        debug_assert_eq!(c.len(), m);
        // SAFETY: A reads inside `ablock[..kk*m]`, C reads/writes guarded
        // by `i + 4 <= m` / `i < m` against the &mut slice length.
        unsafe {
            let ap = ablock.as_ptr();
            let cp = c.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= m {
                let mut v = _mm256_loadu_pd(cp.add(i));
                for l in 0..kk {
                    v = _mm256_fmadd_pd(
                        _mm256_loadu_pd(ap.add(l * m + i)),
                        _mm256_set1_pd(*w.get_unchecked(l)),
                        v,
                    );
                }
                _mm256_storeu_pd(cp.add(i), v);
                i += 4;
            }
            while i < m {
                let mut t = *cp.add(i);
                for l in 0..kk {
                    t = (*ap.add(l * m + i)).mul_add(*w.get_unchecked(l), t);
                }
                *cp.add(i) = t;
                i += 1;
            }
        }
    }

    pub(super) fn gemm_col1_entry(ablock: &[f64], m: usize, w: &[f64], c: &mut [f64]) {
        // SAFETY: see `dot_entry`.
        unsafe { gemm_col1_impl(ablock, m, w, c) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn tn_tile4_impl(a: [&[f64]; 4], b: [&[f64]; 4]) -> [[f64; 4]; 4] {
        let m = a[0].len();
        debug_assert!(a.iter().chain(b.iter()).all(|s| s.len() == m));
        // SAFETY: reads guarded by `r + 4 <= m` / `r < m` against the
        // common (debug-asserted) column length m.
        unsafe {
            let ap = [a[0].as_ptr(), a[1].as_ptr(), a[2].as_ptr(), a[3].as_ptr()];
            let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
            let mut acc = [[_mm256_setzero_pd(); 4]; 4];
            let mut r = 0;
            while r + 4 <= m {
                let va = [
                    _mm256_loadu_pd(ap[0].add(r)),
                    _mm256_loadu_pd(ap[1].add(r)),
                    _mm256_loadu_pd(ap[2].add(r)),
                    _mm256_loadu_pd(ap[3].add(r)),
                ];
                let vb = [
                    _mm256_loadu_pd(bp[0].add(r)),
                    _mm256_loadu_pd(bp[1].add(r)),
                    _mm256_loadu_pd(bp[2].add(r)),
                    _mm256_loadu_pd(bp[3].add(r)),
                ];
                for ci in 0..4 {
                    for cj in 0..4 {
                        acc[ci][cj] = _mm256_fmadd_pd(va[ci], vb[cj], acc[ci][cj]);
                    }
                }
                r += 4;
            }
            let mut out = [[0.0f64; 4]; 4];
            for ci in 0..4 {
                for cj in 0..4 {
                    let mut lanes = [0.0f64; 4];
                    _mm256_storeu_pd(lanes.as_mut_ptr(), acc[ci][cj]);
                    out[ci][cj] = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
                }
            }
            while r < m {
                for ci in 0..4 {
                    let av = *ap[ci].add(r);
                    for cj in 0..4 {
                        out[ci][cj] = av.mul_add(*bp[cj].add(r), out[ci][cj]);
                    }
                }
                r += 1;
            }
            out
        }
    }

    pub(super) fn tn_tile4_entry(a: [&[f64]; 4], b: [&[f64]; 4]) -> [[f64; 4]; 4] {
        // SAFETY: see `dot_entry`.
        unsafe { tn_tile4_impl(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn pack_f32_impl(src: &[f64], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len().min(dst.len());
        // SAFETY: reads/writes guarded by `i + 4 <= n` / `i < n` against
        // the borrowed slice lengths; vcvtpd2ps rounds to nearest exactly
        // like `as f32`, so the narrowing is bit-identical to scalar.
        unsafe {
            let sp = src.as_ptr();
            let dp = dst.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= n {
                _mm_storeu_ps(dp.add(i), _mm256_cvtpd_ps(_mm256_loadu_pd(sp.add(i))));
                i += 4;
            }
            while i < n {
                *dp.add(i) = *sp.add(i) as f32;
                i += 1;
            }
        }
    }

    pub(super) fn pack_f32_entry(src: &[f64], dst: &mut [f32]) {
        // SAFETY: see `dot_entry`.
        unsafe { pack_f32_impl(src, dst) }
    }
}

#[cfg(target_arch = "x86_64")]
static AVX2_KERNELS: Kernels = Kernels {
    level: SimdLevel::Avx2,
    dot: avx2::dot_entry,
    dot2: avx2::dot2_entry,
    axpy: avx2::axpy_entry,
    gemm_panel4: avx2::gemm_panel4_entry,
    gemm_col1: avx2::gemm_col1_entry,
    tn_tile4: avx2::tn_tile4_entry,
    pack_f32: avx2::pack_f32_entry,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn vecs(rng: &mut Pcg64, n: usize) -> (Vec<f64>, Vec<f64>) {
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        (x, y)
    }

    /// every remainder class around the 8-lane dot body and 4/2-lane tails
    const LENS: [usize; 10] = [0, 1, 2, 3, 7, 8, 9, 31, 64, 101];

    #[test]
    fn detection_reports_a_runnable_level() {
        let ks = kernels();
        assert!(is_available(ks.level), "active level {:?} must be runnable", ks.level);
        assert!(available_levels().contains(&SimdLevel::Scalar));
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert!(!active_name().is_empty());
    }

    #[test]
    fn table_for_unavailable_levels_is_none() {
        for l in [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2] {
            assert_eq!(table_for(l).is_some(), is_available(l));
            if let Some(t) = table_for(l) {
                assert_eq!(t.level, l);
            }
        }
    }

    #[test]
    fn dot_and_dot2_bit_identical_across_levels() {
        let mut rng = Pcg64::seed_from(11);
        for n in LENS {
            let (x, y) = vecs(&mut rng, n);
            let want = dot_scalar(&x, &y);
            let want2 = dot2_scalar(&x, &y);
            assert_eq!(want2.0.to_bits(), want.to_bits(), "dot2.0 == dot, n={n}");
            assert_eq!(want2.1.to_bits(), dot_scalar(&y, &y).to_bits(), "dot2.1 == y·y, n={n}");
            for lvl in available_levels() {
                let t = table_for(lvl).unwrap();
                let got = (t.dot)(&x, &y);
                assert_eq!(got.to_bits(), want.to_bits(), "dot {lvl:?} n={n}");
                let got2 = (t.dot2)(&x, &y);
                assert_eq!(got2.0.to_bits(), want2.0.to_bits(), "dot2.xy {lvl:?} n={n}");
                assert_eq!(got2.1.to_bits(), want2.1.to_bits(), "dot2.yy {lvl:?} n={n}");
            }
        }
    }

    #[test]
    fn axpy_bit_identical_across_levels() {
        let mut rng = Pcg64::seed_from(12);
        for n in LENS {
            let (x, y0) = vecs(&mut rng, n);
            let alpha = rng.next_gaussian();
            let mut want = y0.clone();
            axpy_scalar(alpha, &x, &mut want);
            for lvl in available_levels() {
                let t = table_for(lvl).unwrap();
                let mut got = y0.clone();
                (t.axpy)(alpha, &x, &mut got);
                for i in 0..n {
                    assert_eq!(got[i].to_bits(), want[i].to_bits(), "axpy {lvl:?} n={n} i={i}");
                }
            }
        }
    }

    #[test]
    fn pack_f32_bit_identical_across_levels() {
        let mut rng = Pcg64::seed_from(13);
        for n in LENS {
            let (x, _) = vecs(&mut rng, n);
            let mut want = vec![0.0f32; n];
            pack_f32_scalar(&x, &mut want);
            for lvl in available_levels() {
                let t = table_for(lvl).unwrap();
                let mut got = vec![0.0f32; n];
                (t.pack_f32)(&x, &mut got);
                for i in 0..n {
                    assert_eq!(got[i].to_bits(), want[i].to_bits(), "pack {lvl:?} n={n} i={i}");
                }
            }
        }
    }

    fn panel_inputs(
        rng: &mut Pcg64,
        m: usize,
        kk: usize,
    ) -> (Vec<f64>, [Vec<f64>; 4], [Vec<f64>; 4]) {
        let ablock: Vec<f64> = (0..m * kk).map(|_| rng.next_gaussian()).collect();
        let mut w: [Vec<f64>; 4] = Default::default();
        let mut c: [Vec<f64>; 4] = Default::default();
        for t in 0..4 {
            // sprinkle exact zeros into the weights: the remainder kernel
            // must multiply them through, not skip them
            w[t] = (0..kk)
                .map(|l| if (l + t) % 3 == 0 { 0.0 } else { rng.next_gaussian() })
                .collect();
            c[t] = (0..m).map(|_| rng.next_gaussian()).collect();
        }
        (ablock, w, c)
    }

    #[test]
    fn gemm_panel_matches_scalar_within_tolerance() {
        let mut rng = Pcg64::seed_from(14);
        for (m, kk) in [(1, 1), (2, 3), (5, 8), (8, 17), (13, 64), (64, 9)] {
            let (ablock, w, c0) = panel_inputs(&mut rng, m, kk);
            let wr: [&[f64]; 4] = [&w[0][..], &w[1][..], &w[2][..], &w[3][..]];
            let mut want = c0.clone();
            {
                let [a, b, c, d] = &mut want;
                gemm_panel4_scalar(&ablock, m, wr, [&mut a[..], &mut b[..], &mut c[..], &mut d[..]]);
            }
            for lvl in available_levels() {
                let t = table_for(lvl).unwrap();
                let mut got = c0.clone();
                {
                    let [a, b, c, d] = &mut got;
                    (t.gemm_panel4)(&ablock, m, wr, [&mut a[..], &mut b[..], &mut c[..], &mut d[..]]);
                }
                for ti in 0..4 {
                    for i in 0..m {
                        let (g, s) = (got[ti][i], want[ti][i]);
                        assert!(
                            (g - s).abs() <= 1e-9 * (1.0 + s.abs()),
                            "panel {lvl:?} m={m} kk={kk} col={ti} i={i}: {g} vs {s}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_col_bitwise_consistent_with_panel_per_level() {
        // per level, the 1-column remainder kernel must produce bit-for-bit
        // the panel kernel's columns — including for exact-zero weights
        // (the old remainder path skipped them; see ISSUE 8 satellite 1)
        let mut rng = Pcg64::seed_from(15);
        for (m, kk) in [(1, 2), (3, 5), (7, 16), (12, 33), (30, 64)] {
            let (ablock, w, c0) = panel_inputs(&mut rng, m, kk);
            let wr: [&[f64]; 4] = [&w[0][..], &w[1][..], &w[2][..], &w[3][..]];
            for lvl in available_levels() {
                let t = table_for(lvl).unwrap();
                let mut panel = c0.clone();
                {
                    let [a, b, c, d] = &mut panel;
                    (t.gemm_panel4)(&ablock, m, wr, [&mut a[..], &mut b[..], &mut c[..], &mut d[..]]);
                }
                for ti in 0..4 {
                    let mut col = c0[ti].clone();
                    (t.gemm_col1)(&ablock, m, &w[ti][..], &mut col[..]);
                    for i in 0..m {
                        assert_eq!(
                            col[i].to_bits(),
                            panel[ti][i].to_bits(),
                            "col-vs-panel {lvl:?} m={m} kk={kk} col={ti} i={i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tn_tile_matches_scalar_within_tolerance() {
        let mut rng = Pcg64::seed_from(16);
        for m in [1usize, 2, 3, 4, 5, 9, 33, 64] {
            let cols: Vec<Vec<f64>> =
                (0..8).map(|_| (0..m).map(|_| rng.next_gaussian()).collect()).collect();
            let a: [&[f64]; 4] = [&cols[0], &cols[1], &cols[2], &cols[3]];
            let b: [&[f64]; 4] = [&cols[4], &cols[5], &cols[6], &cols[7]];
            let want = tn_tile4_scalar(a, b);
            for lvl in available_levels() {
                let t = table_for(lvl).unwrap();
                let got = (t.tn_tile4)(a, b);
                for ci in 0..4 {
                    for cj in 0..4 {
                        let (g, s) = (got[ci][cj], want[ci][cj]);
                        assert!(
                            (g - s).abs() <= 1e-9 * (1.0 + s.abs()),
                            "tile {lvl:?} m={m} [{ci}][{cj}]: {g} vs {s}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tn_tile_matches_dot_reference() {
        let mut rng = Pcg64::seed_from(17);
        let m = 29;
        let cols: Vec<Vec<f64>> =
            (0..8).map(|_| (0..m).map(|_| rng.next_gaussian()).collect()).collect();
        let a: [&[f64]; 4] = [&cols[0], &cols[1], &cols[2], &cols[3]];
        let b: [&[f64]; 4] = [&cols[4], &cols[5], &cols[6], &cols[7]];
        for lvl in available_levels() {
            let t = table_for(lvl).unwrap();
            let got = (t.tn_tile4)(a, b);
            for ci in 0..4 {
                for cj in 0..4 {
                    let want = dot_scalar(a[ci], b[cj]);
                    assert!(
                        (got[ci][cj] - want).abs() <= 1e-10 * (1.0 + want.abs()),
                        "tile-vs-dot {lvl:?} [{ci}][{cj}]"
                    );
                }
            }
        }
    }
}
