//! Cholesky factorization of SPD matrices with rank-1 updates.
//!
//! The A-optimality objective maintains the posterior precision
//! `Λ + σ⁻² X_S X_Sᵀ` whose Cholesky factor is updated in O(d²) per added
//! experiment via [`chol_rank1_update`] instead of refactorizing in O(d³).

use super::{Matrix, solve::{solve_lower, solve_lower_t}};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
#[derive(Debug, Clone)]
pub struct CholeskyFactor {
    /// lower triangular, stored as a full column-major matrix (upper = 0)
    pub l: Matrix,
}

/// Factor an SPD matrix; returns `None` if a non-positive pivot appears
/// (matrix not positive definite to working precision).
pub fn cholesky(a: &Matrix) -> Option<CholeskyFactor> {
    let mut l = a.clone();
    if cholesky_in_place(&mut l) {
        Some(CholeskyFactor { l })
    } else {
        None
    }
}

/// In-place lower Cholesky on a full square matrix; zeroes the strict upper
/// triangle. Returns false on non-SPD input.
pub fn cholesky_in_place(a: &mut Matrix) -> bool {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky of non-square");
    for j in 0..n {
        // diagonal
        let mut d = a.get(j, j);
        for k in 0..j {
            let ljk = a.get(j, k);
            d -= ljk * ljk;
        }
        if d <= 0.0 || !d.is_finite() {
            return false;
        }
        let djj = d.sqrt();
        a.set(j, j, djj);
        // column below diagonal
        for i in (j + 1)..n {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= a.get(i, k) * a.get(j, k);
            }
            a.set(i, j, s / djj);
        }
        // zero upper
        for i in 0..j {
            a.set(i, j, 0.0);
        }
    }
    true
}

impl CholeskyFactor {
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// Solve `A x = b` via the factor (forward + back substitution).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_lower_t(&self.l, &y)
    }

    /// `log det A = 2 Σ log L_ii`.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }

    /// Reconstruct `A = L Lᵀ` (tests / diagnostics).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.dim();
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..=i.min(j) {
                    s += self.l.get(i, k) * self.l.get(j, k);
                }
                a.set(i, j, s);
            }
        }
        a
    }

    /// Trace of `A⁻¹` computed column-by-column: `tr(A⁻¹) = Σ_i ‖L⁻¹ e_i‖²`.
    /// O(d³) — used for exact A-optimality evaluation (the incremental path
    /// in `objectives::aopt` avoids this per query).
    pub fn inv_trace(&self) -> f64 {
        let n = self.dim();
        let mut tr = 0.0;
        let mut e = vec![0.0; n];
        for i in 0..n {
            e.fill(0.0);
            e[i] = 1.0;
            let y = solve_lower(&self.l, &e);
            tr += y.iter().map(|v| v * v).sum::<f64>();
        }
        tr
    }
}

/// Rank-1 update: given `L` with `A = L Lᵀ`, transform `L` in place so
/// `L Lᵀ = A + x xᵀ`. Classic Givens-based O(d²) algorithm; consumes `x`
/// as scratch.
pub fn chol_rank1_update(l: &mut Matrix, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(x.len(), n);
    for k in 0..n {
        let lkk = l.get(k, k);
        let xk = x[k];
        let r = (lkk * lkk + xk * xk).sqrt();
        let c = r / lkk;
        let s = xk / lkk;
        l.set(k, k, r);
        for i in (k + 1)..n {
            let lik = l.get(i, k);
            let v = (lik + s * x[i]) / c;
            x[i] = c * x[i] - s * v;
            l.set(i, k, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let mut b = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                b.set(i, j, rng.next_gaussian());
            }
        }
        let mut a = super::super::blas::syrk(&b);
        for i in 0..n {
            a.add_at(i, i, n as f64); // well conditioned
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Pcg64::seed_from(1);
        for n in [1, 2, 5, 12] {
            let a = random_spd(&mut rng, n);
            let f = cholesky(&a).expect("spd");
            assert!(f.reconstruct().max_abs_diff(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn known_2x2() {
        let a = Matrix::from_rows(2, 2, &[4.0, 2.0, 2.0, 5.0]);
        let f = cholesky(&a).unwrap();
        assert!((f.l.get(0, 0) - 2.0).abs() < 1e-14);
        assert!((f.l.get(1, 0) - 1.0).abs() < 1e-14);
        assert!((f.l.get(1, 1) - 2.0).abs() < 1e-14);
        assert_eq!(f.l.get(0, 1), 0.0);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eig -1
        assert!(cholesky(&a).is_none());
        let zero = Matrix::zeros(2, 2);
        assert!(cholesky(&zero).is_none());
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Pcg64::seed_from(3);
        let a = random_spd(&mut rng, 8);
        let f = cholesky(&a).unwrap();
        let x_true: Vec<f64> = (0..8).map(|i| i as f64 - 3.0).collect();
        let mut b = vec![0.0; 8];
        super::super::blas::gemv(&a, &x_true, &mut b);
        let x = f.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn log_det_and_inv_trace() {
        // diag(2, 8): logdet = ln 16, tr(inv) = 0.5 + 0.125
        let a = Matrix::from_rows(2, 2, &[2.0, 0.0, 0.0, 8.0]);
        let f = cholesky(&a).unwrap();
        assert!((f.log_det() - 16f64.ln()).abs() < 1e-12);
        assert!((f.inv_trace() - 0.625).abs() < 1e-12);
    }

    #[test]
    fn rank1_update_matches_refactor() {
        let mut rng = Pcg64::seed_from(5);
        let a = random_spd(&mut rng, 10);
        let mut f = cholesky(&a).unwrap();
        let x: Vec<f64> = (0..10).map(|_| rng.next_gaussian()).collect();
        // updated A
        let mut a2 = a.clone();
        for i in 0..10 {
            for j in 0..10 {
                a2.add_at(i, j, x[i] * x[j]);
            }
        }
        let mut xs = x.clone();
        chol_rank1_update(&mut f.l, &mut xs);
        let f2 = cholesky(&a2).unwrap();
        assert!(f.l.max_abs_diff(&f2.l) < 1e-8);
    }

    #[test]
    fn repeated_rank1_updates_stay_accurate() {
        let mut rng = Pcg64::seed_from(7);
        let n = 6;
        let mut a = Matrix::identity(n);
        let mut f = cholesky(&a).unwrap();
        for _ in 0..25 {
            let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian() * 0.7).collect();
            for i in 0..n {
                for j in 0..n {
                    a.add_at(i, j, x[i] * x[j]);
                }
            }
            let mut xs = x.clone();
            chol_rank1_update(&mut f.l, &mut xs);
        }
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-7);
    }
}
