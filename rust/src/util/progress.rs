//! Terminal progress meter for long experiment sweeps (stderr, no deps).
//!
//! Quiet unless logging level is at least Info and stderr is not captured.

use crate::util::logging::{enabled, Level};
use crate::util::timer::fmt_duration_s;
use std::time::Instant;

/// A counting progress meter: `Progress::new("fig2 sweep", 40)`.
pub struct Progress {
    label: String,
    total: usize,
    done: usize,
    start: Instant,
    last_render: f64,
    active: bool,
}

impl Progress {
    pub fn new(label: &str, total: usize) -> Self {
        Progress {
            label: label.to_string(),
            total,
            done: 0,
            start: Instant::now(),
            last_render: -1.0,
            active: enabled(Level::Info),
        }
    }

    /// Advance by one step and maybe re-render (throttled to 10 Hz).
    pub fn tick(&mut self) {
        self.done += 1;
        let t = self.start.elapsed().as_secs_f64();
        if self.active && (t - self.last_render > 0.1 || self.done == self.total) {
            self.last_render = t;
            let pct = if self.total == 0 {
                100.0
            } else {
                100.0 * self.done as f64 / self.total as f64
            };
            let eta = if self.done > 0 && self.total > self.done {
                let rate = t / self.done as f64;
                format!(" eta {}", fmt_duration_s(rate * (self.total - self.done) as f64))
            } else {
                String::new()
            };
            eprint!(
                "\r[dash] {}: {}/{} ({:.0}%) {}{}   ",
                self.label,
                self.done,
                self.total,
                pct,
                fmt_duration_s(t),
                eta
            );
            if self.done >= self.total {
                eprintln!();
            }
        }
    }

    pub fn done(&self) -> usize {
        self.done
    }

    pub fn finish(&mut self) {
        if self.active && self.done < self.total {
            self.done = self.total.saturating_sub(1);
            self.tick();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_count() {
        let mut p = Progress::new("test", 3);
        p.tick();
        p.tick();
        assert_eq!(p.done(), 2);
        p.finish();
        assert!(p.done() >= 2);
    }

    #[test]
    fn zero_total_does_not_divide_by_zero() {
        let mut p = Progress::new("zero", 0);
        p.tick(); // should not panic
        assert_eq!(p.done(), 1);
    }
}
