//! Miniature property-based testing harness (the `proptest` crate is not
//! available offline).
//!
//! A property is a closure `Fn(&mut Gen) -> Result<(), String>`; the runner
//! executes it for `cases` deterministic seeds with a growing size budget.
//! On failure it re-runs at smaller sizes to report the smallest failing
//! size, then panics with the seed so the case can be replayed exactly:
//!
//! ```no_run
//! use dash_select::util::proptest::{check, Gen};
//! check("sort idempotent", 64, |g| {
//!     let mut v = g.vec_f64(0.0, 1.0, g.size());
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     let w = { let mut w = v.clone(); w.sort_by(|a, b| a.partial_cmp(b).unwrap()); w };
//!     if v == w { Ok(()) } else { Err("not idempotent".into()) }
//! });
//! ```

use crate::rng::Pcg64;

/// Randomness + size budget handed to properties.
pub struct Gen {
    rng: Pcg64,
    size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Pcg64::seed_from(seed), size: size.max(1) }
    }

    /// Current size budget (grows over cases; properties should scale their
    /// instances by it so small cases run first).
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.gen_range_usize(lo, hi)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f64(&mut self, lo: f64, hi: f64, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// A standard-normal vector.
    pub fn vec_normal(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.rng.next_gaussian()).collect()
    }

    /// A random subset of `0..n` of the given size (uniform, no repeats).
    pub fn subset(&mut self, n: usize, size: usize) -> Vec<usize> {
        self.rng.sample_indices(n, size.min(n))
    }
}

/// Run `prop` for `cases` seeds. Panics with a replayable seed on failure.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, cases, 0xDA5E_0001, prop)
}

/// [`check`] with an explicit base seed (replays: pass the reported seed
/// with `cases = 1`).
pub fn check_seeded<F>(name: &str, cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // size ramps from 2 to ~66 over the run
        let size = 2 + (case * 64) / cases.max(1);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // try to find a smaller failing size for readability
            let mut min_fail = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut g2 = Gen::new(seed, s);
                match prop(&mut g2) {
                    Err(m) => {
                        min_fail = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assert two floats agree to a relative-or-absolute tolerance; formats a
/// useful error for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol}, diff {})", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum symmetric", 32, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            close(a + b, b + a, 1e-12)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn subset_is_valid() {
        check("subset bounds", 32, |g| {
            let n = g.usize_in(1, 50);
            let k = g.usize_in(0, n);
            let s = g.subset(n, k);
            if s.len() != k {
                return Err(format!("len {} != {}", s.len(), k));
            }
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != k {
                return Err("duplicates".into());
            }
            if s.iter().any(|&i| i >= n) {
                return Err("out of range".into());
            }
            Ok(())
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-13, 1e-12).is_ok());
        assert!(close(1.0, 1.1, 1e-12).is_err());
        // relative scaling: large numbers allowed proportional slack
        assert!(close(1e9, 1e9 + 1.0, 1e-8).is_ok());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut g1 = Gen::new(42, 10);
        let mut g2 = Gen::new(42, 10);
        assert_eq!(g1.u64(), g2.u64());
        assert_eq!(g1.vec_f64(0.0, 1.0, 5), g2.vec_f64(0.0, 1.0, 5));
    }
}
