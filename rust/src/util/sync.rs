//! Poison-recovering lock wrappers with an optional lock-order deadlock
//! detector.
//!
//! Every lock in this crate goes through [`Mutex`] / [`RwLock`] below instead
//! of `std::sync` (enforced by `dash audit`, rule `raw-lock`). The wrappers
//! buy two things:
//!
//! 1. **Poison recovery, single-sourced.** A panicking holder poisons a std
//!    lock; the serving stack's policy since the panic-containment work is
//!    that the data is still structurally valid (every mutation is
//!    complete-before-publish), so waiters recover the guard instead of
//!    propagating the poison. That `unwrap_or_else(PoisonError::into_inner)`
//!    pattern was duplicated ad hoc (`coordinator/batcher.rs`,
//!    `util/threadpool.rs`, `runtime/client.rs`); it now lives here only.
//!    `lock()`/`read()`/`write()` therefore return guards directly, not
//!    `Result`s — there is no error case left to handle at call sites.
//!
//! 2. **Lock-order deadlock detection in instrumented builds.** When
//!    `debug_assertions` are on (all of `cargo test` under this workspace's
//!    dev profile) or the `lock-order` cargo feature is enabled, every
//!    blocking acquisition records an edge `held → wanted` in a process-wide
//!    acquisition-order graph, keyed by lock *instance*. A cycle in that
//!    graph means two threads can interleave into a deadlock even if this
//!    run happened not to; [`lock_order_cycles`] returns every cycle seen so
//!    far, with both acquisition sites (`file:line:col` via
//!    `#[track_caller]`) for every edge. The interleave and chaos suites
//!    assert the graph stays acyclic after full serving runs.
//!
//! In release builds without the feature, the tracking module compiles to
//! unit types and empty inline functions: guards carry a zero-sized token,
//! no thread-local is touched, and the wrappers are a pure passthrough to
//! `std::sync` plus the poison recovery branch (which the happy path never
//! takes). The `sync` entry in `BENCH_executor.json` pins this: wrapped vs
//! raw uncontended throughput must stay within measurement noise.
//!
//! `try_lock` acquisitions record the hold (so later blocking acquisitions
//! under it still get edges) but add no incoming edge themselves: a
//! non-blocking attempt cannot deadlock, whatever order it runs in.

use std::ops::{Deref, DerefMut};
use std::panic::Location;
use std::sync::atomic::AtomicU32;
use std::sync::PoisonError;
use std::time::Duration;

/// A mutual-exclusion lock: `std::sync::Mutex` plus poison recovery and
/// (in instrumented builds) lock-order tracking. See the module docs.
pub struct Mutex<T> {
    /// Lazily-assigned lock-order class id (0 = unassigned). Kept in all
    /// build modes so `new` can stay a `const fn` without cfg'd struct
    /// layouts; release builds never read it.
    #[cfg_attr(
        not(any(debug_assertions, feature = "lock-order")),
        allow(dead_code)
    )]
    id: AtomicU32,
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    /// Creates the lock. `const` so wrappers can back `static`s.
    pub const fn new(value: T) -> Self {
        Mutex { id: AtomicU32::new(0), inner: std::sync::Mutex::new(value) }
    }

    /// Acquires the lock, blocking, recovering from poison.
    #[track_caller]
    pub fn lock(&self) -> MutexGuard<'_, T> {
        let token = order::blocking_acquire(&self.id, Location::caller());
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        MutexGuard { inner, _token: token }
    }

    /// Acquires the lock only if it is free right now. A poisoned-but-free
    /// lock is recovered and counts as acquired.
    #[track_caller]
    pub fn try_lock(&self) -> Option<MutexGuard<'_, T>> {
        use std::sync::TryLockError;
        let inner = match self.inner.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => return None,
        };
        let token = order::try_acquire(&self.id, Location::caller());
        Some(MutexGuard { inner, _token: token })
    }

    /// Whether a holder has panicked while holding the lock. The wrappers
    /// recover from poison transparently; this is observable state for
    /// tests of that recovery.
    pub fn is_poisoned(&self) -> bool {
        self.inner.is_poisoned()
    }

    /// Consumes the lock, returning the value (recovering from poison).
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").field("inner", &self.inner).finish()
    }
}

/// Guard for [`Mutex`]. Releasing it pops the detector's held-lock stack
/// (via the token's drop; the guard itself needs no `Drop` impl, so it can
/// be destructured by [`Condvar::wait_timeout`]).
pub struct MutexGuard<'a, T> {
    inner: std::sync::MutexGuard<'a, T>,
    _token: order::Token,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

/// A reader-writer lock: `std::sync::RwLock` plus poison recovery and
/// (in instrumented builds) lock-order tracking. Readers and writers share
/// one lock-order class: a read→write upgrade attempt while the read guard
/// is still held is itself reported as a self-cycle.
pub struct RwLock<T> {
    #[cfg_attr(
        not(any(debug_assertions, feature = "lock-order")),
        allow(dead_code)
    )]
    id: AtomicU32,
    inner: std::sync::RwLock<T>,
}

impl<T> RwLock<T> {
    /// Creates the lock. `const` so wrappers can back `static`s.
    pub const fn new(value: T) -> Self {
        RwLock { id: AtomicU32::new(0), inner: std::sync::RwLock::new(value) }
    }

    /// Acquires a shared read guard, blocking, recovering from poison.
    #[track_caller]
    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        let token = order::blocking_acquire(&self.id, Location::caller());
        let inner = self.inner.read().unwrap_or_else(PoisonError::into_inner);
        RwLockReadGuard { inner, _token: token }
    }

    /// Acquires the exclusive write guard, blocking, recovering from poison.
    #[track_caller]
    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        let token = order::blocking_acquire(&self.id, Location::caller());
        let inner = self.inner.write().unwrap_or_else(PoisonError::into_inner);
        RwLockWriteGuard { inner, _token: token }
    }

    /// Whether a holder has panicked while holding the write guard.
    pub fn is_poisoned(&self) -> bool {
        self.inner.is_poisoned()
    }
}

impl<T: Default> Default for RwLock<T> {
    fn default() -> Self {
        RwLock::new(T::default())
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RwLock").field("inner", &self.inner).finish()
    }
}

/// Shared guard for [`RwLock`].
pub struct RwLockReadGuard<'a, T> {
    inner: std::sync::RwLockReadGuard<'a, T>,
    _token: order::Token,
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

/// Exclusive guard for [`RwLock`].
pub struct RwLockWriteGuard<'a, T> {
    inner: std::sync::RwLockWriteGuard<'a, T>,
    _token: order::Token,
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

/// Condition variable paired with the wrapper [`Mutex`]: poison on rewake is
/// recovered exactly like a plain acquisition, and the detector's held-lock
/// bookkeeping survives the release/reacquire inside `wait_timeout` (the
/// guard's token is carried across the wait — the lock-order edges recorded
/// when the guard was first taken remain the authoritative ones).
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    /// Creates the condition variable. `const` for `static` pairings.
    pub const fn new() -> Self {
        Condvar { inner: std::sync::Condvar::new() }
    }

    /// Wakes one waiter.
    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    /// Wakes every waiter.
    pub fn notify_all(&self) {
        self.inner.notify_all();
    }

    /// Blocks on the condition for at most `dur`, releasing and reacquiring
    /// the guard's lock. Returns the reacquired guard and whether the wait
    /// timed out (spurious wakeups return `false` exactly as in std).
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let MutexGuard { inner, _token } = guard;
        let (inner, result) = self
            .inner
            .wait_timeout(inner, dur)
            .unwrap_or_else(PoisonError::into_inner);
        (MutexGuard { inner, _token }, result.timed_out())
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

/// One potential deadlock: a cycle in the acquisition-order graph. The
/// report is self-contained text — `locks` lists the instance ids around
/// the cycle, `edges` one human-readable line per edge with both
/// acquisition sites (where the earlier lock was taken and where the later
/// one was requested while it was held).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CycleReport {
    /// Lock-order class ids along the cycle, starting with the edge that
    /// closed it.
    pub locks: Vec<u32>,
    /// One line per edge: `lock #A -> lock #B: #A held at <site>, #B
    /// acquired at <site>`.
    pub edges: Vec<String>,
}

impl std::fmt::Display for CycleReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "potential deadlock across {} locks:", self.locks.len())?;
        for e in &self.edges {
            write!(f, "\n  {e}")?;
        }
        Ok(())
    }
}

/// Every acquisition-order cycle observed so far in this process. Empty in
/// uninstrumented builds (see [`lock_order_enabled`]). Cycles accumulate
/// for the process lifetime; tests with intentional inversions must filter
/// by the ids of their own locks ([`Mutex`]es hand them out via the
/// detector lazily, so two tests never share an id).
pub fn lock_order_cycles() -> Vec<CycleReport> {
    order::cycles()
}

/// Whether the lock-order detector is compiled in (`debug_assertions` or
/// the `lock-order` feature).
pub fn lock_order_enabled() -> bool {
    order::ENABLED
}

#[cfg(any(debug_assertions, feature = "lock-order"))]
mod order {
    //! The instrumented half of the detector. Deliberately uses
    //! `std::sync::Mutex` for its own graph (the tracker must not trace
    //! itself) — `dash audit` allowlists this file for the `raw-lock` rule.

    use std::cell::RefCell;
    use std::collections::{BTreeMap, BTreeSet};
    use std::panic::Location;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::{Mutex as StdMutex, PoisonError};

    use super::CycleReport;

    pub(super) const ENABLED: bool = true;

    /// 0 is reserved for "no class assigned yet" in each lock's slot.
    static NEXT_ID: AtomicU32 = AtomicU32::new(1);

    fn class_of(slot: &AtomicU32) -> u32 {
        let cur = slot.load(Ordering::Relaxed);
        if cur != 0 {
            return cur;
        }
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        match slot.compare_exchange(0, id, Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => id,
            Err(winner) => winner,
        }
    }

    #[derive(Clone, Copy)]
    struct Held {
        id: u32,
        site: &'static Location<'static>,
    }

    thread_local! {
        static HELD: RefCell<Vec<Held>> = RefCell::new(Vec::new());
    }

    /// Where each recorded edge's endpoints were acquired (first sighting
    /// wins; one representative pair of sites per ordered lock pair).
    struct Edge {
        from_site: &'static Location<'static>,
        to_site: &'static Location<'static>,
    }

    #[derive(Default)]
    struct Graph {
        edges: BTreeMap<(u32, u32), Edge>,
        cycles: Vec<CycleReport>,
        /// Normalized (sorted id) cycles already reported, to keep repeat
        /// traversals of a known inversion from flooding the report list.
        seen: BTreeSet<Vec<u32>>,
    }

    static GRAPH: StdMutex<Option<Graph>> = StdMutex::new(None);

    /// Pops this acquisition off the thread's held stack on drop. Carried
    /// by every guard; its drop runs after the std guard's (field order in
    /// the wrappers), i.e. the hold window covers the full critical
    /// section.
    pub(super) struct Token {
        id: u32,
    }

    impl Drop for Token {
        fn drop(&mut self) {
            let id = self.id;
            // try_with: thread-local teardown order during process exit may
            // destroy HELD before a static guard drops; losing the pop then
            // is harmless.
            let _ = HELD.try_with(|h| {
                let mut h = h.borrow_mut();
                if let Some(pos) = h.iter().rposition(|e| e.id == id) {
                    h.remove(pos);
                }
            });
        }
    }

    fn push_held(id: u32, site: &'static Location<'static>) -> Token {
        let _ = HELD.try_with(|h| h.borrow_mut().push(Held { id, site }));
        Token { id }
    }

    /// A blocking acquisition: record `held → wanted` edges for every lock
    /// this thread already holds (checking each new edge for cycles), then
    /// push the hold.
    pub(super) fn blocking_acquire(
        slot: &AtomicU32,
        site: &'static Location<'static>,
    ) -> Token {
        let id = class_of(slot);
        let _ = HELD.try_with(|h| {
            let held = h.borrow();
            if !held.is_empty() {
                record_edges(&held, id, site);
            }
        });
        push_held(id, site)
    }

    /// A non-blocking acquisition: push the hold (so locks taken under it
    /// get edges) but record no incoming edge — `try_lock` cannot deadlock.
    pub(super) fn try_acquire(
        slot: &AtomicU32,
        site: &'static Location<'static>,
    ) -> Token {
        let id = class_of(slot);
        push_held(id, site)
    }

    fn record_edges(held: &[Held], to: u32, to_site: &'static Location<'static>) {
        let mut graph =
            GRAPH.lock().unwrap_or_else(PoisonError::into_inner);
        let g = graph.get_or_insert_with(Graph::default);
        for h in held {
            if h.id == to {
                // Re-acquiring a lock already held by this thread (e.g. an
                // RwLock read→write upgrade) self-deadlocks outright.
                report_cycle(
                    g,
                    vec![to],
                    vec![format!(
                        "lock #{to} -> lock #{to}: held at {}, re-acquired at \
                         {to_site}",
                        h.site
                    )],
                );
                continue;
            }
            if g.edges.contains_key(&(h.id, to)) {
                continue;
            }
            // Adding h.id → to closes a cycle iff `to` already reaches h.id.
            if let Some(path) = find_path(g, to, h.id) {
                let mut locks = vec![h.id, to];
                let mut edges = vec![format!(
                    "lock #{} -> lock #{to}: #{} held at {}, #{to} acquired \
                     at {to_site}",
                    h.id, h.id, h.site
                )];
                for (a, b) in &path {
                    if *b != locks[0] {
                        locks.push(*b);
                    }
                    if let Some(e) = g.edges.get(&(*a, *b)) {
                        edges.push(format!(
                            "lock #{a} -> lock #{b}: #{a} held at {}, #{b} \
                             acquired at {}",
                            e.from_site, e.to_site
                        ));
                    }
                }
                report_cycle(g, locks, edges);
            }
            g.edges.insert(
                (h.id, to),
                Edge { from_site: h.site, to_site },
            );
        }
    }

    fn report_cycle(g: &mut Graph, locks: Vec<u32>, edges: Vec<String>) {
        let mut key = locks.clone();
        key.sort_unstable();
        key.dedup();
        if g.seen.insert(key) {
            g.cycles.push(CycleReport { locks, edges });
        }
    }

    /// Depth-first search for a path `from → … → target` over recorded
    /// edges, returned as the list of edges walked.
    fn find_path(g: &Graph, from: u32, target: u32) -> Option<Vec<(u32, u32)>> {
        let mut stack: Vec<(u32, Vec<(u32, u32)>)> = vec![(from, Vec::new())];
        let mut visited = BTreeSet::new();
        while let Some((node, path)) = stack.pop() {
            if node == target {
                return Some(path);
            }
            if !visited.insert(node) {
                continue;
            }
            for (&(a, b), _) in g.edges.range((node, 0)..=(node, u32::MAX)) {
                let mut next = path.clone();
                next.push((a, b));
                stack.push((b, next));
            }
        }
        None
    }

    pub(super) fn cycles() -> Vec<CycleReport> {
        let graph = GRAPH.lock().unwrap_or_else(PoisonError::into_inner);
        graph.as_ref().map(|g| g.cycles.clone()).unwrap_or_default()
    }
}

#[cfg(not(any(debug_assertions, feature = "lock-order")))]
mod order {
    //! Uninstrumented stub: zero-sized token, no thread-local, no graph.
    //! Everything inlines to nothing.

    use std::panic::Location;
    use std::sync::atomic::AtomicU32;

    use super::CycleReport;

    pub(super) const ENABLED: bool = false;

    pub(super) struct Token;

    #[inline(always)]
    pub(super) fn blocking_acquire(
        _slot: &AtomicU32,
        _site: &'static Location<'static>,
    ) -> Token {
        Token
    }

    #[inline(always)]
    pub(super) fn try_acquire(
        _slot: &AtomicU32,
        _site: &'static Location<'static>,
    ) -> Token {
        Token
    }

    #[inline(always)]
    pub(super) fn cycles() -> Vec<CycleReport> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_round_trips_value() {
        let m = Mutex::new(41);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 42);
        assert_eq!(m.into_inner(), 42);
    }

    #[test]
    fn try_lock_contended_returns_none() {
        let m = Mutex::new(0u8);
        let g = m.lock();
        assert!(m.try_lock().is_none());
        drop(g);
        assert!(m.try_lock().is_some());
    }

    #[test]
    fn poisoned_mutex_recovers_with_state_intact() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        let r = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        assert!(r.is_err());
        assert!(m.is_poisoned());
        assert_eq!(*m.lock(), vec![1, 2, 3]);
    }

    #[test]
    fn poisoned_rwlock_recovers_for_readers_and_writers() {
        let l = Arc::new(RwLock::new(7u32));
        let l2 = Arc::clone(&l);
        let r = std::thread::spawn(move || {
            let _g = l2.write();
            panic!("poison the rwlock");
        })
        .join();
        assert!(r.is_err());
        assert!(l.is_poisoned());
        assert_eq!(*l.read(), 7);
        *l.write() = 8;
        assert_eq!(*l.read(), 8);
    }

    #[test]
    fn condvar_wait_timeout_reacquires_and_times_out() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = m.lock();
        let (g, timed_out) =
            cv.wait_timeout(g, Duration::from_millis(1));
        assert!(timed_out);
        assert!(!*g);
    }

    // Detector semantics. These tests only run meaningfully in
    // instrumented builds; in release-without-feature they degrade to
    // checking that the API shape stays callable and empty.

    fn ids_of(report: &CycleReport) -> Vec<u32> {
        let mut v = report.locks.clone();
        v.sort_unstable();
        v.dedup();
        v
    }

    fn cycles_touching(a: &Mutex<u8>, b: &Mutex<u8>) -> Vec<CycleReport> {
        // Force class assignment without recording edges.
        let (ga, gb) = (a.try_lock(), b.try_lock());
        drop((ga, gb));
        let (ia, ib) = (
            a.id.load(std::sync::atomic::Ordering::Relaxed),
            b.id.load(std::sync::atomic::Ordering::Relaxed),
        );
        let mut want = vec![ia, ib];
        want.sort_unstable();
        lock_order_cycles()
            .into_iter()
            .filter(|c| ids_of(c) == want)
            .collect()
    }

    #[test]
    fn abba_inversion_reports_cycle_with_both_sites() {
        if !lock_order_enabled() {
            assert!(lock_order_cycles().is_empty());
            return;
        }
        let a = Mutex::new(0u8);
        let b = Mutex::new(0u8);
        {
            let _ga = a.lock();
            let _gb = b.lock(); // edge a -> b
        }
        {
            let _gb = b.lock();
            let _ga = a.lock(); // edge b -> a: closes the cycle
        }
        let found = cycles_touching(&a, &b);
        assert_eq!(found.len(), 1, "exactly one ABBA cycle reported");
        let report = &found[0];
        assert_eq!(report.edges.len(), 2, "both edges in the report");
        for edge in &report.edges {
            assert!(
                edge.contains("sync.rs"),
                "acquisition sites point into this file: {edge}"
            );
        }
        let text = report.to_string();
        assert!(text.contains("potential deadlock"));
    }

    #[test]
    fn consistent_nesting_stays_silent() {
        let a = Mutex::new(0u8);
        let b = Mutex::new(0u8);
        for _ in 0..3 {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        assert!(
            cycles_touching(&a, &b).is_empty(),
            "same-order nesting must not report"
        );
    }

    #[test]
    fn abba_dedupes_repeat_traversals() {
        if !lock_order_enabled() {
            return;
        }
        let a = Mutex::new(0u8);
        let b = Mutex::new(0u8);
        for _ in 0..4 {
            {
                let _ga = a.lock();
                let _gb = b.lock();
            }
            {
                let _gb = b.lock();
                let _ga = a.lock();
            }
        }
        assert_eq!(cycles_touching(&a, &b).len(), 1, "one report per cycle");
    }

    #[test]
    fn try_lock_records_no_inversion_edge() {
        if !lock_order_enabled() {
            return;
        }
        let a = Mutex::new(0u8);
        let b = Mutex::new(0u8);
        {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        {
            let _gb = b.lock();
            let _ga = a.try_lock(); // non-blocking: cannot deadlock
        }
        assert!(cycles_touching(&a, &b).is_empty());
    }

    #[test]
    fn rwlock_upgrade_under_read_is_reported() {
        if !lock_order_enabled() {
            return;
        }
        let l = Arc::new(RwLock::new(0u8));
        // Two concurrent readers are fine, so this does not deadlock the
        // test itself — but the same-class re-acquisition is exactly the
        // pattern that deadlocks against a queued writer.
        let g = l.read();
        let g2 = l.read();
        drop((g, g2));
        let id = l.id.load(std::sync::atomic::Ordering::Relaxed);
        let hit = lock_order_cycles()
            .into_iter()
            .any(|c| c.locks == vec![id]);
        assert!(hit, "read-under-read on one thread reports a self-cycle");
    }

    #[test]
    fn three_lock_rotation_reports_cycle() {
        if !lock_order_enabled() {
            return;
        }
        let a = Mutex::new(0u8);
        let b = Mutex::new(0u8);
        let c = Mutex::new(0u8);
        {
            let _g1 = a.lock();
            let _g2 = b.lock();
        }
        {
            let _g1 = b.lock();
            let _g2 = c.lock();
        }
        {
            let _g1 = c.lock();
            let _g2 = a.lock(); // a->b->c->a
        }
        let ids: Vec<u32> = [&a, &b, &c]
            .iter()
            .map(|m| m.id.load(std::sync::atomic::Ordering::Relaxed))
            .collect();
        let mut want = ids.clone();
        want.sort_unstable();
        let hit = lock_order_cycles().into_iter().any(|r| ids_of(&r) == want);
        assert!(hit, "three-lock rotation closes a cycle");
    }
}
