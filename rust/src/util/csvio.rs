//! Tiny CSV reader/writer for experiment output and dataset persistence.
//!
//! Handles quoting (RFC-4180 style: fields containing `,`, `"` or newlines
//! are wrapped in double quotes, embedded quotes doubled).

use std::fs;
use std::io::{self, Write as _};
use std::path::Path;

/// An in-memory CSV table: a header row plus data rows of equal width.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row; panics if the width disagrees with the header (a
    /// programming error in the experiment drivers).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "csv row width mismatch");
        self.rows.push(row);
    }

    /// Append a row of floats formatted via [`crate::util::fmt_f64`].
    pub fn push_f64(&mut self, row: &[f64]) {
        self.push(row.iter().map(|v| crate::util::fmt_f64(*v)).collect());
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// All values of a named column parsed as f64 (NaN on parse failure).
    pub fn col_f64(&self, name: &str) -> Vec<f64> {
        let Some(i) = self.col(name) else { return Vec::new() };
        self.rows.iter().map(|r| r[i].parse::<f64>().unwrap_or(f64::NAN)).collect()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }

    pub fn parse(text: &str) -> Result<CsvTable, String> {
        let mut rows = parse_rows(text)?;
        if rows.is_empty() {
            return Err("empty csv".into());
        }
        let header = rows.remove(0);
        for (i, r) in rows.iter().enumerate() {
            if r.len() != header.len() {
                return Err(format!(
                    "row {} has {} fields, header has {}",
                    i + 1,
                    r.len(),
                    header.len()
                ));
            }
        }
        Ok(CsvTable { header, rows })
    }

    pub fn load(path: &Path) -> Result<CsvTable, String> {
        let text = fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::parse(&text)
    }

    /// Render as an aligned plain-text table (for terminal reports).
    pub fn to_pretty(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:>w$}", c, w = widths[i]));
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }
}

fn needs_quoting(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_row(out: &mut String, row: &[String]) {
    for (i, field) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(field) {
            out.push('"');
            out.push_str(&field.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(field);
        }
    }
    out.push('\n');
}

fn parse_rows(text: &str) -> Result<Vec<Vec<String>>, String> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;
    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quote".into());
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_simple() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push(vec!["1".into(), "x".into()]);
        t.push(vec!["2".into(), "y".into()]);
        let s = t.to_string();
        assert_eq!(CsvTable::parse(&s).unwrap(), t);
    }

    #[test]
    fn round_trip_quoted() {
        let mut t = CsvTable::new(&["name", "val"]);
        t.push(vec!["has,comma".into(), "has\"quote".into()]);
        t.push(vec!["has\nnewline".into(), "plain".into()]);
        let s = t.to_string();
        assert_eq!(CsvTable::parse(&s).unwrap(), t);
    }

    #[test]
    fn width_mismatch_rejected() {
        assert!(CsvTable::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn col_accessors() {
        let t = CsvTable::parse("k,val\n1,0.5\n2,0.75\n").unwrap();
        assert_eq!(t.col("val"), Some(1));
        assert_eq!(t.col_f64("val"), vec![0.5, 0.75]);
        assert!(t.col("nope").is_none());
        assert!(t.col_f64("nope").is_empty());
    }

    #[test]
    fn no_trailing_newline_ok() {
        let t = CsvTable::parse("a,b\n1,2").unwrap();
        assert_eq!(t.rows, vec![vec!["1".to_string(), "2".to_string()]]);
    }

    #[test]
    fn crlf_ok() {
        let t = CsvTable::parse("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn pretty_renders() {
        let t = CsvTable::parse("algo,value\ndash,0.9\ngreedy,0.91\n").unwrap();
        let p = t.to_pretty();
        assert!(p.contains("dash"));
        assert!(p.lines().count() >= 4);
    }

    #[test]
    fn save_load(){
        let mut t = CsvTable::new(&["x"]);
        t.push_f64(&[1.25]);
        let p = std::env::temp_dir().join("dash_select_csv_test.csv");
        t.save(&p).unwrap();
        assert_eq!(CsvTable::load(&p).unwrap(), t);
        let _ = std::fs::remove_file(&p);
    }
}
