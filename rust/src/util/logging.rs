//! Leveled stderr logger with a process-global level, no deps.
//!
//! Controlled by `DASH_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Experiment drivers default to `info`;
//! tests stay quiet at `warn`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn from_u8(raw: u8) -> Level {
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => " WARN",
            Level::Info => " INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != u8::MAX {
        return Level::from_u8(raw);
    }
    let lvl = std::env::var("DASH_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Warn);
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l <= current_level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[dash {}] {}", l.tag().trim(), args);
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Warn);
    }
}
