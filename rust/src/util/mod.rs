//! General-purpose substrates built from scratch (the image is offline and
//! ships no general crates): JSON, CSV, timing, logging, poison-recovering
//! lock wrappers with lock-order deadlock detection, a thread pool with
//! parallel-map, a progress meter, and a miniature property-testing harness.

pub mod json;
pub mod csvio;
pub mod timer;
pub mod logging;
pub mod sync;
pub mod threadpool;
pub mod progress;
pub mod proptest;

pub use json::Json;
pub use timer::Timer;
pub use threadpool::ThreadPool;

/// Format a float compactly for tables (trims trailing zeros, 4 sig decimals).
pub fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        return "nan".into();
    }
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 1e6 || a < 1e-4 {
        format!("{v:.3e}")
    } else {
        let s = format!("{v:.4}");
        let s = s.trim_end_matches('0').trim_end_matches('.');
        s.to_string()
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for fewer than 2 entries).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// `p`-quantile (linear interpolation) of an unsorted slice.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_trims() {
        assert_eq!(fmt_f64(1.5), "1.5");
        assert_eq!(fmt_f64(0.0), "0");
        assert_eq!(fmt_f64(2.0), "2");
    }

    #[test]
    fn fmt_extremes_scientific() {
        assert!(fmt_f64(1.23e9).contains('e'));
        assert!(fmt_f64(1.23e-9).contains('e'));
        assert_eq!(fmt_f64(f64::NAN), "nan");
    }

    #[test]
    fn mean_stddev_quantile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487358056).abs() < 1e-12);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert!(quantile(&[], 0.5).is_nan());
    }
}
