//! A small fixed-size thread pool with a scoped `parallel_map`, built on
//! `std::thread` and channels (tokio is unavailable offline).
//!
//! The oracle layer uses this to evaluate independent marginal-gain queries
//! concurrently — the "polynomially many queries per adaptive round" of the
//! paper's adaptivity model. On a single-core testbed the pool degrades to
//! near-sequential execution; round/query accounting (what the paper
//! actually measures) is unaffected.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("dash-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped -> shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// Pool sized to the machine (`available_parallelism`), or `DASH_THREADS`.
    pub fn default_size() -> usize {
        if let Ok(v) = std::env::var("DASH_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Apply `f` to `0..n`, writing results in index order. Blocks until all
    /// chunks complete. `f` must be `Sync` (shared across workers).
    ///
    /// Work is split into `size * 4` contiguous chunks for load balancing.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Default + Clone + 'static,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let mut out = vec![T::default(); n];
        let chunks = (self.size * 4).min(n).max(1);
        let chunk_len = n.div_ceil(chunks);
        let pending = AtomicUsize::new(0);
        let (done_tx, done_rx) = channel::<()>();

        // SAFETY-free scoped execution: we use std::thread::scope so borrows
        // of `f` and `out` are statically guaranteed to outlive the workers.
        // The pool's own threads are used only through `execute`, which
        // requires 'static; for borrowed closures we spawn scoped threads
        // directly, bounded by pool size.
        std::thread::scope(|scope| {
            let out_ptr = SendPtr(out.as_mut_ptr());
            let f = &f;
            let mut spawned = 0usize;
            for c in 0..chunks {
                let start = c * chunk_len;
                if start >= n {
                    break;
                }
                let end = (start + chunk_len).min(n);
                pending.fetch_add(1, Ordering::SeqCst);
                let done_tx = done_tx.clone();
                let pending_ref = &pending;
                let out_ptr = out_ptr;
                if spawned < self.size.saturating_sub(1) {
                    spawned += 1;
                    scope.spawn(move || {
                        // rebind the wrapper: edition-2021 disjoint capture
                        // would otherwise capture the raw-pointer field
                        // directly, which is !Send
                        let out_ptr = out_ptr;
                        for i in start..end {
                            let v = f(i);
                            // SAFETY: each index i is written by exactly one
                            // chunk; chunks are disjoint; `out` outlives scope.
                            unsafe { *out_ptr.0.add(i) = v };
                        }
                        pending_ref.fetch_sub(1, Ordering::SeqCst);
                        let _ = done_tx.send(());
                    });
                } else {
                    // run remaining chunks inline to avoid oversubscription
                    for i in start..end {
                        let v = f(i);
                        unsafe { *out_ptr.0.add(i) = v };
                    }
                    pending.fetch_sub(1, Ordering::SeqCst);
                    let _ = done_tx.send(());
                }
            }
            drop(done_tx);
            while pending.load(Ordering::SeqCst) > 0 {
                if done_rx.recv().is_err() {
                    break;
                }
            }
        });
        out
    }
}

struct SendPtr<T>(*mut T);
// manual impls: derive would add a spurious `T: Copy` bound
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: used only for disjoint index writes inside thread::scope.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Convenience: one-shot parallel map with a temporary default-size pool.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone + 'static,
    F: Fn(usize) -> T + Sync,
{
    ThreadPool::new(ThreadPool::default_size()).parallel_map(n, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn execute_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_order_and_values() {
        let pool = ThreadPool::new(3);
        let out = pool.parallel_map(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let pool = ThreadPool::new(2);
        assert!(pool.parallel_map(0, |i| i).is_empty());
        assert_eq!(pool.parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn parallel_map_borrowed_state() {
        let data: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map(1000, |i| data[i] * 2.0);
        assert_eq!(out[999], 1998.0);
    }

    #[test]
    fn pool_size_floor() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.parallel_map(5, |i| i), vec![0, 1, 2, 3, 4]);
    }
}
