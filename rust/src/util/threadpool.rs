//! A small fixed-size thread pool with scoped parallel maps, built on
//! `std::thread` and channels (tokio is unavailable offline).
//!
//! The oracle layer's [`BatchExecutor`](crate::oracle::BatchExecutor) uses
//! this to evaluate independent marginal-gain queries concurrently — the
//! "polynomially many queries per adaptive round" of the paper's adaptivity
//! model. Two dispatch primitives:
//!
//! - [`ThreadPool::scoped_map`] — runs a *borrowed* closure over `0..n` on
//!   the pool's **persistent workers** (no thread spawn per call). The
//!   caller participates by draining queued jobs while it waits, so a
//!   saturated — or even nested — pool still makes progress.
//! - [`ThreadPool::parallel_map`] — the original convenience wrapper,
//!   now a thin delegation to `scoped_map`.
//!
//! On a single-core testbed both degrade to sequential execution;
//! round/query accounting (what the paper actually measures) is unaffected.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::sync::{Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Dropping the pool joins all workers.
///
/// `Sync`: the job sender is mutex-wrapped so one pool instance can be
/// shared (e.g. `Arc<ThreadPool>` owned by the coordinator's leader and
/// used by every served job) instead of each call site spawning threads.
pub struct ThreadPool {
    tx: Option<Mutex<Sender<Job>>>,
    /// shared with workers; `scoped_map` callers drain it while waiting
    rx: Arc<Mutex<Receiver<Job>>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    ///
    /// The spawn expect is a fatal startup invariant (allowlisted in
    /// `audit.allow`): a process that cannot create its worker threads has
    /// no degraded mode to fall back to.
    #[allow(clippy::expect_used)]
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("dash-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().recv() };
                        match job {
                            Ok(job) => run_job(job),
                            Err(_) => break, // sender dropped -> shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(Mutex::new(tx)), rx, workers, size }
    }

    /// Pool sized to the machine (`available_parallelism`), or `DASH_THREADS`.
    pub fn default_size() -> usize {
        if let Ok(v) = std::env::var("DASH_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget job.
    ///
    /// The expects are pool-internal fatal invariants (allowlisted in
    /// `audit.allow`): the sender outlives every `&self` caller by
    /// construction, and the worker receiver is only dropped in
    /// `ThreadPool::drop` after this handle is gone.
    #[allow(clippy::expect_used)]
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .lock()
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Apply `f` to `0..n` on the persistent workers, writing results in
    /// index order. Blocks until all chunks complete; panics if any chunk
    /// panicked. `f` may borrow caller state (`Sync` suffices) — the
    /// completion barrier guarantees no borrow outlives this call.
    ///
    /// Work is split into `size * 4` contiguous chunks for load balancing.
    /// While waiting, the caller drains the job queue itself, so calling
    /// `scoped_map` from inside a pool job cannot deadlock.
    ///
    /// The `panic!` re-raise and the completion expect are the documented
    /// propagation contract (allowlisted in `audit.allow`): a panicking
    /// chunk must panic the *caller*, never be swallowed into a partial
    /// result vector.
    #[allow(clippy::expect_used)]
    pub fn scoped_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.size <= 1 || n == 1 {
            return (0..n).map(&f).collect();
        }

        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let chunks = (self.size * 4).min(n).max(1);
        let chunk_len = n.div_ceil(chunks);

        // (completed chunk count, wakeup) + sticky panic flag
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));

        let out_ptr = SendPtr(out.as_mut_ptr());
        // SAFETY: lifetime erasure to ship the borrowed closure through the
        // 'static job channel. Sound because the barrier below does not
        // return until every dispatched chunk has run (or recorded a
        // panic), so the erased borrows of `f` and `out` never dangle.
        let f_obj: &(dyn Fn(usize) -> T + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) -> T + Sync) =
            unsafe { std::mem::transmute(f_obj) };

        let mut dispatched = 0usize;
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk_len).min(n);
            dispatched += 1;
            let done = Arc::clone(&done);
            let panicked = Arc::clone(&panicked);
            let out_ptr = out_ptr;
            self.execute(move || {
                // rebind the wrapper: edition-2021 disjoint capture would
                // otherwise capture the raw-pointer field directly (!Send)
                let out_ptr = out_ptr;
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    for i in start..end {
                        let v = f_static(i);
                        // SAFETY: each index i is written by exactly one
                        // chunk; chunks are disjoint; `out` outlives the
                        // barrier.
                        unsafe { *out_ptr.0.add(i) = Some(v) };
                    }
                }));
                if r.is_err() {
                    panicked.store(true, Ordering::SeqCst);
                }
                let (lock, cvar) = &*done;
                *lock.lock() += 1;
                cvar.notify_all();
            });
            start = end;
        }

        // Barrier with queue-draining: run pending jobs (ours or other
        // callers') instead of idling, then sleep briefly when none are
        // grabbable. `try_lock` (not `lock`): an *idle* worker parks inside
        // `recv()` while holding the rx mutex, and blocking on it here
        // would trade the condvar wait for a mutex wait — an idle worker
        // also means the queue will drain without our help.
        loop {
            if *done.0.lock() >= dispatched {
                break;
            }
            let job = match self.rx.try_lock() {
                Some(rx) => rx.try_recv().ok(),
                None => None,
            };
            match job {
                Some(job) => run_job(job),
                None => {
                    let (lock, cvar) = &*done;
                    let completed = lock.lock();
                    if *completed >= dispatched {
                        break;
                    }
                    // the wrapper Condvar recovers a poisoned rewake too:
                    // that was the one spot that used to panic the *drain*
                    // path
                    let _ =
                        cvar.wait_timeout(completed, Duration::from_millis(1));
                }
            }
        }

        if panicked.load(Ordering::SeqCst) {
            panic!("scoped_map: worker job panicked");
        }
        out.into_iter()
            .map(|v| v.expect("scoped_map chunk completed"))
            .collect()
    }

    /// Scratch-carrying variant of [`ThreadPool::scoped_map`]: `init`
    /// builds one scratch value per dispatched chunk (on the worker that
    /// runs it), and `f` receives it mutably alongside each index, so a
    /// chunk's iterations reuse one arena instead of allocating per call.
    /// Results are returned in index order, exactly as `scoped_map`.
    ///
    /// The oracle engine's zero-clone sweep path is the primary caller:
    /// indices are candidate blocks, the scratch is a
    /// [`SweepScratch`](crate::objectives::SweepScratch), and the shared
    /// objective state is only ever borrowed.
    pub fn scoped_map_with<T, S, I, F>(&self, n: usize, init: I, f: F) -> Vec<T>
    where
        T: Send + 'static,
        I: Fn() -> S + Sync,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.size <= 1 || n == 1 {
            let mut scratch = init();
            return (0..n).map(|i| f(i, &mut scratch)).collect();
        }
        let chunks = (self.size * 4).min(n).max(1);
        let chunk_len = n.div_ceil(chunks);
        let nchunks = n.div_ceil(chunk_len);
        let parts: Vec<Vec<T>> = self.scoped_map(nchunks, |c| {
            let lo = c * chunk_len;
            let hi = ((c + 1) * chunk_len).min(n);
            let mut scratch = init();
            (lo..hi).map(|i| f(i, &mut scratch)).collect()
        });
        let mut out = Vec::with_capacity(n);
        for p in parts {
            out.extend(p);
        }
        debug_assert_eq!(out.len(), n);
        out
    }

    /// Alias of [`ThreadPool::scoped_map`] kept for the original call
    /// sites' naming.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Sync,
    {
        self.scoped_map(n, f)
    }
}

/// Run one job, containing any panic to this job (a panicking job must not
/// kill a worker — later scoped_map barriers depend on every worker
/// surviving).
fn run_job(job: Job) {
    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
        crate::log_warn!("thread-pool job panicked");
    }
}

struct SendPtr<T>(*mut T);
// manual impls: derive would add a spurious `T: Copy` bound
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: used only for disjoint index writes guarded by scoped_map's
// completion barrier.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Convenience: one-shot parallel map with a temporary default-size pool.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Sync,
{
    ThreadPool::new(ThreadPool::default_size()).parallel_map(n, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn execute_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_order_and_values() {
        let pool = ThreadPool::new(3);
        let out = pool.parallel_map(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let pool = ThreadPool::new(2);
        assert!(pool.parallel_map(0, |i| i).is_empty());
        assert_eq!(pool.parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn parallel_map_borrowed_state() {
        let data: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map(1000, |i| data[i] * 2.0);
        assert_eq!(out[999], 1998.0);
    }

    #[test]
    fn pool_size_floor() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.parallel_map(5, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn scoped_map_non_default_type() {
        // Box<usize> is neither Default-returning-useful nor Clone-cheap;
        // scoped_map must not require either
        let pool = ThreadPool::new(3);
        let out = pool.scoped_map(64, |i| Box::new(i * 3));
        for (i, v) in out.iter().enumerate() {
            assert_eq!(**v, i * 3);
        }
    }

    #[test]
    fn scoped_map_reuses_pool_across_calls() {
        let pool = ThreadPool::new(4);
        for round in 0..20 {
            let out = pool.scoped_map(100, |i| i + round);
            assert_eq!(out[99], 99 + round);
        }
    }

    #[test]
    fn scoped_map_is_sync_shareable() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<ThreadPool>();
        let pool = Arc::new(ThreadPool::new(4));
        let mut handles = Vec::new();
        for t in 0..4 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let out = p.scoped_map(200, |i| i * t);
                assert_eq!(out[199], 199 * t);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn nested_scoped_map_makes_progress() {
        // a job on the pool dispatches another scoped_map onto the same
        // pool; the caller-drains-queue barrier must prevent deadlock
        let pool = Arc::new(ThreadPool::new(2));
        let p2 = Arc::clone(&pool);
        let outer = pool.scoped_map(4, move |i| {
            let inner = p2.scoped_map(8, |j| j + i);
            inner.iter().sum::<usize>()
        });
        for (i, v) in outer.iter().enumerate() {
            assert_eq!(*v, 28 + 8 * i);
        }
    }

    #[test]
    fn scoped_map_with_reuses_scratch_per_chunk() {
        let pool = ThreadPool::new(3);
        let inits = Arc::new(AtomicU64::new(0));
        let i2 = Arc::clone(&inits);
        let out = pool.scoped_map_with(
            97,
            move || {
                i2.fetch_add(1, Ordering::SeqCst);
                Vec::<usize>::new()
            },
            |i, scratch| {
                scratch.push(i); // scratch persists across a chunk's indices
                i * 2 + scratch.len().min(1) // = i*2 + 1 always
            },
        );
        assert_eq!(out.len(), 97);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2 + 1);
        }
        // one scratch per dispatched chunk, bounded by size*4
        let n_inits = inits.load(Ordering::SeqCst) as usize;
        assert!(n_inits >= 1 && n_inits <= 12, "{n_inits} inits");
    }

    #[test]
    fn scoped_map_with_sequential_degenerate() {
        let pool = ThreadPool::new(1);
        let out = pool.scoped_map_with(5, || 10usize, |i, s| i + *s);
        assert_eq!(out, vec![10, 11, 12, 13, 14]);
        assert!(pool.scoped_map_with(0, || (), |i, _| i).is_empty());
    }

    #[test]
    #[should_panic(expected = "scoped_map: worker job panicked")]
    fn scoped_map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.scoped_map(16, |i| {
            if i == 7 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn pool_recovers_poisoned_lock_guards() {
        // regression: a thread panicking while holding a pool mutex used
        // to cascade `PoisonError` panics into every later caller via the
        // barrier's `cvar.wait_timeout(..).unwrap()` — one contained
        // worker fault became a wedged drain path
        let pool = Arc::new(ThreadPool::new(2));
        let p = Arc::clone(&pool);
        let _ = std::thread::spawn(move || {
            let _guard = p.tx.as_ref().expect("pool live").lock();
            panic!("poison the sender mutex");
        })
        .join();
        assert!(
            pool.tx.as_ref().expect("pool live").is_poisoned(),
            "mutex must be poisoned for the regression to bite"
        );
        // dispatch and the completion barrier must recover the guards
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(pool.scoped_map(8, |i| i), (0..8).collect::<Vec<usize>>());
    }

    #[test]
    fn pool_survives_job_panic() {
        let pool = ThreadPool::new(2);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = pool.scoped_map(4, |_| -> usize { panic!("x") });
        }));
        // workers must still serve new work
        assert_eq!(pool.scoped_map(3, |i| i), vec![0, 1, 2]);
    }
}
