//! Wall-clock timing helpers used by the experiment harness and benches.

use std::time::{Duration, Instant};

/// A simple stopwatch with split support.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
    last_split: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

impl Timer {
    pub fn start() -> Self {
        let now = Instant::now();
        Timer { start: now, last_split: now }
    }

    /// Seconds since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Seconds since the previous `split()` (or construction), and resets
    /// the split point.
    pub fn split_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last_split).as_secs_f64();
        self.last_split = now;
        dt
    }

    pub fn reset(&mut self) {
        let now = Instant::now();
        self.start = now;
        self.last_split = now;
    }
}

/// Time a closure; returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Human-readable duration: "532ms", "2.41s", "3m12s".
pub fn fmt_duration_s(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.0}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.0}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        let m = (s / 60.0).floor();
        format!("{}m{:02.0}s", m as u64, s - m * 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let a = t.split_s();
        assert!(a > 0.0);
        let b = t.elapsed_s();
        assert!(b >= a);
        t.reset();
        assert!(t.elapsed_s() < b);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration_s(0.0000005), "0us");
        assert_eq!(fmt_duration_s(0.5), "500ms");
        assert_eq!(fmt_duration_s(2.5), "2.50s");
        assert_eq!(fmt_duration_s(200.0), "3m20s");
    }
}
