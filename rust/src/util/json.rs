//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`) written by the
//! python AOT pipeline, and for machine-readable experiment reports. Supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null); numbers are parsed as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for test golden files.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`Json::parse`], with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly (no spaces).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // -0.0 must keep its sign ("-0" is a valid JSON number and
                // parses back to -0.0) so floats round-trip bit-exactly
                if n.fract() == 0.0 && n.abs() < 9e15 && !n.is_sign_negative() {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors (return None on type mismatch) ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Largest integer JSON can carry faithfully: from 2^53 upward the
    /// f64 parse may already have rounded the written digits (2^53 + 1
    /// parses to exactly 2^53), so the typed integer accessors refuse
    /// rather than silently return a neighbor. Exclusive at 2^53.
    const MAX_EXACT_INT: f64 = 9007199254740991.0; // 2^53 - 1

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n >= 0.0 && n <= Self::MAX_EXACT_INT {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// Non-negative integer as u64, exact or nothing: values above 2^53
    /// are rejected (`None`) because the f64 representation can no longer
    /// prove what the sender wrote.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n >= 0.0 && n <= Self::MAX_EXACT_INT {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` if not an object or key absent.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- builders ----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (manifest never emits them);
                            // replace with U+FFFD rather than erroring.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unterminated string"))?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-4e2").unwrap(), Json::Num(-400.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn round_trip_compact_and_pretty() {
        let src = r#"{"m":[{"d":512,"name":"lreg","n":256}],"v":1}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escapes_round_trip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse("\"héllo → λ\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → λ");
        let esc = Json::parse("\"\\u03bb\"").unwrap();
        assert_eq!(esc.as_str().unwrap(), "λ");
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 5, "f": 1.5, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(5));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        // up to 2^53 - 1 the mapping is provably exact; at 2^53 a written
        // neighbor (2^53 + 1) would already have rounded onto it, so the
        // accessors refuse from there on instead of silently substituting
        assert_eq!(Json::Num(9007199254740991.0).as_u64(), Some(9007199254740991));
        assert_eq!(Json::Num(9007199254740992.0).as_u64(), None);
        assert_eq!(Json::Num(9007199254740994.0).as_u64(), None);
        assert_eq!(Json::Num(9007199254740992.0).as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
        assert_eq!(v.get("s").unwrap().as_f64(), None);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(42.5).to_string_compact(), "42.5");
        assert_eq!(Json::Num(-42.0).to_string_compact(), "-42");
    }

    #[test]
    fn negative_zero_round_trips_bit_exactly() {
        let s = Json::Num(-0.0).to_string_compact();
        assert_eq!(s, "-0");
        match Json::parse(&s).unwrap() {
            Json::Num(n) => assert_eq!(n.to_bits(), (-0.0f64).to_bits()),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![("xs", Json::arr_f64(&[1.0, 2.0])), ("k", 3usize.into())]);
        assert_eq!(v.get("k").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 2);
    }
}
