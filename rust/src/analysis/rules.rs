//! The project-invariant lints. Each rule scans one file's
//! [`MaskedSource`](super::lexer::MaskedSource) (comments and literals
//! already blanked) and reports [`Violation`]s; the allowlist layer in
//! [`super::allowlist`] decides which survive.
//!
//! | rule | scope | invariant |
//! |------|-------|-----------|
//! | `no-panic` | `rust/src/`, outside `#[cfg(test)]` | no `.unwrap()` / `.expect()` / `panic!` / `todo!` / `unreachable!` — library code answers with `SelectError`, it does not abort a serving thread |
//! | `unsafe-code` | everywhere | `unsafe` only in files on the `unsafe-file` allowlist, and every such line carries a `// SAFETY:` comment on it or within the 8 lines above |
//! | `raw-lock` | everywhere but `util/sync.rs` | no `std::sync::Mutex`/`RwLock`/`Condvar`/guards/`PoisonError` — locks go through the poison-recovering, order-tracked `util::sync` wrappers |
//! | `lock-unwrap` | everywhere | no `.lock().unwrap()` / `.read().expect(…)` etc., even in tests — a poisoned lock must recover, not cascade |
//! | `wire-sorted-keys` | wire-codec files | no hand-assembled JSON object literals — frames are emitted via `util::json::Json`, whose `BTreeMap` keeps keys sorted (the byte-identity contract) |
//!
//! Matching runs on whitespace-squeezed text with a per-byte line map, so
//! a call chain split across lines (`.write()\n    .unwrap()`) is still
//! one match, reported at the line the chain starts on.

use super::lexer::{mask, squeeze, MaskedSource, Squeezed};
use std::collections::BTreeSet;

/// Rule names (also the first token of `allow` entries in `audit.allow`).
pub const NO_PANIC: &str = "no-panic";
pub const UNSAFE_CODE: &str = "unsafe-code";
pub const RAW_LOCK: &str = "raw-lock";
pub const LOCK_UNWRAP: &str = "lock-unwrap";
pub const WIRE_SORTED_KEYS: &str = "wire-sorted-keys";

/// Files whose string literals must not hand-assemble JSON frames.
pub const WIRE_FILES: &[&str] = &[
    "rust/src/coordinator/wire.rs",
    "rust/src/coordinator/net.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/store.rs",
];

/// The one module allowed to name raw `std::sync` lock types.
pub const SYNC_WRAPPER_FILE: &str = "rust/src/util/sync.rs";

/// One finding: `file:line`, the rule, a human message, and the trimmed
/// source line (the allowlist matches needles against the raw line).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub excerpt: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}\n    {}",
            self.file, self.line, self.rule, self.message, self.excerpt
        )
    }
}

/// Scan one file. `rel` is the repo-relative path with forward slashes;
/// `unsafe_files` is the set of `unsafe-file` allowlist paths.
pub fn scan_file(
    rel: &str,
    source: &str,
    unsafe_files: &BTreeSet<String>,
) -> Vec<Violation> {
    let masked = mask(source);
    let sq = squeeze(&masked.masked);
    let raw_lines: Vec<&str> = source.lines().collect();
    let mut out = Vec::new();

    let mut report = |rule: &'static str, line: usize, message: String| {
        let excerpt = raw_lines
            .get(line.saturating_sub(1))
            .map(|l| truncate(l.trim()))
            .unwrap_or_default();
        Violation { rule, file: rel.to_string(), line, message, excerpt }
    };

    // ---- lock-unwrap: everywhere, tests included -------------------------
    const GUARD_CALLS: &[&str] = &[".lock()", ".read()", ".write()", ".try_lock()"];
    for guard in GUARD_CALLS {
        for tail in &[".unwrap()", ".expect("] {
            let pat = format!("{guard}{tail}");
            for at in find_all(&sq.text, &pat) {
                out.push(report(
                    LOCK_UNWRAP,
                    sq.lines[at],
                    format!(
                        "`{pat}` — wrapper locks recover poison and return \
                         guards directly; use crate::util::sync"
                    ),
                ));
            }
        }
    }

    // ---- no-panic: rust/src only, outside #[cfg(test)] -------------------
    if rel.starts_with("rust/src/") {
        for pat in &[".unwrap()", ".expect("] {
            for at in find_all(&sq.text, pat) {
                if masked.in_test(sq.lines[at]) {
                    continue;
                }
                // already reported by lock-unwrap above
                if GUARD_CALLS.iter().any(|g| sq.text[..at].ends_with(g)) {
                    continue;
                }
                out.push(report(
                    NO_PANIC,
                    sq.lines[at],
                    format!(
                        "`{pat}` in non-test library code — return a \
                         SelectError (or restructure so the case cannot \
                         arise)"
                    ),
                ));
            }
        }
        for mac in &["panic!(", "todo!(", "unreachable!("] {
            for at in find_all(&sq.text, mac) {
                if masked.in_test(sq.lines[at]) || !boundary_before(&sq.text, at) {
                    continue;
                }
                out.push(report(
                    NO_PANIC,
                    sq.lines[at],
                    format!(
                        "`{mac})` in non-test library code — a serving \
                         thread must answer, not abort"
                    ),
                ));
            }
        }
    }

    // ---- unsafe-code: everywhere ----------------------------------------
    for line in unsafe_lines(&masked) {
        if !unsafe_files.contains(rel) {
            out.push(report(
                UNSAFE_CODE,
                line,
                "`unsafe` outside the audited unsafe-file allowlist".into(),
            ));
            continue;
        }
        if !has_safety_comment(&masked, line) {
            out.push(report(
                UNSAFE_CODE,
                line,
                "`unsafe` without a `// SAFETY:` comment on the line or \
                 within the 8 lines above"
                    .into(),
            ));
        }
    }

    // ---- raw-lock: everywhere but the wrapper module ---------------------
    if rel != SYNC_WRAPPER_FILE {
        scan_raw_lock(&sq, &mut out, &mut report);
    }

    // ---- wire-sorted-keys: wire-codec files ------------------------------
    if WIRE_FILES.contains(&rel) {
        for (line, content) in &masked.strings {
            if masked.in_test(*line) {
                continue;
            }
            // literal contents keep their escape bytes, so a JSON object
            // opener is spelled `{"` in raw strings and `{\"` in ordinary
            // ones — match both
            if content.contains("{\"") || content.contains("{\\\"") {
                out.push(report(
                    WIRE_SORTED_KEYS,
                    *line,
                    "hand-assembled JSON object literal in a wire-codec \
                     file — emit frames via util::json::Json, whose BTreeMap \
                     keeps keys sorted (the byte-identity contract)"
                        .into(),
                ));
            }
        }
    }

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// `std::sync` lock types that must not appear outside the wrapper module.
const BANNED_SYNC: &[&str] = &[
    "Mutex",
    "MutexGuard",
    "RwLock",
    "RwLockReadGuard",
    "RwLockWriteGuard",
    "Condvar",
    "PoisonError",
];

fn scan_raw_lock(
    sq: &Squeezed,
    out: &mut Vec<Violation>,
    report: &mut impl FnMut(&'static str, usize, String) -> Violation,
) {
    let msg = |tok: &str| {
        format!(
            "raw `std::sync::{tok}` — use the poison-recovering, \
             order-tracked crate::util::sync wrappers"
        )
    };
    // qualified paths: std::sync::Mutex, use std::sync::Mutex as …
    for tok in BANNED_SYNC {
        let pat = format!("std::sync::{tok}");
        for at in find_all(&sq.text, &pat) {
            if !ident_boundary_after(&sq.text, at + pat.len()) {
                continue;
            }
            out.push(report(RAW_LOCK, sq.lines[at], msg(tok)));
        }
    }
    // grouped imports: use std::sync::{…, Mutex, …}
    for at in find_all(&sq.text, "std::sync::{") {
        let open = at + "std::sync::{".len() - 1;
        let Some(close) = matching_brace(&sq.text, open) else { continue };
        let group = &sq.text[open + 1..close];
        for tok in BANNED_SYNC {
            for hit in find_all(group, tok) {
                let before_ok = hit == 0
                    || !is_ident_char(group.as_bytes()[hit - 1]);
                let after_ok =
                    ident_boundary_after(group, hit + tok.len());
                if before_ok && after_ok {
                    let pos = open + 1 + hit;
                    out.push(report(RAW_LOCK, sq.lines[pos], msg(tok)));
                }
            }
        }
    }
}

/// 1-based lines (deduped) containing the keyword `unsafe` in code.
fn unsafe_lines(masked: &MaskedSource) -> Vec<usize> {
    let mut lines = BTreeSet::new();
    let bytes = masked.masked.as_bytes();
    for at in find_all(&masked.masked, "unsafe") {
        let before_ok = at == 0 || !is_ident_char(bytes[at - 1]);
        let after_ok = ident_boundary_after(&masked.masked, at + "unsafe".len());
        if before_ok && after_ok {
            let line =
                1 + bytes[..at].iter().filter(|&&b| b == b'\n').count();
            lines.insert(line);
        }
    }
    lines.into_iter().collect()
}

/// `// SAFETY:` on the line itself or within the 8 lines above it.
fn has_safety_comment(masked: &MaskedSource, line: usize) -> bool {
    let lo = line.saturating_sub(8).max(1);
    (lo..=line).any(|l| {
        let c = masked.comment_on(l);
        c.contains("SAFETY") || c.contains("Safety:")
    })
}

fn find_all(haystack: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = haystack[from..].find(needle) {
        out.push(from + rel);
        from += rel + 1;
    }
    out
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn boundary_before(text: &str, at: usize) -> bool {
    at == 0 || !is_ident_char(text.as_bytes()[at - 1])
}

fn ident_boundary_after(text: &str, end: usize) -> bool {
    text.as_bytes().get(end).map(|&b| !is_ident_char(b)).unwrap_or(true)
}

fn matching_brace(text: &str, open: usize) -> Option<usize> {
    let bytes = text.as_bytes();
    let mut depth = 0usize;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

fn truncate(line: &str) -> String {
    if line.len() <= 120 {
        line.to_string()
    } else {
        let mut end = 117;
        while !line.is_char_boundary(end) {
            end -= 1;
        }
        format!("{}...", &line[..end])
    }
}
