//! `dash audit`: the in-tree invariant auditor.
//!
//! The serving stack's written rules — no panic paths in library code, no
//! raw poisoning locks, `unsafe` only in audited files with per-block
//! `// SAFETY:` comments, sorted-key wire frames — were policed by hand
//! for three PRs running. This module turns them into machine checks the
//! repo runs on itself: a dependency-free lexer ([`lexer`]), the rule
//! scanners ([`rules`]), and a committed, shrink-only exemption file
//! ([`allowlist`], `audit.allow` at the repo root).
//!
//! Entry points: [`audit_root`] walks `rust/src`, `rust/tests`,
//! `rust/benches`, and `examples` under a repo root and applies
//! `audit.allow`; [`audit_sources`] is the pure core over in-memory
//! `(path, contents)` pairs (what the self-tests feed with planted
//! violations). The CLI front is `dash audit [--root DIR]`, a required CI
//! gate; `tests/audit.rs` also runs [`audit_root`] against this very
//! repository, so `cargo test` enforces the invariants with no CI in the
//! loop.

pub mod allowlist;
pub mod lexer;
pub mod rules;

pub use allowlist::{parse as parse_allowlist, AllowEntry, Allowlist};
pub use rules::Violation;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Directories (repo-relative) the auditor scans for `.rs` files.
pub const SCAN_DIRS: &[&str] =
    &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Name of the exemption file at the repo root.
pub const ALLOW_FILE: &str = "audit.allow";

/// The result of an audit pass.
#[derive(Debug, Default)]
pub struct AuditOutcome {
    /// Violations that survived the allowlist, in path/line order.
    pub violations: Vec<Violation>,
    /// Violations suppressed by an `allow` entry, with the entry's
    /// 1-based line in `audit.allow`.
    pub suppressed: Vec<(Violation, usize)>,
    /// Diagnostics for allowlist entries that matched nothing — hard
    /// errors under the shrink-only policy.
    pub stale: Vec<String>,
    /// Number of files scanned.
    pub files_scanned: usize,
}

impl AuditOutcome {
    /// Whether the tree passes: no surviving violations, no stale entries.
    pub fn clean(&self) -> bool {
        self.violations.is_empty() && self.stale.is_empty()
    }

    /// Human-readable report (diagnostics plus a one-line summary).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&v.to_string());
            out.push('\n');
        }
        for s in &self.stale {
            out.push_str(s);
            out.push('\n');
        }
        out.push_str(&format!(
            "audit: {} file(s), {} violation(s), {} suppressed by \
             audit.allow, {} stale allowlist entr{}\n",
            self.files_scanned,
            self.violations.len(),
            self.suppressed.len(),
            self.stale.len(),
            if self.stale.len() == 1 { "y" } else { "ies" },
        ));
        out
    }
}

/// Audit in-memory sources (repo-relative path with forward slashes,
/// contents) against a parsed allowlist. Pure: the self-tests drive this
/// with planted violations.
pub fn audit_sources(
    files: &[(String, String)],
    allow: &Allowlist,
) -> AuditOutcome {
    let unsafe_files: BTreeSet<String> =
        allow.unsafe_files.iter().map(|(p, _, _)| p.clone()).collect();
    let mut hits = vec![0usize; allow.allows.len()];
    let mut unsafe_hits = vec![false; allow.unsafe_files.len()];
    let mut outcome = AuditOutcome { files_scanned: files.len(), ..Default::default() };

    for (rel, source) in files {
        for v in rules::scan_file(rel, source, &unsafe_files) {
            let matched = allow.allows.iter().position(|e| {
                e.rule == v.rule && e.path == v.file && v.excerpt.contains(&e.needle)
            });
            match matched {
                Some(i) => {
                    hits[i] += 1;
                    outcome.suppressed.push((v, allow.allows[i].line));
                }
                None => outcome.violations.push(v),
            }
        }
        // an unsafe-file entry is "used" when its file still has unsafe
        for (i, (p, _, _)) in allow.unsafe_files.iter().enumerate() {
            if p == rel && has_unsafe(source) {
                unsafe_hits[i] = true;
            }
        }
    }

    for (i, e) in allow.allows.iter().enumerate() {
        if hits[i] == 0 {
            outcome.stale.push(format!(
                "audit.allow:{}: stale entry (matches nothing — the code it \
                 excused is gone; delete the line): allow {} {} {}",
                e.line, e.rule, e.path, e.needle
            ));
        }
    }
    for (i, (p, _, line)) in allow.unsafe_files.iter().enumerate() {
        if !unsafe_hits[i] {
            outcome.stale.push(format!(
                "audit.allow:{line}: stale unsafe-file entry ({p} has no \
                 unsafe code or was not scanned; delete the line)"
            ));
        }
    }

    outcome
        .violations
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    outcome
}

/// Whether `source` contains the `unsafe` keyword in code (not comments
/// or strings).
fn has_unsafe(source: &str) -> bool {
    let masked = lexer::mask(source);
    let bytes = masked.masked.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = masked.masked[from..].find("unsafe") {
        let at = from + rel;
        from = at + 1;
        let before = at == 0 || !ident(bytes[at - 1]);
        let after = bytes.get(at + 6).map(|&b| !ident(b)).unwrap_or(true);
        if before && after {
            return true;
        }
    }
    false
}

fn ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Audit the repository at `root`: read `root/audit.allow` (absent =
/// empty), walk [`SCAN_DIRS`], scan every `.rs` file. IO problems are
/// `Err`; rule findings are in the returned outcome.
pub fn audit_root(root: &Path) -> Result<AuditOutcome, String> {
    let allow_path = root.join(ALLOW_FILE);
    let allow = if allow_path.exists() {
        let text = std::fs::read_to_string(&allow_path)
            .map_err(|e| format!("reading {}: {e}", allow_path.display()))?;
        allowlist::parse(&text)?
    } else {
        Allowlist::default()
    };
    let mut files = Vec::new();
    for dir in SCAN_DIRS {
        let base = root.join(dir);
        if !base.is_dir() {
            continue;
        }
        let mut paths = Vec::new();
        collect_rs(&base, &mut paths)?;
        for p in paths {
            let source = std::fs::read_to_string(&p)
                .map_err(|e| format!("reading {}: {e}", p.display()))?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            files.push((rel, source));
        }
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(audit_sources(&files, &allow))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("listing {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("listing {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk up from `start` to the first directory that looks like this
/// repository's root (has `rust/src` and a `Cargo.toml`).
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut dir = Some(start.to_path_buf());
    while let Some(d) = dir {
        if d.join("rust/src").is_dir() && d.join("Cargo.toml").is_file() {
            return Some(d);
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    None
}
