//! The committed exemption file, `audit.allow` at the repo root.
//!
//! Policy: **shrink-only**. Every entry is an explicit, justified
//! exception reviewed like code; a new violation means fixing the code,
//! not growing this file. Stale entries (matching nothing) are hard
//! errors, so the list cannot silently outlive the code it excuses.
//!
//! Grammar (one entry per line; `#` starts a comment):
//!
//! ```text
//! allow <rule> <path> <needle…> -- <justification>
//! unsafe-file <path> -- <justification>
//! ```
//!
//! An `allow` entry suppresses violations of `<rule>` in `<path>` whose
//! raw source line contains `<needle…>` (everything between the path and
//! the ` -- ` separator, so needles may contain spaces). An `unsafe-file`
//! entry admits `<path>` to the `unsafe` file allowlist — `// SAFETY:`
//! comments are still required per block there.

/// One `allow` line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowEntry {
    /// Rule name this entry suppresses (see [`super::rules`]).
    pub rule: String,
    /// Repo-relative path (forward slashes) the entry applies to.
    pub path: String,
    /// Substring the flagged raw source line must contain.
    pub needle: String,
    /// Why the exemption is sound (required).
    pub justification: String,
    /// 1-based line in `audit.allow` (for stale-entry diagnostics).
    pub line: usize,
}

/// The parsed `audit.allow` file.
#[derive(Debug, Default, Clone)]
pub struct Allowlist {
    /// `allow` entries, in file order.
    pub allows: Vec<AllowEntry>,
    /// `unsafe-file` entries: `(path, justification, line)`.
    pub unsafe_files: Vec<(String, String, usize)>,
}

impl Allowlist {
    /// Total entry count (the acceptance budget is ≤ 10).
    pub fn len(&self) -> usize {
        self.allows.len() + self.unsafe_files.len()
    }

    /// Whether the list has no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parse `audit.allow` text. Malformed lines are errors, not warnings —
/// a typo must not silently disable an exemption (the stale-entry check
/// would catch it later, but with a worse message) or, worse, widen one.
pub fn parse(text: &str) -> Result<Allowlist, String> {
    let mut out = Allowlist::default();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (keyword, rest) = line
            .split_once(char::is_whitespace)
            .ok_or_else(|| bad(lineno, "missing fields", raw))?;
        let (spec, justification) = rest
            .split_once(" -- ")
            .ok_or_else(|| bad(lineno, "missing ` -- <justification>`", raw))?;
        let justification = justification.trim();
        if justification.is_empty() {
            return Err(bad(lineno, "empty justification", raw));
        }
        match keyword {
            "allow" => {
                let spec = spec.trim();
                let (rule, rest) = spec
                    .split_once(char::is_whitespace)
                    .ok_or_else(|| bad(lineno, "allow needs `<rule> <path> <needle>`", raw))?;
                let (path, needle) = rest
                    .trim()
                    .split_once(char::is_whitespace)
                    .ok_or_else(|| bad(lineno, "allow needs a needle after the path", raw))?;
                let needle = needle.trim();
                if needle.is_empty() {
                    return Err(bad(lineno, "empty needle", raw));
                }
                out.allows.push(AllowEntry {
                    rule: rule.to_string(),
                    path: path.to_string(),
                    needle: needle.to_string(),
                    justification: justification.to_string(),
                    line: lineno,
                });
            }
            "unsafe-file" => {
                let path = spec.trim();
                if path.is_empty() || path.contains(char::is_whitespace) {
                    return Err(bad(lineno, "unsafe-file needs exactly one path", raw));
                }
                out.unsafe_files.push((
                    path.to_string(),
                    justification.to_string(),
                    lineno,
                ));
            }
            other => {
                return Err(bad(
                    lineno,
                    &format!("unknown keyword `{other}` (allow | unsafe-file)"),
                    raw,
                ));
            }
        }
    }
    Ok(out)
}

fn bad(line: usize, what: &str, raw: &str) -> String {
    format!("audit.allow:{line}: {what}: `{raw}`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_both_entry_kinds() {
        let text = "\
# comment
allow no-panic rust/src/a.rs expect(\"pool shut down\") -- fatal invariant

unsafe-file rust/src/linalg/simd.rs -- std::arch kernels
";
        let al = parse(text).expect("parses");
        assert_eq!(al.len(), 2);
        assert_eq!(al.allows[0].rule, "no-panic");
        assert_eq!(al.allows[0].path, "rust/src/a.rs");
        assert_eq!(al.allows[0].needle, "expect(\"pool shut down\")");
        assert_eq!(al.allows[0].justification, "fatal invariant");
        assert_eq!(al.unsafe_files[0].0, "rust/src/linalg/simd.rs");
    }

    #[test]
    fn needles_keep_interior_spaces() {
        let al = parse("allow no-panic rust/src/a.rs at least one guess -- why\n")
            .expect("parses");
        assert_eq!(al.allows[0].needle, "at least one guess");
    }

    #[test]
    fn malformed_lines_are_errors() {
        assert!(parse("allow no-panic rust/src/a.rs needle\n").is_err(), "no justification");
        assert!(parse("allow no-panic -- j\n").is_err(), "missing fields");
        assert!(parse("permit x y z -- j\n").is_err(), "unknown keyword");
        assert!(parse("unsafe-file a.rs b.rs -- j\n").is_err(), "two paths");
        assert!(parse("allow no-panic rust/src/a.rs x --  \n").is_err(), "empty justification");
    }

    #[test]
    fn empty_and_comment_only_is_empty() {
        let al = parse("# nothing\n\n").expect("parses");
        assert!(al.is_empty());
    }
}
