//! A masking lexer for Rust source: just enough tokenization to make
//! substring scanning sound.
//!
//! Pattern rules over raw source text would trip on `panic!` inside a doc
//! comment or a string literal. [`mask`] walks the source once and returns
//! a [`MaskedSource`]: the text with every comment body and every string /
//! char literal's *contents* replaced by spaces (delimiters and newlines
//! kept, so byte offsets and line numbers are unchanged), plus the side
//! tables the rules need — per-line comment text (for `// SAFETY:`
//! checks), string literals with their lines (for the wire-format rule),
//! and which lines fall inside `#[cfg(test)]`-gated items (brace-matched
//! on the masked text, where every `{`/`}` is real code).
//!
//! Handled syntax: line comments, nested block comments, string literals
//! with escapes, raw strings `r"…"` / `r#"…"#` (any hash depth, `b`
//! prefixes too), char and byte literals, and lifetimes (`'a` is not a
//! char literal). This is not a full lexer — it does not need to be; it
//! only has to agree with rustc about *where code is*.

/// The output of [`mask`]: scan-ready text plus side tables.
pub struct MaskedSource {
    /// Source with comment bodies and literal contents blanked; identical
    /// length and line structure to the input.
    pub masked: String,
    /// Concatenated comment text per line (1-based line - 1).
    pub comments: Vec<String>,
    /// String-literal contents (unmasked) with their 1-based start lines.
    pub strings: Vec<(usize, String)>,
    /// Per line (1-based line - 1): inside a `#[cfg(test)]`-gated brace
    /// span.
    pub test_lines: Vec<bool>,
}

impl MaskedSource {
    /// Whether 1-based `line` is inside a `#[cfg(test)]` item.
    pub fn in_test(&self, line: usize) -> bool {
        self.test_lines.get(line.saturating_sub(1)).copied().unwrap_or(false)
    }

    /// Comment text on 1-based `line` ("" when none).
    pub fn comment_on(&self, line: usize) -> &str {
        self.comments
            .get(line.saturating_sub(1))
            .map(String::as_str)
            .unwrap_or("")
    }
}

#[derive(Clone, Copy)]
enum State {
    Code,
    LineComment,
    BlockComment { depth: usize },
    Str,
    RawStr { hashes: usize },
    Char,
}

/// Blank comments and literal contents out of `source` (see module docs).
pub fn mask(source: &str) -> MaskedSource {
    let bytes = source.as_bytes();
    let mut masked = Vec::with_capacity(bytes.len());
    let nlines = source.lines().count().max(1);
    let mut comments = vec![String::new(); nlines];
    let mut strings: Vec<(usize, String)> = Vec::new();
    let mut cur_string = String::new();
    let mut cur_string_line = 0usize;

    let mut state = State::Code;
    let mut line = 1usize;
    let mut i = 0usize;
    while i < bytes.len() {
        let b = bytes[i];
        let nxt = bytes.get(i + 1).copied().unwrap_or(0);
        match state {
            State::Code => {
                if b == b'/' && nxt == b'/' {
                    state = State::LineComment;
                    masked.push(b' ');
                    masked.push(b' ');
                    i += 2;
                    continue;
                }
                if b == b'/' && nxt == b'*' {
                    state = State::BlockComment { depth: 1 };
                    masked.push(b' ');
                    masked.push(b' ');
                    i += 2;
                    continue;
                }
                // raw strings: r"…", r#"…"#, br#"…"# — the prefix byte(s)
                // must not be part of an identifier (`attr"x"` is not raw)
                let ident_before = i > 0 && is_ident_byte(bytes[i - 1]);
                if !ident_before && (b == b'r' || (b == b'b' && nxt == b'r')) {
                    let start = if b == b'b' { i + 2 } else { i + 1 };
                    let mut hashes = 0usize;
                    let mut j = start;
                    while bytes.get(j) == Some(&b'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if bytes.get(j) == Some(&b'"') {
                        masked.extend_from_slice(&bytes[i..=j]);
                        i = j + 1;
                        cur_string.clear();
                        cur_string_line = line;
                        state = State::RawStr { hashes };
                        continue;
                    }
                }
                if !ident_before && b == b'b' && nxt == b'"' {
                    masked.push(b);
                    masked.push(nxt);
                    i += 2;
                    cur_string.clear();
                    cur_string_line = line;
                    state = State::Str;
                    continue;
                }
                if b == b'"' {
                    masked.push(b);
                    i += 1;
                    cur_string.clear();
                    cur_string_line = line;
                    state = State::Str;
                    continue;
                }
                if b == b'\'' || (b == b'b' && nxt == b'\'' && !ident_before) {
                    let q = if b == b'b' { i + 1 } else { i };
                    // char literal iff an escape follows, or the quote two
                    // chars (one utf-8 scalar) later closes it; otherwise a
                    // lifetime
                    let after = bytes.get(q + 1).copied().unwrap_or(0);
                    let is_char = after == b'\\'
                        || closes_char_literal(bytes, q + 1);
                    if is_char {
                        masked.extend_from_slice(&bytes[i..=q]);
                        i = q + 1;
                        state = State::Char;
                        continue;
                    }
                    masked.push(b);
                    i += 1;
                    continue;
                }
                masked.push(b);
                if b == b'\n' {
                    line += 1;
                }
                i += 1;
            }
            State::LineComment => {
                if b == b'\n' {
                    masked.push(b);
                    line += 1;
                    state = State::Code;
                } else {
                    if line <= comments.len() {
                        push_char(&mut comments[line - 1], bytes, i);
                    }
                    masked.push(b' ');
                }
                i += 1;
            }
            State::BlockComment { depth } => {
                if b == b'/' && nxt == b'*' {
                    state = State::BlockComment { depth: depth + 1 };
                    masked.push(b' ');
                    masked.push(b' ');
                    i += 2;
                    continue;
                }
                if b == b'*' && nxt == b'/' {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment { depth: depth - 1 }
                    };
                    masked.push(b' ');
                    masked.push(b' ');
                    i += 2;
                    continue;
                }
                if b == b'\n' {
                    masked.push(b);
                    line += 1;
                } else {
                    if line <= comments.len() {
                        push_char(&mut comments[line - 1], bytes, i);
                    }
                    masked.push(b' ');
                }
                i += 1;
            }
            State::Str => {
                if b == b'\\' {
                    masked.push(b' ');
                    masked.push(b' ');
                    push_char(&mut cur_string, bytes, i);
                    push_char(&mut cur_string, bytes, i + 1);
                    if nxt == b'\n' {
                        line += 1;
                        // keep the newline so line numbers stay aligned
                        *masked.last_mut().unwrap_or(&mut 0) = b'\n';
                    }
                    i += 2;
                    continue;
                }
                if b == b'"' {
                    masked.push(b);
                    strings.push((cur_string_line, std::mem::take(&mut cur_string)));
                    state = State::Code;
                    i += 1;
                    continue;
                }
                push_char(&mut cur_string, bytes, i);
                if b == b'\n' {
                    masked.push(b);
                    line += 1;
                } else {
                    masked.push(b' ');
                }
                i += 1;
            }
            State::RawStr { hashes } => {
                if b == b'"' {
                    let mut ok = true;
                    for h in 0..hashes {
                        if bytes.get(i + 1 + h) != Some(&b'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        masked.push(b);
                        for _ in 0..hashes {
                            masked.push(b'#');
                        }
                        strings.push((
                            cur_string_line,
                            std::mem::take(&mut cur_string),
                        ));
                        state = State::Code;
                        i += 1 + hashes;
                        continue;
                    }
                }
                push_char(&mut cur_string, bytes, i);
                if b == b'\n' {
                    masked.push(b);
                    line += 1;
                } else {
                    masked.push(b' ');
                }
                i += 1;
            }
            State::Char => {
                if b == b'\\' {
                    masked.push(b' ');
                    masked.push(b' ');
                    i += 2;
                    continue;
                }
                if b == b'\'' {
                    masked.push(b);
                    state = State::Code;
                    i += 1;
                    continue;
                }
                masked.push(b' ');
                if b == b'\n' {
                    // malformed literal; keep line accounting sane
                    *masked.last_mut().unwrap_or(&mut 0) = b'\n';
                    line += 1;
                }
                i += 1;
            }
        }
    }

    let masked = String::from_utf8_lossy(&masked).into_owned();
    let test_lines = mark_test_lines(&masked, nlines);
    MaskedSource { masked, comments, strings, test_lines }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whether the bytes starting at `i` are one character followed by a
/// closing single quote (i.e. `'x'` rather than a lifetime `'x`).
fn closes_char_literal(bytes: &[u8], i: usize) -> bool {
    let Some(&first) = bytes.get(i) else { return false };
    if first == b'\'' {
        return false;
    }
    // utf-8 scalar length from the lead byte
    let len = match first {
        b if b < 0x80 => 1,
        b if b >= 0xF0 => 4,
        b if b >= 0xE0 => 3,
        b if b >= 0xC0 => 2,
        _ => 1,
    };
    bytes.get(i + len) == Some(&b'\'')
}

fn push_char(dst: &mut String, bytes: &[u8], i: usize) {
    if let Some(&b) = bytes.get(i) {
        // rule needles are ascii; non-ascii comment/string bytes only need
        // to survive as *something*
        dst.push(if b < 0x80 { b as char } else { '?' });
    }
}

/// Mark every line covered by a `#[cfg(test)]`-gated braced item, by
/// brace-matching on the masked text (where braces are always code).
fn mark_test_lines(masked: &str, nlines: usize) -> Vec<bool> {
    let mut out = vec![false; nlines];
    let bytes = masked.as_bytes();
    let mut search = 0usize;
    while let Some(rel) = masked[search..].find("cfg(test)") {
        let at = search + rel;
        search = at + 1;
        // must sit inside an attribute: look back for `#[` or `#![` with
        // only attribute-ish bytes between
        if !inside_attribute(masked, at) {
            continue;
        }
        // walk forward to the item's opening brace; a `;` first means a
        // braceless item (e.g. `mod tests;`) — no span to mark
        let mut j = at;
        let mut attr_depth = 0usize;
        let mut opened = None;
        while j < bytes.len() {
            match bytes[j] {
                b'[' => attr_depth += 1,
                b']' => attr_depth = attr_depth.saturating_sub(1),
                b'{' if attr_depth == 0 => {
                    opened = Some(j);
                    break;
                }
                b';' if attr_depth == 0 => break,
                b'=' if attr_depth == 0 => {
                    // `#[cfg(test)] const X: … = …;` — still braceless for
                    // our purposes (any braces belong to the initializer,
                    // which the forward walk below would handle anyway)
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        let Some(open) = opened else { continue };
        let mut depth = 0usize;
        let mut k = open;
        let mut close = bytes.len();
        while k < bytes.len() {
            match bytes[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = k;
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        let start_line = line_of(bytes, at);
        let end_line = line_of(bytes, close.min(bytes.len() - 1));
        for l in start_line..=end_line.min(nlines) {
            out[l - 1] = true;
        }
    }
    out
}

/// Whether the `cfg(test)` at byte `at` sits inside `#[…]` / `#![…]`.
fn inside_attribute(masked: &str, at: usize) -> bool {
    let head = &masked.as_bytes()[..at];
    let mut j = head.len();
    while j > 0 {
        j -= 1;
        match head[j] {
            b'[' => {
                // allow `#[` and `#![`
                if j >= 1 && head[j - 1] == b'#' {
                    return true;
                }
                if j >= 2 && head[j - 1] == b'!' && head[j - 2] == b'#' {
                    return true;
                }
                return false;
            }
            b']' | b'{' | b'}' | b';' => return false,
            _ => {}
        }
    }
    false
}

fn line_of(bytes: &[u8], at: usize) -> usize {
    1 + bytes[..at].iter().filter(|&&b| b == b'\n').count()
}

/// The masked text with all whitespace removed, plus a map from each
/// squeezed byte back to its 1-based source line — this is what makes
/// multi-line patterns (`.write()\n    .unwrap()`) one substring search.
pub struct Squeezed {
    pub text: String,
    pub lines: Vec<usize>,
}

/// Squeeze `masked` (see [`Squeezed`]).
pub fn squeeze(masked: &str) -> Squeezed {
    let mut text = String::with_capacity(masked.len());
    let mut lines = Vec::with_capacity(masked.len());
    let mut line = 1usize;
    for ch in masked.chars() {
        if ch == '\n' {
            line += 1;
            continue;
        }
        if ch.is_whitespace() {
            continue;
        }
        text.push(ch);
        lines.push(line);
    }
    Squeezed { text, lines }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let x = \"panic!(boom)\"; // panic!(no)\nlet y = 1;\n";
        let m = mask(src);
        assert!(!m.masked.contains("panic!"), "{}", m.masked);
        assert_eq!(m.masked.len(), src.len());
        assert_eq!(m.strings.len(), 1);
        assert_eq!(m.strings[0], (1, "panic!(boom)".to_string()));
        assert!(m.comment_on(1).contains("panic!(no)"));
    }

    #[test]
    fn raw_strings_any_hash_depth() {
        let src = "let s = r#\"unsafe { \"quoted\" }\"#; unsafe_marker();\n";
        let m = mask(src);
        assert!(!m.masked.contains("unsafe {"));
        assert!(m.masked.contains("unsafe_marker"));
        assert_eq!(m.strings[0].1, "unsafe { \"quoted\" }");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }\n";
        let m = mask(src);
        assert!(m.masked.contains("fn f<'a>(x: &'a str)"));
        assert!(!m.masked.contains("'x'"), "char contents blanked");
    }

    #[test]
    fn escaped_quote_in_char_literal() {
        let src = "let q = '\\''; let w = '\\\\'; code();\n";
        let m = mask(src);
        assert!(m.masked.contains("code()"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* a /* panic!() */ still comment */ real();\n";
        let m = mask(src);
        assert!(!m.masked.contains("panic!"));
        assert!(m.masked.contains("real()"));
    }

    #[test]
    fn cfg_test_span_is_marked() {
        let src = "fn live() { x.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { y.unwrap(); }\n\
                   }\n\
                   fn after() {}\n";
        let m = mask(src);
        assert!(!m.in_test(1));
        assert!(m.in_test(2));
        assert!(m.in_test(3));
        assert!(m.in_test(4));
        assert!(m.in_test(5));
        assert!(!m.in_test(6));
    }

    #[test]
    fn cfg_test_in_string_does_not_mark() {
        let src = "let s = \"#[cfg(test)]\";\nfn f() { g.unwrap(); }\n";
        let m = mask(src);
        assert!(!m.in_test(2));
    }

    #[test]
    fn squeeze_maps_lines_across_breaks() {
        let src = "a.write()\n    .unwrap();\n";
        let m = mask(src);
        let sq = squeeze(&m.masked);
        let at = sq.text.find(".write().unwrap()").expect("joined");
        assert_eq!(sq.lines[at], 1);
        let dot = sq.text.find(".unwrap()").expect("second");
        assert_eq!(sq.lines[dot], 2);
    }
}
