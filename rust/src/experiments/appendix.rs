//! Appendix demonstrations:
//!
//! - **A.1/A.2** — on the min-construction, plain (α=1) adaptive sampling
//!   fails (iteration cap / pool exhaustion) while DASH's α-scaled
//!   thresholds terminate with a valid set.
//! - **J** — the TOP-k γ² worst-case bound `f(TOPK) ≥ γ²·f(O)` checked
//!   against brute-force OPT on small instances.

use crate::algorithms::{
    AdaptiveSampling, AdaptiveSamplingConfig, Dash, DashConfig, OptEstimate, TopK,
};
use crate::data::synthetic;
use crate::objectives::counterexamples::MinCounterexample;
use crate::objectives::{spectra, LinearRegressionObjective, Objective};
use crate::rng::Pcg64;
use crate::util::csvio::CsvTable;

/// A.2 head-to-head result.
#[derive(Debug)]
pub struct AppendixA2Result {
    pub opt: f64,
    pub plain_value: f64,
    pub plain_failed: bool,
    pub dash_value: f64,
    pub dash_failed: bool,
    pub dash_rounds: usize,
}

/// Run the Appendix A.2 construction at cardinality `k`.
pub fn run_appendix_a2(k: usize, seed: u64) -> AppendixA2Result {
    let f = MinCounterexample::new(k);
    let opt = f.opt();
    let mut rng = Pcg64::seed_from(seed);
    // 32 samples: tight enough expectation estimates that the threshold
    // comparisons match the paper's exact-expectation story
    let plain = AdaptiveSampling::new(AdaptiveSamplingConfig {
        k,
        r: 1,
        epsilon: 0.0,
        samples: 32,
        opt: OptEstimate::Known(opt),
        max_rounds: 80,
    })
    .run(&f, &mut rng);
    let mut rng = Pcg64::seed_from(seed + 1);
    let dash = Dash::new(DashConfig {
        k,
        r: 1,
        epsilon: 0.0,
        alpha: 0.5,
        samples: 32,
        opt: OptEstimate::Known(opt),
        opt_guesses: 1,
        max_rounds: 80,
        max_filter_iters: 0,
    })
    .run(&f, &mut rng);
    AppendixA2Result {
        opt,
        plain_value: plain.value,
        plain_failed: plain.hit_iteration_cap,
        dash_value: dash.value,
        dash_failed: dash.hit_iteration_cap,
        dash_rounds: dash.rounds,
    }
}

/// Appendix J: TOP-k value vs the γ²·OPT bound over random instances.
/// Returns a CSV (one row per trial) and the count of bound violations
/// (expected 0).
pub fn run_topk_bound(trials: usize, seed: u64) -> (CsvTable, usize) {
    let mut t = CsvTable::new(&["trial", "gamma_sq", "topk_value", "opt", "ratio", "bound_ok"]);
    let mut violations = 0;
    for trial in 0..trials {
        let mut rng = Pcg64::seed_from(seed + trial as u64);
        let n = 10;
        let k = 3;
        let ds = synthetic::regression_d1(&mut rng, 80, n, 5, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        // brute force OPT over C(10, 3)
        let mut opt = 0.0f64;
        for a in 0..n {
            for b in (a + 1)..n {
                for c in (b + 1)..n {
                    opt = opt.max(obj.eval(&[a, b, c]));
                }
            }
        }
        let topk = TopK::new(k).run(&obj);
        let gamma = spectra::regression_gamma(&ds.x, k, 10, &mut rng);
        let gamma_sq = gamma * gamma;
        let ratio = if opt > 0.0 { topk.value / opt } else { 1.0 };
        let ok = topk.value + 1e-9 >= gamma_sq * opt;
        if !ok {
            violations += 1;
        }
        t.push(vec![
            trial.to_string(),
            crate::util::fmt_f64(gamma_sq),
            crate::util::fmt_f64(topk.value),
            crate::util::fmt_f64(opt),
            crate::util::fmt_f64(ratio),
            ok.to_string(),
        ]);
    }
    (t, violations)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a2_dash_succeeds_plain_fails() {
        let r = run_appendix_a2(2, 11);
        assert!(r.plain_failed, "plain adaptive sampling must hit its cap");
        assert!(!r.dash_failed, "DASH must terminate");
        assert!(r.dash_value >= 1.0);
        assert!(r.plain_value < r.opt);
    }

    #[test]
    fn topk_bound_holds() {
        let (table, violations) = run_topk_bound(5, 101);
        assert_eq!(table.rows.len(), 5);
        assert_eq!(violations, 0, "Appendix J bound must hold:\n{}", table.to_pretty());
    }
}
