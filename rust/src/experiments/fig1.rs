//! Figure 1: the marginal-contribution "sandwich" — for a fixed element
//! `a`, the scatter of `f_S(a)` over random sets `S`, which differential
//! submodularity predicts lies between two proportional submodular
//! envelopes. Also reports the sampled spectral estimates of γ and α = γ².

use super::results_dir;
use crate::data::synthetic;
use crate::objectives::{spectra, LinearRegressionObjective};
use crate::rng::Pcg64;
use crate::util::csvio::CsvTable;

/// Configuration for the Fig. 1 run.
#[derive(Debug, Clone)]
pub struct Fig1Config {
    pub seed: u64,
    /// random-set sizes to sample (paper uses |S| = 100 on D1)
    pub sizes: Vec<usize>,
    pub trials_per_size: usize,
    pub save: bool,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config { seed: 1, sizes: vec![0, 10, 25, 50, 100], trials_per_size: 40, save: true }
    }
}

/// Outputs: the scatter plus the estimated envelope ratio.
#[derive(Debug)]
pub struct Fig1Output {
    pub scatter: CsvTable,
    pub gamma: f64,
    pub alpha: f64,
    /// observed min/max of Σ singleton gains / set gain (Thm. 6 sandwich)
    pub ratio_lo: f64,
    pub ratio_hi: f64,
}

/// Run Figure 1 on the D1 regression workload.
pub fn run_fig1(cfg: &Fig1Config) -> Fig1Output {
    let mut rng = Pcg64::seed_from(cfg.seed);
    let ds = synthetic::regression_d1(&mut rng, 400, 200, 60, 0.4);
    let obj = LinearRegressionObjective::new(&ds);

    // pick the element with the largest singleton value (a clearly
    // informative feature, as in the paper's depiction)
    let st = crate::objectives::Objective::empty_state(&obj);
    let all: Vec<usize> = (0..200).collect();
    let singles = st.gains(&all);
    let a = (0..200)
        .max_by(|&x, &y| singles[x].total_cmp(&singles[y]))
        .unwrap_or(0);

    let pts = spectra::sandwich_scatter(&obj, a, &cfg.sizes, cfg.trials_per_size, &mut rng);
    let mut scatter = CsvTable::new(&["set_size", "marginal"]);
    for p in &pts {
        scatter.push(vec![p.set_size.to_string(), crate::util::fmt_f64(p.marginal)]);
    }

    let gamma = spectra::regression_gamma(&ds.x, 25, 8, &mut rng);
    let alpha = gamma * gamma;
    let (ratio_lo, ratio_hi) = spectra::marginal_ratio_range(&obj, 20, 5, 30, &mut rng);

    if cfg.save {
        let path = results_dir().join("fig1_sandwich.csv");
        if scatter.save(&path).is_ok() {
            crate::log_info!("wrote {path:?}");
        }
    }
    Fig1Output { scatter, gamma, alpha, ratio_lo, ratio_hi }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_produces_scatter_and_ratios() {
        let out = run_fig1(&Fig1Config {
            seed: 3,
            sizes: vec![0, 5, 10],
            trials_per_size: 5,
            save: false,
        });
        assert_eq!(out.scatter.rows.len(), 15);
        assert!(out.gamma > 0.0 && out.gamma <= 1.0);
        assert!((out.alpha - out.gamma * out.gamma).abs() < 1e-12);
        assert!(out.ratio_lo <= out.ratio_hi);
        // Theorem 6 sandwich: the singleton-sum/set-gain ratio is bounded
        // away from 0 and ∞ for this well-conditioned instance
        assert!(out.ratio_lo > 0.05, "lo {}", out.ratio_lo);
        assert!(out.ratio_hi < 50.0, "hi {}", out.ratio_hi);
    }
}
