//! The generic figure driver: regenerates the paper's Figures 2, 3 and 4
//! (each a 2×3 grid: accuracy-vs-rounds, accuracy-vs-k, time-vs-k for a
//! synthetic and a real-data workload).
//!
//! Benchmarked algorithms mirror §5: DASH, SDS_MA, Parallel SDS_MA, TOP-k,
//! RANDOM, and LASSO on the feature-selection figures. Sequential SDS_MA
//! runs are wallclock-capped like the paper's manual termination (the "X"
//! in Fig. 3f); capped cells are emitted as `terminated`.

use super::datasets::{DatasetId, Scale};
use super::results_dir;
use crate::coordinator::{AlgorithmChoice, Backend, Leader, ObjectiveChoice, SelectionJob};
use crate::algorithms::{
    AdaptiveSequencingConfig, DashConfig, GreedyConfig, LassoConfig,
};
use crate::data::{Dataset, Task};
use crate::objectives::{LogisticObjective, Objective, OvrSoftmaxObjective, R2Objective};
use crate::util::csvio::CsvTable;
use std::sync::Arc;

/// Which paper figure to regenerate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FigureId {
    /// linear regression feature selection
    Fig2,
    /// logistic regression feature selection
    Fig3,
    /// Bayesian A-optimal experimental design
    Fig4,
}

impl FigureId {
    pub fn parse(s: &str) -> Option<FigureId> {
        match s.to_ascii_lowercase().as_str() {
            "fig2" | "2" => Some(FigureId::Fig2),
            "fig3" | "3" => Some(FigureId::Fig3),
            "fig4" | "4" => Some(FigureId::Fig4),
            _ => None,
        }
    }

    /// (synthetic dataset, real-data dataset) rows of the figure.
    pub fn datasets(self) -> (DatasetId, DatasetId) {
        match self {
            FigureId::Fig2 => (DatasetId::D1, DatasetId::D2),
            FigureId::Fig3 => (DatasetId::D3, DatasetId::D4),
            FigureId::Fig4 => (DatasetId::D1Design, DatasetId::D2Design),
        }
    }

    pub fn objective(self) -> ObjectiveChoice {
        match self {
            FigureId::Fig2 => ObjectiveChoice::Lreg,
            FigureId::Fig3 => ObjectiveChoice::Logistic,
            FigureId::Fig4 => ObjectiveChoice::Aopt { beta_sq: 1.0, sigma_sq: 1.0 },
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FigureId::Fig2 => "fig2",
            FigureId::Fig3 => "fig3",
            FigureId::Fig4 => "fig4",
        }
    }
}

/// Which panel column to produce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Panel {
    Rounds,
    Accuracy,
    Time,
    All,
}

impl Panel {
    pub fn parse(s: &str) -> Option<Panel> {
        match s.to_ascii_lowercase().as_str() {
            "rounds" => Some(Panel::Rounds),
            "accuracy" => Some(Panel::Accuracy),
            "time" => Some(Panel::Time),
            "all" => Some(Panel::All),
            _ => None,
        }
    }
}

/// Figure run configuration.
#[derive(Debug, Clone)]
pub struct FigureConfig {
    pub figure: FigureId,
    pub scale: Scale,
    pub panel: Panel,
    pub seed: u64,
    pub backend: Backend,
    /// wallclock cap per algorithm run (the paper's manual termination)
    pub algo_budget_s: f64,
    /// write CSVs under results/
    pub save: bool,
}

impl Default for FigureConfig {
    fn default() -> Self {
        FigureConfig {
            figure: FigureId::Fig2,
            scale: Scale::Quick,
            panel: Panel::All,
            seed: 1,
            backend: Backend::Native,
            algo_budget_s: 120.0,
            save: true,
        }
    }
}

/// CSV outputs, one per produced panel (keyed by panel label).
#[derive(Debug, Default)]
pub struct FigureOutputs {
    pub tables: Vec<(String, CsvTable)>,
}

impl FigureOutputs {
    pub fn get(&self, label: &str) -> Option<&CsvTable> {
        self.tables.iter().find(|(l, _)| l == label).map(|(_, t)| t)
    }
}

/// Accuracy metric per figure: R² (Fig2), classification rate (Fig3),
/// normalized A-optimality (Fig4).
pub fn metric_for(figure: FigureId, ds: &Dataset, set: &[usize]) -> f64 {
    match figure {
        FigureId::Fig2 => R2Objective::new(ds).eval(set),
        FigureId::Fig3 => match ds.task {
            Task::MultiClassification { .. } => OvrSoftmaxObjective::new(ds)
                .map(|o| o.accuracy_on(set, &ds.x, &ds.y))
                .unwrap_or(f64::NAN),
            _ => LogisticObjective::new(ds).accuracy_on(set, &ds.x, &ds.y),
        },
        FigureId::Fig4 => {
            crate::objectives::AOptimalityObjective::new(ds, 1.0, 1.0).eval(set)
        }
    }
}

fn algorithms(figure: FigureId, threads: usize) -> Vec<AlgorithmChoice> {
    let mut algos = vec![
        AlgorithmChoice::Dash(DashConfig::default()),
        AlgorithmChoice::Greedy(GreedyConfig::default()),
        AlgorithmChoice::ParallelGreedy { cfg: GreedyConfig::default(), threads },
        AlgorithmChoice::TopK,
        AlgorithmChoice::Random { trials: 5 },
        AlgorithmChoice::AdaptiveSequencing(AdaptiveSequencingConfig::default()),
    ];
    if matches!(figure, FigureId::Fig2 | FigureId::Fig3) {
        algos.push(AlgorithmChoice::Lasso(LassoConfig::default()));
    }
    algos
}

/// Run one figure; returns the CSV panels.
pub fn run_figure(cfg: &FigureConfig) -> FigureOutputs {
    let leader = Leader::new();
    let (syn, real) = cfg.figure.datasets();
    let mut out = FigureOutputs::default();
    for (row, id) in [("synthetic", syn), ("real", real)] {
        let ds = Arc::new(id.build(cfg.scale, cfg.seed));
        crate::log_info!("{} {row}: dataset {} ({}×{})", cfg.figure.name(), ds.name, ds.d(), ds.n());
        if matches!(cfg.panel, Panel::Rounds | Panel::All) {
            let t = rounds_panel(&leader, cfg, &ds, id);
            out.tables.push((format!("{}_{}_rounds", cfg.figure.name(), row), t));
        }
        if matches!(cfg.panel, Panel::Accuracy | Panel::Time | Panel::All) {
            let (acc, time) = sweep_panels(&leader, cfg, &ds, id);
            if matches!(cfg.panel, Panel::Accuracy | Panel::All) {
                out.tables.push((format!("{}_{}_accuracy", cfg.figure.name(), row), acc));
            }
            if matches!(cfg.panel, Panel::Time | Panel::All) {
                out.tables.push((format!("{}_{}_time", cfg.figure.name(), row), time));
            }
        }
    }
    if cfg.save {
        let dir = results_dir();
        for (label, t) in &out.tables {
            let path = dir.join(format!("{label}.csv"));
            if let Err(e) = t.save(&path) {
                crate::log_warn!("saving {path:?}: {e}");
            } else {
                crate::log_info!("wrote {path:?}");
            }
        }
    }
    out
}

/// Panel (a)/(d): metric after each adaptive round at fixed k.
fn rounds_panel(
    leader: &Leader,
    cfg: &FigureConfig,
    ds: &Arc<Dataset>,
    id: DatasetId,
) -> CsvTable {
    let k = id.k_rounds(cfg.scale);
    let mut t = CsvTable::new(&["algorithm", "round", "value", "set_size", "queries"]);
    for alg in algorithms(cfg.figure, 4) {
        if matches!(alg, AlgorithmChoice::Lasso(_)) {
            continue; // LASSO has no round structure; appears in (b)/(e)
        }
        let label = alg.label();
        let job = SelectionJob {
            dataset: Arc::clone(ds),
            objective: cfg.figure.objective(),
            backend: cfg.backend,
            algorithm: alg,
            k,
            seed: cfg.seed,
        };
        match leader.run(&job) {
            Ok(report) => {
                for rec in &report.result.history {
                    t.push(vec![
                        label.to_string(),
                        rec.round.to_string(),
                        crate::util::fmt_f64(rec.value),
                        rec.set_size.to_string(),
                        rec.queries.to_string(),
                    ]);
                }
            }
            Err(e) => crate::log_warn!("{label} failed: {e}"),
        }
    }
    t
}

/// Panels (b)/(e) and (c)/(f): metric and time across the k grid.
fn sweep_panels(
    leader: &Leader,
    cfg: &FigureConfig,
    ds: &Arc<Dataset>,
    id: DatasetId,
) -> (CsvTable, CsvTable) {
    let ks = id.k_grid(cfg.scale);
    let mut acc = CsvTable::new(&["algorithm", "k", "metric", "objective_value"]);
    let mut time = CsvTable::new(&[
        "algorithm",
        "k",
        "wall_s",
        "modeled_parallel_s",
        "modeled_parallel_inf_s",
        "rounds",
        "queries",
        "terminated",
    ]);
    for alg in algorithms(cfg.figure, 4) {
        let label = alg.label();
        let mut over_budget = false;
        for &k in &ks {
            if over_budget {
                // the paper's "X": manual termination once runs blow the
                // budget — larger k can only be slower
                time.push(vec![
                    label.into(),
                    k.to_string(),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                    "X".into(),
                ]);
                continue;
            }
            let job = SelectionJob {
                dataset: Arc::clone(ds),
                objective: cfg.figure.objective(),
                backend: cfg.backend,
                algorithm: alg.clone(),
                k,
                seed: cfg.seed.wrapping_add(k as u64),
            };
            match leader.run(&job) {
                Ok(report) => {
                    let metric = metric_for(cfg.figure, ds, &report.result.set);
                    acc.push(vec![
                        label.into(),
                        k.to_string(),
                        crate::util::fmt_f64(metric),
                        crate::util::fmt_f64(report.native_value),
                    ]);
                    time.push(vec![
                        label.into(),
                        k.to_string(),
                        crate::util::fmt_f64(report.result.wall_s),
                        crate::util::fmt_f64(report.result.modeled_parallel_s(Some(64))),
                        crate::util::fmt_f64(report.result.modeled_parallel_s(None)),
                        report.result.rounds.to_string(),
                        report.result.queries.to_string(),
                        String::new(),
                    ]);
                    if report.result.wall_s > cfg.algo_budget_s {
                        over_budget = true;
                    }
                }
                Err(e) => crate::log_warn!("{label} k={k} failed: {e}"),
            }
        }
    }
    (acc, time)
}

/// Speedup summary (the paper's headline 2–8×): **adaptivity speedup** —
/// greedy rounds over DASH rounds at the largest k. This matches the
/// paper's accounting, where every oracle query costs roughly the same
/// (each is a model refit) so parallel runtime ∝ sequential rounds. Our
/// incremental-state oracles make greedy's per-query cost artificially
/// cheap, so the wallclock-derived modeled columns (kept in the CSV for
/// sensitivity analysis) under-credit DASH relative to the paper's setup.
pub fn speedup_summary(time_table: &CsvTable) -> Option<f64> {
    let k_col = time_table.col("k")?;
    let algo_col = time_table.col("algorithm")?;
    let rounds_col = time_table.col("rounds")?;
    let max_k: usize = time_table
        .rows
        .iter()
        .filter_map(|r| r[k_col].parse::<usize>().ok())
        .max()?;
    let at = |name: &str| -> Option<f64> {
        time_table
            .rows
            .iter()
            .find(|r| r[algo_col] == name && r[k_col] == max_k.to_string())
            .and_then(|r| r[rounds_col].parse::<f64>().ok())
    };
    let dash = at("dash")?;
    let greedy = at("parallel_sds_ma").or_else(|| at("sds_ma"))?;
    if dash > 0.0 {
        Some(greedy / dash)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_helpers() {
        assert_eq!(FigureId::parse("fig3"), Some(FigureId::Fig3));
        assert_eq!(FigureId::parse("4"), Some(FigureId::Fig4));
        assert_eq!(FigureId::parse("x"), None);
        assert_eq!(Panel::parse("TIME"), Some(Panel::Time));
    }

    #[test]
    fn metric_for_regression_is_r2() {
        let mut rng = crate::rng::Pcg64::seed_from(1);
        let ds = crate::data::synthetic::regression_d1(&mut rng, 80, 10, 5, 0.2);
        let m = metric_for(FigureId::Fig2, &ds, &[0, 1, 2]);
        assert!((0.0..=1.0).contains(&m));
        assert_eq!(metric_for(FigureId::Fig2, &ds, &[]), 0.0);
    }

    #[test]
    fn speedup_summary_reads_table() {
        let csv = "algorithm,k,wall_s,modeled_parallel_s,rounds,queries,terminated\n\
                   dash,10,1,0.5,5,100,\n\
                   parallel_sds_ma,10,4,2.0,20,200,\n";
        let t = CsvTable::parse(csv).unwrap();
        assert_eq!(speedup_summary(&t), Some(4.0)); // 20 rounds / 5 rounds
    }

    // a tiny end-to-end figure run (quick scale, rounds panel only, small
    // synthetic row) lives in tests/integration.rs to keep unit runtime low
}
