//! Named experiment datasets (Appendix I.2) at two scales:
//!
//! - `Quick` — minutes-scale single-core runs preserving every shape
//!   (feature counts match the paper; sample counts and k are reduced
//!   proportionally).
//! - `Paper` — the paper's dimensions (D2/D4 per the DESIGN.md §3
//!   substitutions).

use crate::data::{clinical_sim, gene_sim, synthetic, Dataset};
use crate::rng::Pcg64;

/// Experiment scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s.to_ascii_lowercase().as_str() {
            "quick" => Some(Scale::Quick),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }
}

/// The paper's datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetId {
    /// D1 — synthetic regression (Fig. 2 top, Fig. 4 top via design variant)
    D1,
    /// D1 design variant (256×1024, cov 0.8)
    D1Design,
    /// D2 — clinical regression substitute (Fig. 2 bottom)
    D2,
    /// D2 design variant (1000 sampled stimuli)
    D2Design,
    /// D3 — synthetic classification (Fig. 3 top)
    D3,
    /// D4 — gene classification substitute, binary reduction (Fig. 3 bottom)
    D4,
}

impl DatasetId {
    pub fn parse(s: &str) -> Option<DatasetId> {
        match s.to_ascii_lowercase().as_str() {
            "d1" => Some(DatasetId::D1),
            "d1-design" | "d1design" => Some(DatasetId::D1Design),
            "d2" => Some(DatasetId::D2),
            "d2-design" | "d2design" => Some(DatasetId::D2Design),
            "d3" => Some(DatasetId::D3),
            "d4" => Some(DatasetId::D4),
            _ => None,
        }
    }

    /// Build the dataset at the given scale.
    pub fn build(self, scale: Scale, seed: u64) -> Dataset {
        let mut rng = Pcg64::seed_from(seed);
        match (self, scale) {
            (DatasetId::D1, Scale::Quick) => synthetic::regression_d1(&mut rng, 400, 500, 100, 0.4),
            (DatasetId::D1, Scale::Paper) => synthetic::regression_d1(&mut rng, 1000, 500, 100, 0.4),
            (DatasetId::D1Design, Scale::Quick) => synthetic::design_d1(&mut rng, 96, 384, 0.8),
            (DatasetId::D1Design, Scale::Paper) => synthetic::design_d1(&mut rng, 256, 1024, 0.8),
            (DatasetId::D2, Scale::Quick) => clinical_sim::clinical_d2(
                &mut rng,
                &clinical_sim::ClinicalConfig { samples: 1200, ..Default::default() },
            ),
            (DatasetId::D2, Scale::Paper) => {
                clinical_sim::clinical_d2(&mut rng, &clinical_sim::ClinicalConfig::default())
            }
            (DatasetId::D2Design, Scale::Quick) => clinical_sim::clinical_d2_design(
                &mut rng,
                &clinical_sim::ClinicalConfig { samples: 1200, features: 96, ..Default::default() },
                300,
            ),
            (DatasetId::D2Design, Scale::Paper) => clinical_sim::clinical_d2_design(
                &mut rng,
                &clinical_sim::ClinicalConfig::default(),
                1000,
            ),
            // d = 256 so the quick scale fits the "small" XLA artifact
            // profile (score-test gains are the fast path for fig3)
            (DatasetId::D3, Scale::Quick) => {
                synthetic::classification_d3(&mut rng, 256, 200, 50, 0.3)
            }
            (DatasetId::D3, Scale::Paper) => {
                synthetic::classification_d3(&mut rng, 800, 200, 50, 0.3)
            }
            (DatasetId::D4, Scale::Quick) => gene_sim::gene_d4_binary(
                &mut rng,
                &gene_sim::GeneConfig { samples: 256, genes: 400, ..Default::default() },
            ),
            (DatasetId::D4, Scale::Paper) => gene_sim::gene_d4_binary(
                &mut rng,
                &gene_sim::GeneConfig::default(),
            ),
        }
    }

    /// The paper's k grid for this dataset (accuracy/time panels).
    pub fn k_grid(self, scale: Scale) -> Vec<usize> {
        match (self, scale) {
            (DatasetId::D4, Scale::Paper) => vec![25, 50, 100, 150, 200],
            (DatasetId::D4, Scale::Quick) => vec![5, 10, 20, 40],
            (_, Scale::Paper) => vec![10, 25, 50, 75, 100],
            (_, Scale::Quick) => vec![5, 10, 20, 30],
        }
    }

    /// k for the accuracy-vs-rounds panel (paper: 100, 200 for D4).
    pub fn k_rounds(self, scale: Scale) -> usize {
        match (self, scale) {
            (DatasetId::D4, Scale::Paper) => 200,
            (DatasetId::D4, Scale::Quick) => 30,
            (_, Scale::Paper) => 100,
            (_, Scale::Quick) => 25,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_quick_datasets_build() {
        for id in [
            DatasetId::D1,
            DatasetId::D1Design,
            DatasetId::D2,
            DatasetId::D2Design,
            DatasetId::D3,
            DatasetId::D4,
        ] {
            let ds = id.build(Scale::Quick, 1);
            assert!(ds.n() > 0 && ds.d() > 0, "{id:?}");
            assert!(!id.k_grid(Scale::Quick).is_empty());
            assert!(id.k_rounds(Scale::Quick) > 0);
        }
    }

    #[test]
    fn paper_dims_match_appendix() {
        // feature counts are the paper's exactly
        assert_eq!(DatasetId::D1.build(Scale::Paper, 1).n(), 500);
        assert_eq!(DatasetId::D3.build(Scale::Paper, 1).n(), 200);
        let d1d = DatasetId::D1Design.build(Scale::Paper, 1);
        assert_eq!((d1d.d(), d1d.n()), (256, 1024));
        assert_eq!(DatasetId::D2.build(Scale::Paper, 1).n(), 385);
        assert_eq!(DatasetId::D4.build(Scale::Paper, 1).n(), 2500);
    }

    #[test]
    fn parsing() {
        assert_eq!(DatasetId::parse("d1"), Some(DatasetId::D1));
        assert_eq!(DatasetId::parse("D2-design"), Some(DatasetId::D2Design));
        assert_eq!(DatasetId::parse("nope"), None);
        assert_eq!(Scale::parse("QUICK"), Some(Scale::Quick));
        assert_eq!(Scale::parse("x"), None);
    }
}
