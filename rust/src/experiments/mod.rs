//! Experiment harness: regenerates every figure of the paper's §5 plus the
//! appendix demonstrations. Each driver emits CSV series (one per panel)
//! under `results/` and prints aligned tables; EXPERIMENTS.md records the
//! paper-vs-measured comparison.
//!
//! | driver | paper artifact |
//! |--------|----------------|
//! | [`fig1`] | Fig. 1 marginal-contribution sandwich scatter |
//! | [`figs`] with [`FigureId::Fig2`] | Fig. 2 linear regression (a–f) |
//! | [`figs`] with [`FigureId::Fig3`] | Fig. 3 logistic regression (a–f) |
//! | [`figs`] with [`FigureId::Fig4`] | Fig. 4 Bayesian A-optimality (a–f) |
//! | [`appendix`] | App. A.1/A.2 counterexamples, App. J TOP-k bound |

pub mod appendix;
pub mod datasets;
pub mod fig1;
pub mod figs;

pub use datasets::{DatasetId, Scale};
pub use figs::{run_figure, FigureConfig, FigureId, FigureOutputs, Panel};

use std::path::PathBuf;

/// Where experiment CSVs land.
pub fn results_dir() -> PathBuf {
    if let Ok(p) = std::env::var("DASH_RESULTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}
