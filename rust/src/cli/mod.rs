//! Minimal CLI substrate (clap is unavailable offline): positional
//! subcommands plus `--key value` / `--flag` options, with typed accessors
//! and a generated usage block. Parse and accessor failures are
//! [`SelectError::InvalidSpec`] — the CLI shares the v1 API's unified
//! error type end to end.

use crate::coordinator::api::SelectError;
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// positional arguments in order (subcommand first)
    pub positional: Vec<String>,
    /// `--key value` options in occurrence order; bare `--flag`s map to
    /// "true". A repeated key keeps every value ([`Args::get_all`]); the
    /// scalar accessors read the last occurrence, shell-style.
    pub options: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, SelectError> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err(SelectError::InvalidSpec("empty option name".into()));
                }
                let (k, v) = if let Some((k, v)) = key.split_once('=') {
                    (k.to_string(), v.to_string())
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    (key.to_string(), it.next().unwrap_or_default())
                } else {
                    (key.to_string(), "true".to_string())
                };
                out.options.entry(k).or_default().push(v);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, SelectError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every value a repeated option was given, in occurrence order, with
    /// comma-separated values within one occurrence split out —
    /// `--worker a --worker b` and `--worker a,b` both yield `[a, b]`.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.options
            .get(key)
            .map(|vals| {
                vals.iter()
                    .flat_map(|v| v.split(','))
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, SelectError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                SelectError::InvalidSpec(format!("--{key}: expected integer, got '{v}'"))
            }),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, SelectError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                SelectError::InvalidSpec(format!("--{key}: expected number, got '{v}'"))
            }),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, SelectError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                SelectError::InvalidSpec(format!("--{key}: expected integer, got '{v}'"))
            }),
        }
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["experiment", "fig2", "--k", "25", "--save"]);
        assert_eq!(a.subcommand(), Some("experiment"));
        assert_eq!(a.positional[1], "fig2");
        assert_eq!(a.get("k"), Some("25"));
        assert!(a.get_flag("save"));
        assert!(!a.get_flag("missing"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["run", "--algo=dash", "--seed=42"]);
        assert_eq!(a.get("algo"), Some("dash"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse(&["x", "--k", "10", "--eps", "0.2"]);
        assert_eq!(a.get_usize("k", 5).unwrap(), 10);
        assert_eq!(a.get_usize("missing", 5).unwrap(), 5);
        assert!((a.get_f64("eps", 0.1).unwrap() - 0.2).abs() < 1e-12);
        assert!(a.get_usize("eps", 1).is_err());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["x", "--verbose", "--k", "3"]);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get_usize("k", 0).unwrap(), 3);
    }

    #[test]
    fn empty_option_rejected() {
        assert!(Args::parse(vec!["--".to_string()]).is_err());
    }

    #[test]
    fn repeated_options_collect_and_scalar_reads_take_the_last() {
        let a = parse(&["route", "--worker", "a:1", "--worker", "b:2,c:3", "--k", "1", "--k", "9"]);
        assert_eq!(a.get_all("worker"), vec!["a:1", "b:2", "c:3"]);
        assert_eq!(a.get_usize("k", 0).unwrap(), 9, "scalar reads take the last occurrence");
        assert!(a.get_all("missing").is_empty());
    }
}
