//! Feature selection for classification (paper §3.1, Cor. 8).
//!
//! Objective: the logistic log-likelihood maximized over weights supported
//! on `S`:
//!
//! ```text
//! ℓ_class(y, w^(S)) = Σ_i  y_i·(x_iᵀ w) − log(1 + exp(x_iᵀ w))
//! ```
//!
//! normalized so `f(∅) = 0` and `f → 1` as the likelihood approaches the
//! (unattainable) perfect fit: `f(S) = (ℓ(w^(S)) − ℓ(0)) / (0 − ℓ(0))`.
//!
//! A marginal-gain query requires refitting with the candidate feature
//! added — this is the paper's "expensive oracle" regime (Fig. 3f: queries
//! of >1 minute on the gene data, sequential greedy would take days). The
//! state keeps the current fit and warm-starts each refit, running a small
//! fixed number of Newton iterations (enough for the gain to stabilize to
//! well below the filtering thresholds' resolution).
//!
//! **Sweep engine note:** each gain is a full Newton refit, which has no
//! shared level-3 structure across candidates, so this objective
//! deliberately keeps the trait's scalar `gains_into` fallback (per-element
//! `gain`). The fallback is read-only like every refit here, so the
//! engine's zero-clone sharding still applies — the parallel win comes
//! from sharding the refits, not from blocking them. The XLA oracle's
//! score-test approximation is the blocked alternative.

use super::{Objective, ObjectiveState};
use crate::data::Dataset;
use crate::linalg::{solve_spd, Matrix};
use std::sync::Arc;

/// Number of Newton iterations for a warm-started refit.
const REFIT_ITERS: usize = 6;
/// Convergence tolerance on the step's squared norm.
const TOL: f64 = 1e-10;
/// Ridge added to the Hessian for numerical safety.
const RIDGE: f64 = 1e-8;

struct LogisticProblem {
    x: Matrix,
    /// labels in {0,1}
    y: Vec<f64>,
    /// −ℓ(0) = d·log 2, the normalization constant
    neg_ell0: f64,
    name: String,
}

/// Feature selection objective for binary logistic regression.
#[derive(Clone)]
pub struct LogisticObjective {
    p: Arc<LogisticProblem>,
}

impl LogisticObjective {
    pub fn new(ds: &Dataset) -> Self {
        Self::from_parts(ds.x.clone(), ds.y.clone(), &format!("logistic[{}]", ds.name))
    }

    pub fn from_parts(x: Matrix, y: Vec<f64>, name: &str) -> Self {
        assert_eq!(x.rows(), y.len(), "response/sample mismatch");
        assert!(
            y.iter().all(|&v| v == 0.0 || v == 1.0),
            "labels must be binary 0/1"
        );
        let d = y.len();
        LogisticObjective {
            p: Arc::new(LogisticProblem {
                x,
                y,
                neg_ell0: d as f64 * std::f64::consts::LN_2,
                name: name.to_string(),
            }),
        }
    }

    pub fn features(&self) -> &Matrix {
        &self.p.x
    }

    pub fn labels(&self) -> &[f64] {
        &self.p.y
    }

    /// Classification accuracy of the max-likelihood fit on support `set`,
    /// evaluated on (possibly different) data.
    pub fn accuracy_on(&self, set: &[usize], x_eval: &Matrix, y_eval: &[f64]) -> f64 {
        if set.is_empty() {
            // majority class
            let pos = y_eval.iter().filter(|&&v| v == 1.0).count() as f64;
            let d = y_eval.len() as f64;
            return (pos / d).max(1.0 - pos / d);
        }
        let st = self.state_for(set);
        let w = st_weights(&*st);
        let xs = x_eval.select_cols(set);
        let mut z = vec![0.0; x_eval.rows()];
        crate::linalg::gemv(&xs, &w, &mut z);
        let correct = z
            .iter()
            .zip(y_eval)
            .filter(|(zi, yi)| (**zi > 0.0) == (**yi == 1.0))
            .count();
        correct as f64 / y_eval.len() as f64
    }
}

fn st_weights(st: &dyn ObjectiveState) -> Vec<f64> {
    // downcast helper: states created by LogisticObjective are LogisticState
    // (we avoid `Any` plumbing by re-fitting if needed — only used by
    // accuracy reporting, not the hot path)
    st.as_logistic_weights().unwrap_or_default()
}

struct LogisticState {
    p: Arc<LogisticProblem>,
    set: Vec<usize>,
    in_set: Vec<bool>,
    /// weights aligned with `set`
    w: Vec<f64>,
    /// margins X_S w (length d)
    z: Vec<f64>,
    /// ℓ(w^(S)) (unnormalized log-likelihood)
    ell: f64,
}

/// Log-likelihood at margins `z`: Σ y·z − log(1+e^z), computed stably.
fn loglik(y: &[f64], z: &[f64]) -> f64 {
    y.iter()
        .zip(z)
        .map(|(&yi, &zi)| {
            // log(1+e^z) = max(z,0) + log1p(e^{-|z|})
            let softplus = zi.max(0.0) + (-zi.abs()).exp().ln_1p();
            yi * zi - softplus
        })
        .sum()
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Newton-fit logistic weights on the given support, warm-started from
/// `w0`. Returns (w, margins, loglik).
fn fit_support(
    p: &LogisticProblem,
    support: &[usize],
    w0: &[f64],
    iters: usize,
) -> (Vec<f64>, Vec<f64>, f64) {
    let d = p.x.rows();
    let s = support.len();
    let mut w = w0.to_vec();
    debug_assert_eq!(w.len(), s);
    let xs = p.x.select_cols(support);
    let mut z = vec![0.0; d];
    crate::linalg::gemv(&xs, &w, &mut z);
    let mut ell = loglik(&p.y, &z);
    for _ in 0..iters {
        // gradient g = X_Sᵀ (y − p), Hessian H = X_Sᵀ W X_S + ridge
        let probs: Vec<f64> = z.iter().map(|&zi| sigmoid(zi)).collect();
        let resid: Vec<f64> = p.y.iter().zip(&probs).map(|(y, pr)| y - pr).collect();
        let mut g = vec![0.0; s];
        crate::linalg::gemv_t(&xs, &resid, &mut g);
        // H = (W^½ X_S)ᵀ (W^½ X_S) as one level-3 syrk over the weighted
        // columns (the column dots inside ride the SIMD dispatch)
        let sw: Vec<f64> = probs.iter().map(|pr| (pr * (1.0 - pr)).max(1e-12).sqrt()).collect();
        let mut xw = Matrix::zeros(d, s);
        for j in 0..s {
            let src = xs.col(j);
            let dst = xw.col_mut(j);
            for i in 0..d {
                dst[i] = src[i] * sw[i];
            }
        }
        let mut h = crate::linalg::syrk(&xw);
        for i in 0..s {
            h.add_at(i, i, RIDGE * (1.0 + h.get(i, i).abs()));
        }
        let Some(step) = solve_spd(&h, &g) else { break };
        // damped update with halving line search on ℓ
        let mut t = 1.0;
        let mut improved = false;
        for _ in 0..8 {
            let w_try: Vec<f64> = w.iter().zip(&step).map(|(wi, si)| wi + t * si).collect();
            let mut z_try = vec![0.0; d];
            crate::linalg::gemv(&xs, &w_try, &mut z_try);
            let ell_try = loglik(&p.y, &z_try);
            if ell_try > ell {
                w = w_try;
                z = z_try;
                ell = ell_try;
                improved = true;
                break;
            }
            t *= 0.5;
        }
        if !improved {
            break;
        }
        let step_sq: f64 = step.iter().map(|s| s * s).sum::<f64>() * t * t;
        if step_sq < TOL {
            break;
        }
    }
    (w, z, ell)
}

impl LogisticState {
    fn new(p: Arc<LogisticProblem>) -> Self {
        let d = p.x.rows();
        let n = p.x.cols();
        LogisticState {
            set: Vec::new(),
            in_set: vec![false; n],
            w: Vec::new(),
            z: vec![0.0; d],
            ell: -p.neg_ell0,
            p,
        }
    }

    fn normalized(&self, ell: f64) -> f64 {
        ((ell + self.p.neg_ell0) / self.p.neg_ell0).max(0.0)
    }
}

impl ObjectiveState for LogisticState {
    fn value(&self) -> f64 {
        self.normalized(self.ell)
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        assert!(a < self.p.x.cols(), "element out of range");
        if self.in_set[a] {
            return;
        }
        self.in_set[a] = true;
        self.set.push(a);
        let mut w0 = self.w.clone();
        w0.push(0.0);
        let (w, z, ell) = fit_support(&self.p, &self.set, &w0, REFIT_ITERS + 4);
        // monotonicity guard: adding a feature cannot reduce the max
        // likelihood; keep the better of warm-started fit vs previous
        if ell >= self.ell {
            self.w = w;
            self.z = z;
            self.ell = ell;
        } else {
            // fall back: keep previous weights with 0 for the new feature
            self.w = w0;
        }
    }

    fn gain(&self, a: usize) -> f64 {
        if self.in_set[a] {
            return 0.0;
        }
        let mut support = self.set.clone();
        support.push(a);
        let mut w0 = self.w.clone();
        w0.push(0.0);
        let (_, _, ell) = fit_support(&self.p, &support, &w0, REFIT_ITERS);
        ((ell - self.ell) / self.p.neg_ell0).max(0.0)
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(LogisticState {
            p: Arc::clone(&self.p),
            set: self.set.clone(),
            in_set: self.in_set.clone(),
            w: self.w.clone(),
            z: self.z.clone(),
            ell: self.ell,
        })
    }

    fn as_logistic_weights(&self) -> Option<Vec<f64>> {
        Some(self.w.clone())
    }
}

impl Objective for LogisticObjective {
    fn n(&self) -> usize {
        self.p.x.cols()
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }

    fn name(&self) -> &str {
        &self.p.name
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(LogisticState::new(Arc::clone(&self.p)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::rng::Pcg64;

    fn toy(rng: &mut Pcg64, d: usize, n: usize) -> Dataset {
        synthetic::classification_d3(rng, d, n, n / 2, 0.2)
    }

    #[test]
    fn empty_value_zero_and_monotone() {
        let mut rng = Pcg64::seed_from(1);
        let ds = toy(&mut rng, 120, 8);
        let obj = LogisticObjective::new(&ds);
        let mut st = obj.empty_state();
        assert_eq!(st.value(), 0.0);
        let mut prev = 0.0;
        for a in 0..8 {
            st.insert(a);
            let v = st.value();
            assert!(v >= prev - 1e-9, "monotone at {a}: {v} < {prev}");
            assert!(v <= 1.0 + 1e-9);
            prev = v;
        }
        assert!(prev > 0.01, "full fit should explain something: {prev}");
    }

    #[test]
    fn gain_matches_eval_delta() {
        let mut rng = Pcg64::seed_from(2);
        let ds = toy(&mut rng, 100, 6);
        let obj = LogisticObjective::new(&ds);
        let st = obj.state_for(&[0, 3]);
        for a in [1usize, 4, 5] {
            let g = st.gain(a);
            let delta = obj.eval(&[0, 3, a]) - obj.eval(&[0, 3]);
            // Newton refits are approximate; allow a small tolerance
            assert!((g - delta).abs() < 5e-4, "a={a}: {g} vs {delta}");
        }
    }

    #[test]
    fn informative_feature_beats_noise() {
        let mut rng = Pcg64::seed_from(3);
        let ds = toy(&mut rng, 400, 10);
        let obj = LogisticObjective::new(&ds);
        let st = obj.empty_state();
        // average gain of true-support features should dominate noise ones
        let mut sup = 0.0;
        let mut sup_n = 0;
        let mut noise = 0.0;
        let mut noise_n = 0;
        for a in 0..10 {
            let g = st.gain(a);
            if ds.true_support.contains(&a) {
                sup += g;
                sup_n += 1;
            } else {
                noise += g;
                noise_n += 1;
            }
        }
        if sup_n > 0 && noise_n > 0 {
            assert!(sup / sup_n as f64 > noise / noise_n as f64, "{sup} vs {noise}");
        }
    }

    #[test]
    fn duplicate_insert_noop_and_zero_gain() {
        let mut rng = Pcg64::seed_from(4);
        let ds = toy(&mut rng, 80, 5);
        let obj = LogisticObjective::new(&ds);
        let mut st = obj.empty_state();
        st.insert(2);
        let v = st.value();
        st.insert(2);
        assert_eq!(st.value(), v);
        assert_eq!(st.gain(2), 0.0);
    }

    #[test]
    fn accuracy_improves_with_true_features() {
        let mut rng = Pcg64::seed_from(5);
        let ds = synthetic::classification_d3(&mut rng, 600, 12, 4, 0.1);
        let obj = LogisticObjective::new(&ds);
        let base = obj.accuracy_on(&[], &ds.x, &ds.y);
        let acc = obj.accuracy_on(&ds.true_support, &ds.x, &ds.y);
        assert!(acc > base, "accuracy {acc} <= baseline {base}");
        assert!(acc > 0.6);
    }

    #[test]
    fn rejects_non_binary_labels() {
        let x = Matrix::zeros(3, 2);
        let result = std::panic::catch_unwind(|| {
            LogisticObjective::from_parts(x, vec![0.0, 2.0, 1.0], "bad")
        });
        assert!(result.is_err());
    }

    #[test]
    fn loglik_stable_at_extreme_margins() {
        let y = vec![1.0, 0.0];
        let z = vec![500.0, -500.0];
        let l = loglik(&y, &z);
        assert!(l.abs() < 1e-6, "perfect fit loglik ~ 0, got {l}");
        let z_bad = vec![-500.0, 500.0];
        let l_bad = loglik(&y, &z_bad);
        assert!(l_bad < -900.0); // strongly penalized, finite
        assert!(l_bad.is_finite());
    }
}
