//! Diversity-regularized objectives: `f_div(S) = f(S) + d(S)` with `d`
//! monotone submodular (paper §3.1, following Das et al. [11]).
//!
//! Corollaries 7–9 show adding any nonnegative submodular `d(S)` preserves
//! γ²-differential submodularity, so DASH applies unchanged. We provide the
//! classic *group-coverage* diversity `d(S) = Σ_g w_g·√|S ∩ g|`, which
//! rewards spreading the selection across feature groups.

use super::{Objective, ObjectiveState, SweepScratch};
use std::sync::Arc;

/// A monotone submodular diversity term.
pub trait DiversityTerm: Send + Sync {
    /// `d(S)`.
    fn eval(&self, set: &[usize]) -> f64;

    /// `d_S(a)` — default computes eval twice.
    fn gain(&self, set: &[usize], a: usize) -> f64 {
        if set.contains(&a) {
            return 0.0;
        }
        let mut s2 = set.to_vec();
        s2.push(a);
        self.eval(&s2) - self.eval(set)
    }
}

/// `d(S) = scale · Σ_groups √|S ∩ g|` — monotone submodular (concave of
/// cardinality per group).
pub struct GroupSqrtDiversity {
    /// group id per element
    group_of: Vec<usize>,
    n_groups: usize,
    scale: f64,
}

impl GroupSqrtDiversity {
    pub fn new(group_of: Vec<usize>, scale: f64) -> Self {
        let n_groups = group_of.iter().max().map(|m| m + 1).unwrap_or(0);
        GroupSqrtDiversity { group_of, n_groups, scale }
    }

    /// Elements `0..n` hashed into `g` round-robin groups.
    pub fn round_robin(n: usize, g: usize, scale: f64) -> Self {
        Self::new((0..n).map(|i| i % g.max(1)).collect(), scale)
    }

    fn group_counts(&self, set: &[usize]) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_groups];
        for &a in set {
            counts[self.group_of[a]] += 1;
        }
        counts
    }
}

impl DiversityTerm for GroupSqrtDiversity {
    fn eval(&self, set: &[usize]) -> f64 {
        self.group_counts(set)
            .iter()
            .map(|&c| (c as f64).sqrt())
            .sum::<f64>()
            * self.scale
    }

    fn gain(&self, set: &[usize], a: usize) -> f64 {
        if set.contains(&a) {
            return 0.0;
        }
        let c = set.iter().filter(|&&b| self.group_of[b] == self.group_of[a]).count() as f64;
        self.scale * ((c + 1.0).sqrt() - c.sqrt())
    }
}

/// `f + d` wrapper objective.
pub struct DiverseObjective<O: Objective> {
    inner: O,
    div: Arc<dyn DiversityTerm>,
    name: String,
}

impl<O: Objective> DiverseObjective<O> {
    pub fn new(inner: O, div: impl DiversityTerm + 'static) -> Self {
        let name = format!("{}+div", inner.name());
        DiverseObjective { inner, div: Arc::new(div), name }
    }
}

struct DiverseState {
    inner: Box<dyn ObjectiveState>,
    div: Arc<dyn DiversityTerm>,
    div_value: f64,
}

impl ObjectiveState for DiverseState {
    fn value(&self) -> f64 {
        self.inner.value() + self.div_value
    }

    fn set(&self) -> &[usize] {
        self.inner.set()
    }

    fn insert(&mut self, a: usize) {
        if self.inner.set().contains(&a) {
            return;
        }
        self.div_value += self.div.gain(self.inner.set(), a);
        self.inner.insert(a);
    }

    fn gain(&self, a: usize) -> f64 {
        self.inner.gain(a) + self.div.gain(self.inner.set(), a)
    }

    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        // the inner objective's blocked kernel does the heavy lifting; the
        // diversity term is an additive per-candidate correction, so block
        // determinism is inherited unchanged
        self.inner.gains_into(candidates, scratch, out);
        for (o, &a) in out.iter_mut().zip(candidates) {
            *o += self.div.gain(self.inner.set(), a);
        }
    }

    fn sweep_block(&self) -> usize {
        self.inner.sweep_block()
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(DiverseState {
            inner: self.inner.clone_box(),
            div: Arc::clone(&self.div),
            div_value: self.div_value,
        })
    }
}

impl<O: Objective> Objective for DiverseObjective<O> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(DiverseState {
            inner: self.inner.empty_state(),
            div: Arc::clone(&self.div),
            div_value: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;
    use crate::rng::Pcg64;

    #[test]
    fn group_sqrt_is_submodular_and_monotone() {
        let d = GroupSqrtDiversity::round_robin(10, 3, 1.0);
        // monotone: gains nonnegative
        for a in 0..10 {
            assert!(d.gain(&[0, 1, 2], a) >= 0.0);
        }
        // submodular: gain shrinks as same-group elements accumulate
        // group of 3 = {0, 3, 6, 9}
        let g_small = d.gain(&[], 3);
        let g_large = d.gain(&[0, 6], 3);
        assert!(g_small > g_large);
        // diminishing-returns over supersets, random spot check
        let g1 = d.gain(&[1], 4);
        let g2 = d.gain(&[1, 7], 4); // 7 shares group 1 with 4
        assert!(g1 >= g2);
    }

    #[test]
    fn gain_matches_eval_difference() {
        let d = GroupSqrtDiversity::round_robin(8, 2, 0.5);
        let set = vec![0, 1, 2];
        for a in 3..8 {
            let g = d.gain(&set, a);
            let mut s2 = set.clone();
            s2.push(a);
            let delta = d.eval(&s2) - d.eval(&set);
            assert!((g - delta).abs() < 1e-12);
        }
        assert_eq!(d.gain(&set, 1), 0.0); // already in set
    }

    #[test]
    fn diverse_objective_combines() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 40, 8, 4, 0.3);
        let base = LinearRegressionObjective::new(&ds);
        let base_val = base.eval(&[0, 1]);
        let div = GroupSqrtDiversity::round_robin(8, 2, 0.1);
        let div_val = div.eval(&[0, 1]);
        let combined = DiverseObjective::new(base, div);
        let v = combined.eval(&[0, 1]);
        assert!((v - (base_val + div_val)).abs() < 1e-10);
    }

    #[test]
    fn diverse_gain_consistency() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::regression_d1(&mut rng, 40, 8, 4, 0.3);
        let obj = DiverseObjective::new(
            LinearRegressionObjective::new(&ds),
            GroupSqrtDiversity::round_robin(8, 3, 0.05),
        );
        let st = obj.state_for(&[2, 5]);
        for a in [0usize, 3, 7] {
            let g = st.gain(a);
            let delta = obj.eval(&[2, 5, a]) - obj.eval(&[2, 5]);
            assert!((g - delta).abs() < 1e-8, "a={a}: {g} vs {delta}");
        }
    }

    #[test]
    fn diverse_prefers_spread() {
        // equal-information features: diversity term should break ties
        // toward covering more groups
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 60, 6, 6, 0.0);
        let obj = DiverseObjective::new(
            LinearRegressionObjective::new(&ds),
            GroupSqrtDiversity::new(vec![0, 0, 0, 1, 1, 1], 10.0),
        );
        // starting from {0} (group 0), a group-1 element has higher div gain
        let st = obj.state_for(&[0]);
        let g_same = st.gain(1);
        let g_cross = st.gain(3);
        assert!(g_cross > g_same);
    }
}
