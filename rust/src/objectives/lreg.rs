//! Feature selection for linear regression (paper §3.1, Cor. 7) and the
//! Appendix F `R²` variant.
//!
//! Objective: `ℓ_reg(y, w^(S)) = ‖y‖² − ‖y − X_S w‖²` at the least-squares
//! optimum — i.e. the squared norm of the projection of `y` onto
//! `span(X_S)`. We report it normalized by `‖y‖²` so `f ∈ [0, 1]` (for
//! column-standardized data this equals R²).
//!
//! State: an incremental thin QR of the selected columns plus the residual
//! `r = y − Q Qᵀ y`. With `Q` orthonormal the exact marginal gain of a
//! candidate column `x` is
//!
//! ```text
//! f_S(a) = (xᵀ r)² / (‖x‖² − ‖Qᵀ x‖²)
//! ```
//!
//! (projection of `y` onto the component of `x` orthogonal to `span(X_S)`),
//! computed in O(d·|S|) per candidate and O(d) once `Qᵀx` is formed — this
//! is exactly the math the L1 Pallas kernel `lreg_gains` batches on the
//! XLA path.
//!
//! The native batched path is the blocked `gains_into` kernel: per
//! [`SWEEP_BLOCK`]-sized candidate block, one level-3 `gemm_tn(Q, X_C)`
//! for all the `Qᵀx` projections plus one `gemv_t(X_C, r)` for all the
//! numerators, replacing per-candidate level-1 dots.

use super::{Objective, ObjectiveState, SweepScratch, SWEEP_BLOCK};
use crate::data::Dataset;
use crate::linalg::{dot, gemm_tn_into, gemv_t, IncrementalQr, Matrix};
use std::sync::Arc;

/// Shared immutable problem data.
struct LregProblem {
    x: Matrix,
    y: Vec<f64>,
    y_sq: f64,
    /// precomputed ‖x_j‖² per column (perf: saves a d-length dot in every
    /// gain query — see EXPERIMENTS.md §Perf)
    col_sq: Vec<f64>,
    name: String,
}

/// Feature selection objective for linear regression.
#[derive(Clone)]
pub struct LinearRegressionObjective {
    p: Arc<LregProblem>,
}

impl LinearRegressionObjective {
    /// Build from a dataset (uses `ds.x` as `d × n` feature matrix and
    /// `ds.y` as response). Columns should be standardized; see
    /// [`Dataset::normalize_columns`].
    pub fn new(ds: &Dataset) -> Self {
        Self::from_parts(ds.x.clone(), ds.y.clone(), &format!("lreg[{}]", ds.name))
    }

    /// Build directly from a feature matrix and response.
    pub fn from_parts(x: Matrix, y: Vec<f64>, name: &str) -> Self {
        assert_eq!(x.rows(), y.len(), "response/sample mismatch");
        let y_sq = dot(&y, &y).max(1e-300);
        let col_sq = (0..x.cols()).map(|j| dot(x.col(j), x.col(j))).collect();
        LinearRegressionObjective {
            p: Arc::new(LregProblem { x, y, y_sq, col_sq, name: name.to_string() }),
        }
    }

    /// The underlying feature matrix (used by the XLA batcher).
    pub fn features(&self) -> &Matrix {
        &self.p.x
    }

    pub fn response(&self) -> &[f64] {
        &self.p.y
    }
}

struct LregState {
    p: Arc<LregProblem>,
    qr: IncrementalQr,
    /// residual y − Q Qᵀ y
    r: Vec<f64>,
    /// f(S) (normalized)
    value: f64,
    set: Vec<usize>,
    in_set: Vec<bool>,
}

impl LregState {
    fn new(p: Arc<LregProblem>) -> Self {
        let n = p.x.cols();
        let d = p.x.rows();
        LregState {
            r: p.y.clone(),
            qr: IncrementalQr::new(d),
            value: 0.0,
            set: Vec::new(),
            in_set: vec![false; n],
            p,
        }
    }

    /// Unnormalized gain of candidate column.
    fn raw_gain(&self, a: usize) -> f64 {
        if self.in_set[a] {
            return 0.0;
        }
        let x = self.p.x.col(a);
        let num = dot(x, &self.r);
        let norm_sq = self.p.col_sq[a];
        let den = (norm_sq - self.qr.proj_sq_norm(x)).max(0.0);
        if den <= 1e-12 * norm_sq.max(1e-300) {
            return 0.0; // numerically in span: no new direction
        }
        (num * num / den).max(0.0)
    }
}

impl ObjectiveState for LregState {
    fn value(&self) -> f64 {
        self.value
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        assert!(a < self.p.x.cols(), "element out of range");
        if self.in_set[a] {
            return;
        }
        self.in_set[a] = true;
        self.set.push(a);
        let x = self.p.x.col(a);
        // orthogonalize and, if independent, update residual + value
        let before_rank = self.qr.rank();
        if self.qr.push_col(x) {
            debug_assert_eq!(self.qr.rank(), before_rank + 1);
            let q = self.qr.basis_col(before_rank);
            let c = dot(q, &self.r);
            crate::linalg::axpy(-c, q, &mut self.r);
            self.value += c * c / self.p.y_sq;
        }
    }

    fn gain(&self, a: usize) -> f64 {
        self.raw_gain(a) / self.p.y_sq
    }

    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        // blocked kernel: per SWEEP_BLOCK candidates, gather X_C once, then
        // Qᵀ·X_C as one level-3 gemm_tn (all projections) and X_Cᵀ·r as one
        // gemv_t (all numerators); the per-candidate tail is O(|S|)
        debug_assert_eq!(candidates.len(), out.len());
        let d = self.p.x.rows();
        let q = self.qr.basis(); // d × s
        let s = q.cols();
        for (blk, out_blk) in
            candidates.chunks(SWEEP_BLOCK).zip(out.chunks_mut(SWEEP_BLOCK))
        {
            let b = blk.len();
            scratch.xc.resize_uninit(d, b);
            for (jj, &a) in blk.iter().enumerate() {
                scratch.xc.col_mut(jj).copy_from_slice(self.p.x.col(a));
            }
            scratch.prod.resize_uninit(s, b);
            gemm_tn_into(q, &scratch.xc, &mut scratch.prod);
            scratch.r1.resize(b, 0.0);
            gemv_t(&scratch.xc, &self.r, &mut scratch.r1);
            for (jj, (&a, o)) in blk.iter().zip(out_blk.iter_mut()).enumerate() {
                if self.in_set[a] {
                    *o = 0.0;
                    continue;
                }
                // columnwise ‖Qᵀx‖² via the SIMD dot (the per-block
                // denominator tail); same dispatched kernel as the shard
                // path, so sharding stays bit-identical
                let pcol = scratch.prod.col(jj);
                let proj: f64 = dot(pcol, pcol);
                let num = scratch.r1[jj];
                let norm_sq = self.p.col_sq[a];
                let den = (norm_sq - proj).max(0.0);
                *o = if den <= 1e-12 * norm_sq.max(1e-300) {
                    0.0 // numerically in span: no new direction
                } else {
                    (num * num / den).max(0.0) / self.p.y_sq
                };
            }
        }
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(LregState {
            p: Arc::clone(&self.p),
            qr: self.qr.clone(),
            r: self.r.clone(),
            value: self.value,
            set: self.set.clone(),
            in_set: self.in_set.clone(),
        })
    }
}

impl Objective for LinearRegressionObjective {
    fn n(&self) -> usize {
        self.p.x.cols()
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }

    fn name(&self) -> &str {
        &self.p.name
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(LregState::new(Arc::clone(&self.p)))
    }
}

/// The Appendix F objective: `R²(S)` — identical machinery with the
/// response standardized to mean 0 / variance 1, so the value *is* the
/// squared multiple correlation.
#[derive(Clone)]
pub struct R2Objective {
    inner: LinearRegressionObjective,
}

impl R2Objective {
    pub fn new(ds: &Dataset) -> Self {
        let mut y = ds.y.clone();
        let d = y.len().max(1);
        let mean = y.iter().sum::<f64>() / d as f64;
        for v in &mut y {
            *v -= mean;
        }
        let var = (dot(&y, &y) / d as f64).max(1e-300);
        let inv = 1.0 / var.sqrt();
        for v in &mut y {
            *v *= inv;
        }
        R2Objective {
            inner: LinearRegressionObjective::from_parts(
                ds.x.clone(),
                y,
                &format!("r2[{}]", ds.name),
            ),
        }
    }
}

impl Objective for R2Objective {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        self.inner.empty_state()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, Task};
    use crate::linalg::solve_lstsq;
    use crate::rng::Pcg64;

    fn toy_ds(rng: &mut Pcg64, d: usize, n: usize) -> Dataset {
        synthetic::regression_d1(rng, d, n, n / 2, 0.3)
    }

    /// reference: f(S) via explicit least squares
    fn eval_ref(ds: &Dataset, set: &[usize]) -> f64 {
        let y_sq = dot(&ds.y, &ds.y);
        if set.is_empty() {
            return 0.0;
        }
        let xs = ds.x.select_cols(set);
        let w = solve_lstsq(&xs, &ds.y);
        let mut fit = vec![0.0; ds.d()];
        crate::linalg::gemv(&xs, &w, &mut fit);
        let resid_sq: f64 = ds.y.iter().zip(&fit).map(|(a, b)| (a - b) * (a - b)).sum();
        (y_sq - resid_sq) / y_sq
    }

    #[test]
    fn matches_least_squares_reference() {
        let mut rng = Pcg64::seed_from(1);
        let ds = toy_ds(&mut rng, 60, 12);
        let obj = LinearRegressionObjective::new(&ds);
        for set in [vec![0], vec![1, 5], vec![0, 3, 7, 11], (0..12).collect::<Vec<_>>()] {
            let inc = obj.eval(&set);
            let reference = eval_ref(&ds, &set);
            assert!((inc - reference).abs() < 1e-8, "set {set:?}: {inc} vs {reference}");
        }
    }

    #[test]
    fn gain_equals_eval_delta() {
        let mut rng = Pcg64::seed_from(2);
        let ds = toy_ds(&mut rng, 50, 10);
        let obj = LinearRegressionObjective::new(&ds);
        let st = obj.state_for(&[2, 4]);
        for a in [0usize, 1, 7, 9] {
            let g = st.gain(a);
            let delta = obj.eval(&[2, 4, a]) - obj.eval(&[2, 4]);
            assert!((g - delta).abs() < 1e-8, "a={a}: gain {g} vs delta {delta}");
        }
    }

    #[test]
    fn monotone_and_bounded() {
        let mut rng = Pcg64::seed_from(3);
        let ds = toy_ds(&mut rng, 40, 8);
        let obj = LinearRegressionObjective::new(&ds);
        let mut st = obj.empty_state();
        let mut prev = 0.0;
        for a in 0..8 {
            st.insert(a);
            let v = st.value();
            assert!(v >= prev - 1e-12, "monotone violated at {a}");
            assert!(v <= 1.0 + 1e-9);
            prev = v;
        }
    }

    #[test]
    fn duplicate_and_dependent_inserts() {
        let mut rng = Pcg64::seed_from(4);
        let ds = toy_ds(&mut rng, 30, 6);
        let obj = LinearRegressionObjective::new(&ds);
        let mut st = obj.empty_state();
        st.insert(0);
        let v1 = st.value();
        st.insert(0); // duplicate: no-op
        assert_eq!(st.value(), v1);
        assert_eq!(st.set(), &[0]);
        // gain of an element already in S is 0
        assert_eq!(st.gain(0), 0.0);
    }

    #[test]
    fn full_set_explains_signal() {
        let mut rng = Pcg64::seed_from(5);
        // low noise: selecting everything should give f near 1
        let ds = synthetic::regression_d1(&mut rng, 200, 10, 10, 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let v = obj.eval(&(0..10).collect::<Vec<_>>());
        assert!(v > 0.95, "full-set value {v}");
    }

    #[test]
    fn r2_objective_in_unit_range() {
        let mut rng = Pcg64::seed_from(6);
        let mut ds = toy_ds(&mut rng, 50, 8);
        // shift y so centering matters
        for v in &mut ds.y {
            *v += 10.0;
        }
        let obj = R2Objective::new(&ds);
        let v = obj.eval(&(0..8).collect::<Vec<_>>());
        assert!((0.0..=1.0 + 1e-9).contains(&v), "r2 {v}");
        // R² of empty set is 0
        assert_eq!(obj.eval(&[]), 0.0);
    }

    #[test]
    fn batch_gains_match_singletons() {
        let mut rng = Pcg64::seed_from(7);
        let ds = toy_ds(&mut rng, 40, 10);
        let obj = LinearRegressionObjective::new(&ds);
        let st = obj.state_for(&[1, 3]);
        let cands = vec![0, 2, 5, 9];
        let batch = st.gains(&cands);
        for (i, &a) in cands.iter().enumerate() {
            // blocked kernel accumulates Qᵀx in tiled order; agreement is
            // to rounding, not to the bit
            assert!((batch[i] - st.gain(a)).abs() < 1e-12);
        }
    }

    #[test]
    fn blocked_kernel_spans_multiple_blocks() {
        let mut rng = Pcg64::seed_from(10);
        // n > SWEEP_BLOCK forces the per-block loop; include in-set
        // candidates to hit the zero path inside a block
        let ds = toy_ds(&mut rng, 60, 80);
        let obj = LinearRegressionObjective::new(&ds);
        let st = obj.state_for(&[5, 40, 77]);
        let cands: Vec<usize> = (0..80).collect();
        let batch = st.gains(&cands);
        for (i, &a) in cands.iter().enumerate() {
            assert!(
                (batch[i] - st.gain(a)).abs() < 1e-12,
                "a={a}: {} vs {}",
                batch[i],
                st.gain(a)
            );
        }
        assert_eq!(batch[5], 0.0);
        assert_eq!(batch[77], 0.0);
    }

    #[test]
    fn out_of_range_panics() {
        let mut rng = Pcg64::seed_from(8);
        let ds = toy_ds(&mut rng, 20, 4);
        let obj = LinearRegressionObjective::new(&ds);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut st = obj.empty_state();
            st.insert(4);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn state_task_is_regression() {
        let mut rng = Pcg64::seed_from(9);
        let ds = toy_ds(&mut rng, 20, 4);
        assert_eq!(ds.task, Task::Regression);
    }
}
