//! Multiclass feature selection via a one-vs-rest reduction (used for the
//! 5-class gene workload D4, paper Fig. 3 bottom row).
//!
//! The paper's `ℓ_class` is the binary logistic log-likelihood; for the
//! 5-class dataset we sum one-vs-rest binary objectives:
//! `f(S) = (1/C) Σ_c f_c(S)` where `f_c` is the normalized binary logistic
//! objective for class-c-vs-rest. Each `f_c` is γ²-differentially
//! submodular (Cor. 8), and differential submodularity is closed under
//! nonnegative sums with the same sandwich functions' sum, so `f` inherits
//! the guarantee with α = min_c α_c. The substitution is recorded in
//! DESIGN.md §3.

use super::{LogisticObjective, Objective, ObjectiveState, SweepScratch};
use crate::data::{Dataset, Task};
use crate::linalg::Matrix;
use std::sync::Arc;

/// One-vs-rest multiclass objective.
#[derive(Clone)]
pub struct OvrSoftmaxObjective {
    per_class: Arc<Vec<LogisticObjective>>,
    n: usize,
    classes: usize,
    name: String,
}

impl OvrSoftmaxObjective {
    /// Build the objective. Non-classification datasets are a typed error
    /// (the serving stack can route arbitrary dataset/objective pairings
    /// here, so this must not panic).
    pub fn new(ds: &Dataset) -> Result<Self, String> {
        let classes = match ds.task {
            Task::MultiClassification { classes } => classes,
            Task::BinaryClassification => 2,
            _ => {
                return Err(
                    "OvrSoftmaxObjective requires a classification dataset"
                        .into(),
                )
            }
        };
        let per_class: Vec<LogisticObjective> = (0..classes)
            .map(|c| {
                let y_bin: Vec<f64> =
                    ds.y.iter().map(|&l| if l as usize == c { 1.0 } else { 0.0 }).collect();
                LogisticObjective::from_parts(
                    ds.x.clone(),
                    y_bin,
                    &format!("ovr{c}[{}]", ds.name),
                )
            })
            .collect();
        Ok(OvrSoftmaxObjective {
            n: ds.n(),
            classes,
            name: format!("ovr-softmax[{}]", ds.name),
            per_class: Arc::new(per_class),
        })
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Multiclass accuracy: predict argmax_c of the class-c margin.
    pub fn accuracy_on(&self, set: &[usize], x_eval: &Matrix, labels: &[f64]) -> f64 {
        if labels.is_empty() {
            return 0.0;
        }
        if set.is_empty() {
            // majority class
            let mut counts = vec![0usize; self.classes];
            for &l in labels {
                counts[l as usize] += 1;
            }
            let majority = counts.iter().max().copied().unwrap_or(0);
            return majority as f64 / labels.len().max(1) as f64;
        }
        let d = x_eval.rows();
        let xs = x_eval.select_cols(set);
        // stack the per-class weight vectors into one |S| × C matrix and
        // score every class in a single level-3 product X_S · W (d × C) —
        // one pass over X_S through the SIMD gemm panels instead of C
        // separate gemvs. A class whose refit produced mismatched weights
        // keeps a zero column (score 0, as before).
        let mut wmat = Matrix::zeros(set.len(), self.classes);
        for (c, obj) in self.per_class.iter().enumerate() {
            let st = obj.state_for(set);
            let w = st.as_logistic_weights().unwrap_or_default();
            if w.len() == set.len() {
                wmat.col_mut(c).copy_from_slice(&w);
            }
        }
        let scores = crate::linalg::gemm(&xs, &wmat);
        let mut correct = 0usize;
        for i in 0..d {
            let mut best = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            for c in 0..self.classes {
                let v = scores.get(i, c);
                if v > best_v {
                    best_v = v;
                    best = c;
                }
            }
            if best == labels[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / d as f64
    }
}

struct OvrState {
    states: Vec<Box<dyn ObjectiveState>>,
    classes: usize,
    set: Vec<usize>,
}

impl ObjectiveState for OvrState {
    fn value(&self) -> f64 {
        self.states.iter().map(|s| s.value()).sum::<f64>() / self.classes as f64
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        if self.set.contains(&a) {
            return;
        }
        self.set.push(a);
        for s in &mut self.states {
            s.insert(a);
        }
    }

    fn gain(&self, a: usize) -> f64 {
        self.states.iter().map(|s| s.gain(a)).sum::<f64>() / self.classes as f64
    }

    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        // per-class sweeps share this shard's scratch; a local buffer
        // collects each class's partial before averaging (the per-class
        // logistic states use the documented scalar-refit fallback, so the
        // allocation is noise next to the Newton refits)
        let mut tmp = vec![0.0; candidates.len()];
        out.fill(0.0);
        for s in &self.states {
            s.gains_into(candidates, scratch, &mut tmp);
            for (o, g) in out.iter_mut().zip(&tmp) {
                *o += *g;
            }
        }
        let inv = 1.0 / self.classes as f64;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(OvrState {
            states: self.states.iter().map(|s| s.clone_box()).collect(),
            classes: self.classes,
            set: self.set.clone(),
        })
    }
}

impl Objective for OvrSoftmaxObjective {
    fn n(&self) -> usize {
        self.n
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(OvrState {
            states: self.per_class.iter().map(|o| o.empty_state()).collect(),
            classes: self.classes,
            set: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gene_sim::{gene_d4, GeneConfig};
    use crate::rng::Pcg64;

    fn small_ds(rng: &mut Pcg64) -> Dataset {
        gene_d4(
            rng,
            &GeneConfig {
                samples: 300,
                genes: 30,
                classes: 3,
                informative_per_class: 4,
                ..Default::default()
            },
        )
    }

    #[test]
    fn value_monotone_and_normalized() {
        let mut rng = Pcg64::seed_from(1);
        let ds = small_ds(&mut rng);
        let obj = OvrSoftmaxObjective::new(&ds).unwrap();
        assert_eq!(obj.classes(), 3);
        let mut st = obj.empty_state();
        assert_eq!(st.value(), 0.0);
        let mut prev = 0.0;
        for a in [0usize, 5, 10, 15] {
            st.insert(a);
            assert!(st.value() >= prev - 1e-9);
            assert!(st.value() <= 1.0);
            prev = st.value();
        }
    }

    #[test]
    fn gain_consistency() {
        let mut rng = Pcg64::seed_from(2);
        let ds = small_ds(&mut rng);
        let obj = OvrSoftmaxObjective::new(&ds).unwrap();
        let st = obj.state_for(&[1]);
        let g = st.gain(8);
        let delta = obj.eval(&[1, 8]) - obj.eval(&[1]);
        assert!((g - delta).abs() < 1e-3, "{g} vs {delta}");
    }

    #[test]
    fn informative_genes_improve_accuracy() {
        let mut rng = Pcg64::seed_from(3);
        let ds = gene_d4(
            &mut rng,
            &GeneConfig {
                samples: 800,
                genes: 40,
                classes: 3,
                informative_per_class: 6,
                effect: 0.5,
                ..Default::default()
            },
        );
        let obj = OvrSoftmaxObjective::new(&ds).unwrap();
        let base = obj.accuracy_on(&[], &ds.x, &ds.y);
        let acc = obj.accuracy_on(&ds.true_support, &ds.x, &ds.y);
        assert!(acc > base + 0.1, "acc {acc} vs majority {base}");
    }

    #[test]
    fn rejects_regression_data() {
        let mut rng = Pcg64::seed_from(4);
        let ds = crate::data::synthetic::regression_d1(&mut rng, 20, 5, 2, 0.2);
        let err = OvrSoftmaxObjective::new(&ds).unwrap_err();
        assert!(err.contains("classification dataset"), "{err}");
    }
}
