//! Bayesian A-optimal experimental design (paper §3.1 + Appendix D,
//! Cor. 9).
//!
//! Objective: expected posterior-variance reduction under the linear model
//! `y_S = X_Sᵀθ + noise`, `θ ~ N(0, Λ⁻¹)`, `Λ = β² I`:
//!
//! ```text
//! f_A-opt(S) = Tr(Λ⁻¹) − Tr((Λ + σ⁻² X_S X_Sᵀ)⁻¹)
//! ```
//!
//! State: the posterior covariance `M = (Λ + σ⁻² X_S X_Sᵀ)⁻¹` maintained
//! explicitly via the Sherman–Morrison identity — adding stimulus `x`
//! updates `M` in O(d²) and gives the exact marginal gain in closed form:
//!
//! ```text
//! f_S(a) = σ⁻² ‖M x_a‖² / (1 + σ⁻² x_aᵀ M x_a)
//! ```
//!
//! This is the math the L1 Pallas kernel `aopt_gains` batches over
//! candidate tiles (`M · X_C` is a single d×d×|C| matmul).
//!
//! The native batched path mirrors it: the blocked `gains_into` kernel
//! computes `M · X_C` as one level-3 [`gemm_into`] per
//! [`SWEEP_BLOCK`]-sized candidate block and finishes with columnwise
//! reductions, instead of one `gemv` per candidate. The engine's
//! sequential sweep and every shard of its parallel sweep run this same
//! kernel — there is exactly one batched-gain implementation.

use super::{Objective, ObjectiveState, SweepScratch, SWEEP_BLOCK};
use crate::data::Dataset;
use crate::linalg::{dot, dot2, gemm_into, Matrix};
use std::sync::Arc;

struct AoptProblem {
    /// stimuli, d × n (one column per selectable experiment)
    x: Matrix,
    beta_sq: f64,
    sigma_sq_inv: f64,
    /// Tr(Λ⁻¹) = d / β², the normalization constant
    prior_trace: f64,
    name: String,
}

/// Bayesian A-optimality objective for experimental design.
#[derive(Clone)]
pub struct AOptimalityObjective {
    p: Arc<AoptProblem>,
}

impl AOptimalityObjective {
    /// `beta_sq` is the prior precision β² (Λ = β²I); `sigma_sq` the
    /// observation noise variance σ².
    pub fn new(ds: &Dataset, beta_sq: f64, sigma_sq: f64) -> Self {
        Self::from_parts(ds.x.clone(), beta_sq, sigma_sq, &format!("aopt[{}]", ds.name))
    }

    pub fn from_parts(x: Matrix, beta_sq: f64, sigma_sq: f64, name: &str) -> Self {
        assert!(beta_sq > 0.0 && sigma_sq > 0.0);
        let d = x.rows();
        AOptimalityObjective {
            p: Arc::new(AoptProblem {
                x,
                beta_sq,
                sigma_sq_inv: 1.0 / sigma_sq,
                prior_trace: d as f64 / beta_sq,
                name: name.to_string(),
            }),
        }
    }

    pub fn stimuli(&self) -> &Matrix {
        &self.p.x
    }

    pub fn params(&self) -> (f64, f64) {
        (self.p.beta_sq, 1.0 / self.p.sigma_sq_inv)
    }

    /// The paper's γ lower bound for this instance (Cor. 9):
    /// `β² / (‖X‖² (β² + σ⁻²‖X‖²))` with ‖X‖ the spectral norm.
    pub fn gamma_bound(&self) -> f64 {
        let g = crate::linalg::syrk(&self.p.x); // XᵀX, n×n — spectral norm via λmax
        // for large n this is heavy; sample-based power iteration instead
        let x_sq = if g.rows() <= 256 {
            crate::linalg::sym_extreme_eigs(&g).1
        } else {
            power_iter_sym(&g, 100)
        };
        self.p.beta_sq / (x_sq * (self.p.beta_sq + self.p.sigma_sq_inv * x_sq)).max(1e-300)
    }
}

/// Relative-change tolerance at which power iteration declares the leading
/// eigenvalue converged. `gamma_bound` only needs λmax to the resolution of
/// its γ lower bound — well-separated spectra converge in a handful of
/// iterations, and each saved iteration is one n×n gemv.
const POWER_ITER_TOL: f64 = 1e-12;

/// Iterations always run before the early exit may fire. A start vector
/// nearly orthogonal to the dominant eigenvector plateaus at a subdominant
/// eigenvalue first; the floor gives the dominant component room to
/// surface before the relative-change test is trusted.
const POWER_ITER_MIN: usize = 8;

/// Largest eigenvalue of a symmetric PSD matrix by power iteration, with a
/// relative-change early exit (`iters` is a cap, not a fixed count).
fn power_iter_sym(a: &Matrix, iters: usize) -> f64 {
    power_iter_sym_count(a, iters).0
}

/// [`power_iter_sym`] plus the number of iterations actually run (the
/// early-exit tests observe this).
fn power_iter_sym_count(a: &Matrix, iters: usize) -> (f64, usize) {
    let n = a.rows();
    // deterministic pseudo-random start: a uniform vector is structurally
    // orthogonal to the dominant eigenvector of e.g. centered Gram
    // matrices, which would make the early exit lock onto λ₂; varied signs
    // make that orthogonality a measure-zero accident instead
    let mut seed = 0x9E37_79B9_7F4A_7C15u64;
    let mut v: Vec<f64> = (0..n)
        .map(|_| {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // map the top bits to (-1, 1), excluding 0
            ((seed >> 11) as f64 / (1u64 << 53) as f64).mul_add(2.0, -1.0) + 1e-3
        })
        .collect();
    let inv = 1.0 / crate::linalg::nrm2(&v).max(1e-300);
    for vi in &mut v {
        *vi *= inv;
    }
    let mut lambda = 0.0;
    let mut av = vec![0.0; n];
    for it in 0..iters {
        crate::linalg::gemv(a, &v, &mut av);
        let norm = crate::linalg::nrm2(&av);
        if norm < 1e-300 {
            return (0.0, it + 1);
        }
        let rel = (norm - lambda).abs() / norm;
        lambda = norm;
        for (vi, avi) in v.iter_mut().zip(&av) {
            *vi = avi / norm;
        }
        if rel <= POWER_ITER_TOL && it + 1 >= POWER_ITER_MIN {
            return (lambda, it + 1);
        }
    }
    (lambda, iters)
}

struct AoptState {
    p: Arc<AoptProblem>,
    /// posterior covariance M (d × d), starts at Λ⁻¹ = I/β²
    m: Matrix,
    /// Tr(M)
    trace: f64,
    set: Vec<usize>,
    in_set: Vec<bool>,
}

impl AoptState {
    fn new(p: Arc<AoptProblem>) -> Self {
        let d = p.x.rows();
        let n = p.x.cols();
        let mut m = Matrix::zeros(d, d);
        let inv_beta = 1.0 / p.beta_sq;
        for i in 0..d {
            m.set(i, i, inv_beta);
        }
        AoptState { trace: p.prior_trace, m, set: Vec::new(), in_set: vec![false; n], p }
    }

    /// (M x, xᵀ M x) for a stimulus column.
    fn mx(&self, a: usize) -> (Vec<f64>, f64) {
        let x = self.p.x.col(a);
        let mut mx = vec![0.0; x.len()];
        crate::linalg::gemv(&self.m, x, &mut mx);
        let xmx = dot(x, &mx);
        (mx, xmx)
    }
}

impl ObjectiveState for AoptState {
    fn value(&self) -> f64 {
        // normalized: (Tr(Λ⁻¹) − Tr(M)) / Tr(Λ⁻¹) ∈ [0, 1)
        ((self.p.prior_trace - self.trace) / self.p.prior_trace).max(0.0)
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        assert!(a < self.p.x.cols(), "element out of range");
        if self.in_set[a] {
            return;
        }
        self.in_set[a] = true;
        self.set.push(a);
        let s2 = self.p.sigma_sq_inv;
        let (mx, xmx) = self.mx(a);
        let denom = 1.0 + s2 * xmx;
        // M ← M − σ⁻² (Mx)(Mx)ᵀ / (1 + σ⁻² xᵀMx)
        let scale = s2 / denom;
        let d = self.m.rows();
        for j in 0..d {
            let mxj = mx[j];
            if mxj == 0.0 {
                continue;
            }
            let col = self.m.col_mut(j);
            let c = scale * mxj;
            for (i, cell) in col.iter_mut().enumerate() {
                *cell -= c * mx[i];
            }
        }
        self.trace -= scale * dot(&mx, &mx);
    }

    fn gain(&self, a: usize) -> f64 {
        if self.in_set[a] {
            return 0.0;
        }
        let s2 = self.p.sigma_sq_inv;
        let (mx, xmx) = self.mx(a);
        let raw = s2 * dot(&mx, &mx) / (1.0 + s2 * xmx);
        (raw / self.p.prior_trace).max(0.0)
    }

    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        // blocked kernel: per SWEEP_BLOCK candidates, gather X_C once and
        // compute M · X_C as one level-3 gemm (register-tiled; streams the
        // d×d posterior once per 4 candidates instead of once per gemv),
        // then finish with columnwise reductions — the pattern mirrored by
        // the Pallas kernel
        debug_assert_eq!(candidates.len(), out.len());
        let d = self.m.rows();
        let s2 = self.p.sigma_sq_inv;
        for (blk, out_blk) in
            candidates.chunks(SWEEP_BLOCK).zip(out.chunks_mut(SWEEP_BLOCK))
        {
            let b = blk.len();
            scratch.xc.resize_uninit(d, b);
            for (jj, &a) in blk.iter().enumerate() {
                scratch.xc.col_mut(jj).copy_from_slice(self.p.x.col(a));
            }
            scratch.prod.resize_uninit(d, b);
            gemm_into(&self.m, &scratch.xc, &mut scratch.prod);
            for (jj, (&a, o)) in blk.iter().zip(out_blk.iter_mut()).enumerate() {
                if self.in_set[a] {
                    *o = 0.0;
                    continue;
                }
                let x = scratch.xc.col(jj);
                let mx = scratch.prod.col(jj);
                // fused columnwise tail: (xᵀMx, ‖Mx‖²) in one SIMD pass,
                // each component bit-identical to the two separate dots
                let (xmx, mm) = dot2(x, mx);
                let raw = s2 * mm / (1.0 + s2 * xmx);
                *o = (raw / self.p.prior_trace).max(0.0);
            }
        }
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(AoptState {
            p: Arc::clone(&self.p),
            m: self.m.clone(),
            trace: self.trace,
            set: self.set.clone(),
            in_set: self.in_set.clone(),
        })
    }
}

impl Objective for AOptimalityObjective {
    fn n(&self) -> usize {
        self.p.x.cols()
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }

    fn name(&self) -> &str {
        &self.p.name
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(AoptState::new(Arc::clone(&self.p)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::cholesky;
    use crate::rng::Pcg64;

    fn toy(rng: &mut Pcg64, d: usize, n: usize) -> AOptimalityObjective {
        let ds = synthetic::design_d1(rng, d, n, 0.5);
        AOptimalityObjective::new(&ds, 1.0, 1.0)
    }

    /// reference: exact Tr((Λ + σ⁻²X_S X_Sᵀ)⁻¹) via Cholesky
    fn eval_ref(obj: &AOptimalityObjective, set: &[usize]) -> f64 {
        let x = obj.stimuli();
        let d = x.rows();
        let (beta_sq, sigma_sq) = obj.params();
        let mut a = Matrix::zeros(d, d);
        for i in 0..d {
            a.set(i, i, beta_sq);
        }
        for &j in set {
            let col = x.col(j);
            for p in 0..d {
                for q in 0..d {
                    a.add_at(p, q, col[p] * col[q] / sigma_sq);
                }
            }
        }
        let f = cholesky(&a).unwrap();
        let prior = d as f64 / beta_sq;
        (prior - f.inv_trace()) / prior
    }

    #[test]
    fn matches_direct_inverse() {
        let mut rng = Pcg64::seed_from(1);
        let obj = toy(&mut rng, 8, 20);
        for set in [vec![], vec![3], vec![0, 5, 9], (0..15).collect::<Vec<_>>()] {
            let inc = obj.eval(&set);
            let reference = eval_ref(&obj, &set);
            assert!((inc - reference).abs() < 1e-9, "set {set:?}: {inc} vs {reference}");
        }
    }

    #[test]
    fn gain_equals_eval_delta() {
        let mut rng = Pcg64::seed_from(2);
        let obj = toy(&mut rng, 10, 30);
        let st = obj.state_for(&[1, 7, 20]);
        for a in [0usize, 5, 29] {
            let g = st.gain(a);
            let delta = obj.eval(&[1, 7, 20, a]) - obj.eval(&[1, 7, 20]);
            assert!((g - delta).abs() < 1e-10, "a={a}: {g} vs {delta}");
        }
    }

    #[test]
    fn monotone_bounded_and_submodular_ratio_positive() {
        let mut rng = Pcg64::seed_from(3);
        let obj = toy(&mut rng, 6, 25);
        let mut st = obj.empty_state();
        let mut prev = 0.0;
        for a in 0..25 {
            st.insert(a);
            let v = st.value();
            assert!(v >= prev - 1e-12);
            assert!(v < 1.0);
            prev = v;
        }
    }

    #[test]
    fn batch_gains_match_singletons() {
        let mut rng = Pcg64::seed_from(4);
        let obj = toy(&mut rng, 8, 20);
        let st = obj.state_for(&[2, 11]);
        let cands: Vec<usize> = vec![0, 2, 6, 19];
        let batch = st.gains(&cands);
        for (i, &a) in cands.iter().enumerate() {
            // blocked gemm accumulates M·x in panel order; agreement is to
            // rounding, not to the bit
            assert!((batch[i] - st.gain(a)).abs() < 1e-12);
        }
        assert_eq!(batch[1], 0.0); // already in set
    }

    #[test]
    fn blocked_kernel_spans_multiple_blocks() {
        let mut rng = Pcg64::seed_from(8);
        let obj = toy(&mut rng, 10, 70); // > SWEEP_BLOCK candidates
        let st = obj.state_for(&[0, 33, 69]);
        let cands: Vec<usize> = (0..70).collect();
        let batch = st.gains(&cands);
        for (i, &a) in cands.iter().enumerate() {
            assert!(
                (batch[i] - st.gain(a)).abs() < 1e-12,
                "a={a}: {} vs {}",
                batch[i],
                st.gain(a)
            );
        }
        assert_eq!(batch[0], 0.0);
        assert_eq!(batch[33], 0.0);
        assert_eq!(batch[69], 0.0);
    }

    #[test]
    fn duplicate_insert_noop() {
        let mut rng = Pcg64::seed_from(5);
        let obj = toy(&mut rng, 6, 10);
        let mut st = obj.empty_state();
        st.insert(4);
        let v = st.value();
        let tr_before = obj.eval(&[4]);
        st.insert(4);
        assert_eq!(st.value(), v);
        assert!((v - tr_before).abs() < 1e-12);
    }

    #[test]
    fn gamma_bound_in_unit_interval() {
        let mut rng = Pcg64::seed_from(6);
        let obj = toy(&mut rng, 8, 30);
        let g = obj.gamma_bound();
        assert!(g > 0.0 && g <= 1.0, "gamma {g}");
    }

    #[test]
    fn power_iteration_matches_jacobi() {
        let mut rng = Pcg64::seed_from(7);
        let mut b = Matrix::zeros(12, 12);
        for j in 0..12 {
            for i in 0..12 {
                b.set(i, j, rng.next_gaussian());
            }
        }
        let a = crate::linalg::syrk(&b);
        let exact = crate::linalg::sym_extreme_eigs(&a).1;
        let approx = power_iter_sym(&a, 300);
        assert!((exact - approx).abs() / exact < 1e-6, "{exact} vs {approx}");
    }

    #[test]
    fn power_iteration_early_exits_when_converged() {
        // a strongly separated spectrum converges in a handful of
        // iterations; the early exit must fire long before the cap
        let mut a = Matrix::identity(16);
        a.set(0, 0, 100.0);
        let (lambda, iters) = power_iter_sym_count(&a, 10_000);
        assert!((lambda - 100.0).abs() < 1e-6, "lambda {lambda}");
        assert!(iters < 100, "should stop early, ran {iters} iterations");
        // the cap still binds when convergence is slower than the cap
        let (_, capped) = power_iter_sym_count(&a, 2);
        assert_eq!(capped, 2);
    }

    #[test]
    fn power_iteration_zero_matrix() {
        let a = Matrix::zeros(8, 8);
        let (lambda, iters) = power_iter_sym_count(&a, 50);
        assert_eq!(lambda, 0.0);
        assert_eq!(iters, 1, "null operator detected on the first gemv");
    }
}
