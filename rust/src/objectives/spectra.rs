//! Spectral diagnostics: estimates of the paper's weak-submodularity ratio
//! γ and differential-submodularity ratio α = γ², plus the Figure 1
//! marginal-contribution "sandwich" data.
//!
//! - For regression (Cor. 7): `γ = λmin(2k)/λmax(2k)` over k-sparse
//!   covariance submatrices — estimated by sampling random 2k-subsets and
//!   taking extreme eigenvalues of the induced covariance blocks.
//! - Empirical sandwich (Fig. 1): fix an element `a`, sample many random
//!   sets `S`, record `f_S(a)` — differential submodularity predicts the
//!   cloud lies between two submodular envelopes proportional to each
//!   other by α.

use super::Objective;
use crate::linalg::{gemm_tn, sym_extreme_eigs, Matrix};
use crate::rng::Pcg64;

/// Estimate `(λmin(s), λmax(s))` of the feature covariance restricted to
/// random s-subsets (columns assumed standardized; covariance = XᵀX/d).
/// Returns the worst case over `trials` random subsets (min of mins, max of
/// maxes) — a sampled surrogate for the paper's restricted spectra.
pub fn sparse_spectrum(
    x: &Matrix,
    s: usize,
    trials: usize,
    rng: &mut Pcg64,
) -> (f64, f64) {
    let n = x.cols();
    let d = x.rows() as f64;
    let s = s.min(n).max(1);
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for _ in 0..trials.max(1) {
        let idx = rng.sample_indices(n, s);
        let xs = x.select_cols(&idx);
        let mut cov = gemm_tn(&xs, &xs);
        cov.scale(1.0 / d);
        let (l, h) = sym_extreme_eigs(&cov);
        lo = lo.min(l);
        hi = hi.max(h);
    }
    (lo.max(0.0), hi)
}

/// Sampled estimate of the regression γ = λmin(2k)/λmax(2k) (Cor. 7).
pub fn regression_gamma(x: &Matrix, k: usize, trials: usize, rng: &mut Pcg64) -> f64 {
    let (lo, hi) = sparse_spectrum(x, 2 * k, trials, rng);
    if hi <= 0.0 {
        return 0.0;
    }
    (lo / hi).clamp(0.0, 1.0)
}

/// α = γ² — the differential-submodularity ratio the paper's guarantees
/// are stated in.
pub fn regression_alpha(x: &Matrix, k: usize, trials: usize, rng: &mut Pcg64) -> f64 {
    let g = regression_gamma(x, k, trials, rng);
    g * g
}

/// One Figure-1 scatter point: for a fixed element `a` and random set size
/// `|S|`, the marginal `f_S(a)` together with `|S|`.
#[derive(Debug, Clone, Copy)]
pub struct SandwichPoint {
    pub set_size: usize,
    pub marginal: f64,
}

/// Generate Fig. 1 data: marginal contribution of `a` onto `trials` random
/// sets of each size in `sizes`.
pub fn sandwich_scatter(
    obj: &dyn Objective,
    a: usize,
    sizes: &[usize],
    trials: usize,
    rng: &mut Pcg64,
) -> Vec<SandwichPoint> {
    let n = obj.n();
    let mut out = Vec::with_capacity(sizes.len() * trials);
    for &s in sizes {
        for _ in 0..trials {
            let mut set: Vec<usize> = rng
                .sample_indices(n, (s + 1).min(n))
                .into_iter()
                .filter(|&b| b != a)
                .collect();
            set.truncate(s.min(n.saturating_sub(1)));
            let st = obj.state_for(&set);
            out.push(SandwichPoint { set_size: set.len(), marginal: st.gain(a) });
        }
    }
    out
}

/// Empirical differential-submodularity check over random (S, A) pairs:
/// returns the observed min and max of `Σ_{a∈A} f_S(a) / f_S(A)` — Thm. 6
/// predicts this ratio is sandwiched within `[γ, 1/γ]`-style bounds.
pub fn marginal_ratio_range(
    obj: &dyn Objective,
    set_size: usize,
    a_size: usize,
    trials: usize,
    rng: &mut Pcg64,
) -> (f64, f64) {
    let n = obj.n();
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for _ in 0..trials {
        let all = rng.sample_indices(n, (set_size + a_size).min(n));
        let (s_part, a_part) = all.split_at(set_size.min(all.len()));
        if a_part.is_empty() {
            continue;
        }
        let st = obj.state_for(s_part);
        let sum_singles: f64 = a_part.iter().map(|&a| st.gain(a)).sum();
        let set_gain = obj.set_gain(&*st, a_part);
        if set_gain > 1e-12 {
            let r = sum_singles / set_gain;
            lo = lo.min(r);
            hi = hi.max(r);
        }
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;

    #[test]
    fn spectrum_of_orthogonal_features_is_unit() {
        // identity-like: uncorrelated standardized features have cov ≈ I
        let mut rng = Pcg64::seed_from(1);
        let x = synthetic::correlated_features(&mut rng, 5000, 10, 0.0);
        let (lo, hi) = sparse_spectrum(&x, 4, 8, &mut rng);
        assert!(lo > 0.7 && hi < 1.3, "({lo}, {hi})");
    }

    #[test]
    fn correlation_shrinks_gamma() {
        let mut rng = Pcg64::seed_from(2);
        let x0 = synthetic::correlated_features(&mut rng, 3000, 20, 0.0);
        let x8 = synthetic::correlated_features(&mut rng, 3000, 20, 0.8);
        let g0 = regression_gamma(&x0, 4, 6, &mut rng);
        let g8 = regression_gamma(&x8, 4, 6, &mut rng);
        assert!(g0 > g8, "gamma should fall with correlation: {g0} vs {g8}");
        assert!(g0 <= 1.0 && g8 > 0.0);
    }

    #[test]
    fn alpha_is_gamma_squared() {
        let mut data_rng = Pcg64::seed_from(3);
        let x = synthetic::correlated_features(&mut data_rng, 1000, 12, 0.4);
        let g = regression_gamma(&x, 3, 5, &mut Pcg64::seed_from(7));
        let a = regression_alpha(&x, 3, 5, &mut Pcg64::seed_from(7));
        assert!((a - g * g).abs() < 1e-12);
    }

    #[test]
    fn sandwich_scatter_shapes() {
        let mut rng = Pcg64::seed_from(4);
        let ds = synthetic::regression_d1(&mut rng, 100, 15, 8, 0.4);
        let obj = LinearRegressionObjective::new(&ds);
        let pts = sandwich_scatter(&obj, 0, &[0, 2, 5], 4, &mut rng);
        assert_eq!(pts.len(), 12);
        assert!(pts.iter().all(|p| p.marginal >= -1e-12 && p.marginal.is_finite()));
        // set sizes recorded correctly (a excluded from S)
        assert!(pts.iter().all(|p| p.set_size <= 5));
        // at |S| = 0 the marginal equals the singleton value exactly
        let singleton = obj.eval(&[0]);
        for p in pts.iter().filter(|p| p.set_size == 0) {
            assert!((p.marginal - singleton).abs() < 1e-10);
        }
    }

    #[test]
    fn ratio_range_is_finite_and_ordered() {
        let mut rng = Pcg64::seed_from(5);
        let ds = synthetic::regression_d1(&mut rng, 120, 12, 6, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let (lo, hi) = marginal_ratio_range(&obj, 3, 3, 20, &mut rng);
        assert!(lo.is_finite() && hi.is_finite());
        assert!(lo <= hi);
        assert!(lo > 0.0, "ratios positive for this objective: {lo}");
    }
}
