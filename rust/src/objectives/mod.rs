//! The paper's objective functions and their incremental oracle states.
//!
//! Each objective `f : 2^N → ℝ₊` is normalized, monotone and
//! γ²-differentially submodular (paper §3):
//!
//! - [`LinearRegressionObjective`] — `ℓ_reg`, variance reduction (Cor. 7)
//! - [`R2Objective`] — the Appendix F goodness-of-fit variant
//! - [`LogisticObjective`] — `ℓ_class`, logistic log-likelihood (Cor. 8)
//! - [`OvrSoftmaxObjective`] — one-vs-rest multiclass reduction (D4)
//! - [`AOptimalityObjective`] — Bayesian A-optimality (Cor. 9)
//! - [`DiverseObjective`] — any of the above plus a submodular `d(S)`
//! - [`counterexamples`] — the Appendix A constructions used in tests
//!
//! Design: an [`Objective`] spawns a cheap-to-clone [`ObjectiveState`] that
//! supports `insert` (grow S by one element) and batched marginal gains on
//! top of the current S. Algorithms never recompute `f(S)` from scratch in
//! their inner loops.
//!
//! Batched sweeps go through [`ObjectiveState::gains_into`]: a *read-only*
//! blocked kernel (`&self`, caller-owned [`SweepScratch`]) so the engine in
//! [`oracle::batch`](crate::oracle::batch) can shard one state across a
//! thread pool without forking it. See the contract on the method.

mod lreg;
mod logistic;
mod softmax;
mod aopt;
mod diversity;
pub mod counterexamples;
pub mod spectra;

pub use aopt::AOptimalityObjective;
pub use diversity::{DiverseObjective, DiversityTerm, GroupSqrtDiversity};
pub use logistic::LogisticObjective;
pub use lreg::{LinearRegressionObjective, R2Objective};
pub use softmax::OvrSoftmaxObjective;

use crate::linalg::Matrix;

/// Candidate-block width of every blocked gain kernel. Block boundaries are
/// fixed by candidate *index* (multiples of this constant from the start of
/// the sweep), never by shard count, so a sharded sweep decomposes into
/// exactly the blocks the sequential sweep would process — the basis of the
/// engine's bit-identical-under-sharding guarantee.
pub const SWEEP_BLOCK: usize = 32;

/// Reusable per-shard scratch arena for blocked gain sweeps.
///
/// [`ObjectiveState::gains_into`] implementations draw every temporary from
/// here instead of allocating (or worse, mutating interior state): the
/// engine hands each shard its own arena, which is what makes the sweep
/// path safe to run on one shared `&ObjectiveState` with zero `clone_box`.
/// Buffers are resized on demand and their prior contents are unspecified;
/// kernels must fully overwrite whatever they read.
#[derive(Debug)]
pub struct SweepScratch {
    /// gathered candidate block `X_C` (d × B, column-major)
    pub xc: Matrix,
    /// kernel product block (`Qᵀ·X_C`, `M·X_C`, …)
    pub prod: Matrix,
    /// per-candidate reduction buffer (length B)
    pub r1: Vec<f64>,
}

impl Default for SweepScratch {
    fn default() -> Self {
        SweepScratch {
            xc: Matrix::zeros(0, 0),
            prod: Matrix::zeros(0, 0),
            r1: Vec::new(),
        }
    }
}

impl SweepScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Incremental evaluation state for one solution set `S`.
///
/// States are snapshots: cloning (`clone_box`) forks the state so DASH can
/// evaluate speculative sets `S ∪ R` without disturbing `S`.
pub trait ObjectiveState: Send + Sync {
    /// Current `f(S)`.
    fn value(&self) -> f64;

    /// Elements currently in `S` (insertion order).
    fn set(&self) -> &[usize];

    /// Grow `S ← S ∪ {a}`. Inserting an element already in `S` is a no-op.
    fn insert(&mut self, a: usize);

    /// Marginal gain `f_S(a)` of a single candidate.
    fn gain(&self, a: usize) -> f64;

    /// Blocked batched gains: write `f_S(candidates[i])` to `out[i]`,
    /// drawing temporaries from `scratch`. This is the sweep-engine entry
    /// point; implementations must obey the contract:
    ///
    /// - **read-only** — `&self`, no interior mutation: the engine runs
    ///   many shards against one shared state with zero `clone_box`;
    /// - **block-determinism** — candidates are processed in
    ///   [`SWEEP_BLOCK`]-sized blocks counted from the start of the slice,
    ///   and each candidate's gain depends only on its own block, so a
    ///   sweep sharded at block boundaries is bit-identical to the
    ///   sequential sweep regardless of shard count;
    /// - `out.len() == candidates.len()` and every element is written.
    ///
    /// Default: the scalar per-element path over [`ObjectiveState::gain`]
    /// (trivially block-deterministic). Objectives override with level-3
    /// blocked kernels where profitable.
    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        let _ = scratch;
        debug_assert_eq!(candidates.len(), out.len());
        for (o, &a) in out.iter_mut().zip(candidates) {
            *o = self.gain(a);
        }
    }

    /// Sharding granularity for this state's sweeps: the engine cuts a
    /// sweep at multiples of this many candidates, counted from the start
    /// of the sweep. Defaults to [`SWEEP_BLOCK`]. States whose batched
    /// path is an external dispatch with its own batch shape (the XLA
    /// oracles' padded `nc`) return that shape so sharding does not
    /// fragment one dispatch into many. Must be ≥ 1, constant for the
    /// life of the state, and independent of shard count — it is part of
    /// the block-determinism contract above.
    fn sweep_block(&self) -> usize {
        SWEEP_BLOCK
    }

    /// Batched marginal gains `f_S(a)` for each candidate. Routed through
    /// [`ObjectiveState::gains_into`] with a throwaway scratch so there is
    /// exactly one batched-gain implementation per objective.
    fn gains(&self, candidates: &[usize]) -> Vec<f64> {
        let mut out = vec![0.0; candidates.len()];
        self.gains_into(candidates, &mut SweepScratch::default(), &mut out);
        out
    }

    /// Fork the state.
    fn clone_box(&self) -> Box<dyn ObjectiveState>;

    /// Fitted logistic weights aligned with `set()`, if this state belongs
    /// to a logistic-family objective (used for accuracy reporting).
    fn as_logistic_weights(&self) -> Option<Vec<f64>> {
        None
    }
}

/// A normalized monotone set function over ground set `0..n`.
pub trait Objective: Sync {
    /// Ground-set size.
    fn n(&self) -> usize;

    /// Short identifier (used in reports).
    fn name(&self) -> &str;

    /// State for `S = ∅`.
    fn empty_state(&self) -> Box<dyn ObjectiveState>;

    /// A known upper bound on `f` (normalized objectives return 1.0); used
    /// to seed DASH's OPT guess. `None` = unbounded/unknown.
    fn upper_bound(&self) -> Option<f64> {
        None
    }

    /// State for an arbitrary `S` (default: inserts one by one).
    fn state_for(&self, set: &[usize]) -> Box<dyn ObjectiveState> {
        let mut st = self.empty_state();
        for &a in set {
            st.insert(a);
        }
        st
    }

    /// `f(S)` evaluated from scratch.
    fn eval(&self, set: &[usize]) -> f64 {
        self.state_for(set).value()
    }

    /// `f_S(A)` — marginal contribution of a *set* `A` on top of `S`
    /// (needed by DASH's round-acceptance test).
    fn set_gain(&self, state: &dyn ObjectiveState, add: &[usize]) -> f64 {
        self.set_gain_state(state, add).0
    }

    /// [`Objective::set_gain`] plus the constructed `S ∪ A` state, for
    /// callers that need both (DASH evaluates `f_S(R)` for sample blocks
    /// and, on acceptance or filtering, reuses the very same states — one
    /// construction, one counted oracle query).
    fn set_gain_state(
        &self,
        state: &dyn ObjectiveState,
        add: &[usize],
    ) -> (f64, Box<dyn ObjectiveState>) {
        let mut st = state.clone_box();
        let before = st.value();
        for &a in add {
            st.insert(a);
        }
        let gain = st.value() - before;
        (gain, st)
    }
}

/// Dedup helper: returns `set` with duplicates removed, preserving order.
pub fn dedup_set(set: &[usize]) -> Vec<usize> {
    let mut seen = std::collections::HashSet::new();
    set.iter().copied().filter(|a| seen.insert(*a)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_preserves_order() {
        assert_eq!(dedup_set(&[3, 1, 3, 2, 1]), vec![3, 1, 2]);
        assert!(dedup_set(&[]).is_empty());
    }
}
