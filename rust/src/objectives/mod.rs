//! The paper's objective functions and their incremental oracle states.
//!
//! Each objective `f : 2^N → ℝ₊` is normalized, monotone and
//! γ²-differentially submodular (paper §3):
//!
//! - [`LinearRegressionObjective`] — `ℓ_reg`, variance reduction (Cor. 7)
//! - [`R2Objective`] — the Appendix F goodness-of-fit variant
//! - [`LogisticObjective`] — `ℓ_class`, logistic log-likelihood (Cor. 8)
//! - [`OvrSoftmaxObjective`] — one-vs-rest multiclass reduction (D4)
//! - [`AOptimalityObjective`] — Bayesian A-optimality (Cor. 9)
//! - [`DiverseObjective`] — any of the above plus a submodular `d(S)`
//! - [`counterexamples`] — the Appendix A constructions used in tests
//!
//! Design: an [`Objective`] spawns a cheap-to-clone [`ObjectiveState`] that
//! supports `insert` (grow S by one element) and batched marginal gains on
//! top of the current S. Algorithms never recompute `f(S)` from scratch in
//! their inner loops.

mod lreg;
mod logistic;
mod softmax;
mod aopt;
mod diversity;
pub mod counterexamples;
pub mod spectra;

pub use aopt::AOptimalityObjective;
pub use diversity::{DiverseObjective, DiversityTerm, GroupSqrtDiversity};
pub use logistic::LogisticObjective;
pub use lreg::{LinearRegressionObjective, R2Objective};
pub use softmax::OvrSoftmaxObjective;

/// Incremental evaluation state for one solution set `S`.
///
/// States are snapshots: cloning (`clone_box`) forks the state so DASH can
/// evaluate speculative sets `S ∪ R` without disturbing `S`.
pub trait ObjectiveState: Send + Sync {
    /// Current `f(S)`.
    fn value(&self) -> f64;

    /// Elements currently in `S` (insertion order).
    fn set(&self) -> &[usize];

    /// Grow `S ← S ∪ {a}`. Inserting an element already in `S` is a no-op.
    fn insert(&mut self, a: usize);

    /// Marginal gain `f_S(a)` of a single candidate.
    fn gain(&self, a: usize) -> f64;

    /// Batched marginal gains `f_S(a)` for each candidate. Default loops
    /// over [`ObjectiveState::gain`]; objectives override with vectorized
    /// math where profitable.
    fn gains(&self, candidates: &[usize]) -> Vec<f64> {
        candidates.iter().map(|&a| self.gain(a)).collect()
    }

    /// Fork the state.
    fn clone_box(&self) -> Box<dyn ObjectiveState>;

    /// Fitted logistic weights aligned with `set()`, if this state belongs
    /// to a logistic-family objective (used for accuracy reporting).
    fn as_logistic_weights(&self) -> Option<Vec<f64>> {
        None
    }
}

/// A normalized monotone set function over ground set `0..n`.
pub trait Objective: Sync {
    /// Ground-set size.
    fn n(&self) -> usize;

    /// Short identifier (used in reports).
    fn name(&self) -> &str;

    /// State for `S = ∅`.
    fn empty_state(&self) -> Box<dyn ObjectiveState>;

    /// A known upper bound on `f` (normalized objectives return 1.0); used
    /// to seed DASH's OPT guess. `None` = unbounded/unknown.
    fn upper_bound(&self) -> Option<f64> {
        None
    }

    /// State for an arbitrary `S` (default: inserts one by one).
    fn state_for(&self, set: &[usize]) -> Box<dyn ObjectiveState> {
        let mut st = self.empty_state();
        for &a in set {
            st.insert(a);
        }
        st
    }

    /// `f(S)` evaluated from scratch.
    fn eval(&self, set: &[usize]) -> f64 {
        self.state_for(set).value()
    }

    /// `f_S(A)` — marginal contribution of a *set* `A` on top of `S`
    /// (needed by DASH's round-acceptance test).
    fn set_gain(&self, state: &dyn ObjectiveState, add: &[usize]) -> f64 {
        self.set_gain_state(state, add).0
    }

    /// [`Objective::set_gain`] plus the constructed `S ∪ A` state, for
    /// callers that need both (DASH evaluates `f_S(R)` for sample blocks
    /// and, on acceptance or filtering, reuses the very same states — one
    /// construction, one counted oracle query).
    fn set_gain_state(
        &self,
        state: &dyn ObjectiveState,
        add: &[usize],
    ) -> (f64, Box<dyn ObjectiveState>) {
        let mut st = state.clone_box();
        let before = st.value();
        for &a in add {
            st.insert(a);
        }
        let gain = st.value() - before;
        (gain, st)
    }
}

/// Dedup helper: returns `set` with duplicates removed, preserving order.
pub fn dedup_set(set: &[usize]) -> Vec<usize> {
    let mut seen = std::collections::HashSet::new();
    set.iter().copied().filter(|a| seen.insert(*a)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_preserves_order() {
        assert_eq!(dedup_set(&[3, 1, 3, 2, 1]), vec![3, 1, 2]);
        assert!(dedup_set(&[]).is_empty());
    }
}
