//! The Appendix A constructions: instances where submodular adaptive
//! sampling provably fails but DASH's α-scaled thresholds succeed. Used by
//! integration tests and the `appendix-a` experiment.

use super::{Objective, ObjectiveState};

/// Appendix A.1/A.2: `f(S) = min{2·u(S) + 1, 2·v(S)}` over ground set
/// `U ∪ V` (`u(S) = |S ∩ U|`, `v(S) = |S ∩ V|`); elements `0..k` are `U`,
/// `k..2k` are `V`. Nonnegative, monotone, 0.5-weakly submodular
/// (Lemma 11); *not* differentially submodular globally, but its
/// restriction to small sets is 0.25-differentially submodular (Lemma 12).
///
/// Plain adaptive sampling filters out all of `U` (singleton value 0) and
/// then can never assemble a set of V-elements whose joint marginal meets
/// the α=1 threshold — the infinite-while-loop example.
pub struct MinCounterexample {
    pub k: usize,
}

impl MinCounterexample {
    pub fn new(k: usize) -> Self {
        MinCounterexample { k }
    }

    /// Optimal value under cardinality k: alternate U/V elements.
    pub fn opt(&self) -> f64 {
        // choose ⌈k/2⌉ from V and ⌊k/2⌋ from U:
        // min(2⌊k/2⌋+1, 2⌈k/2⌉) = k for even k, k for odd k
        self.k as f64
    }
}

struct MinState {
    k: usize,
    set: Vec<usize>,
    value: f64,
}

impl MinState {
    fn f_of(&self, set: &[usize]) -> f64 {
        let u = set.iter().filter(|&&a| a < self.k).count() as f64;
        let v = set.iter().filter(|&&a| a >= self.k && a < 2 * self.k).count() as f64;
        (2.0 * u + 1.0).min(2.0 * v)
    }
}

impl ObjectiveState for MinState {
    fn value(&self) -> f64 {
        self.value
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        if self.set.contains(&a) {
            return;
        }
        self.set.push(a);
        self.value = self.f_of(&self.set);
    }

    fn gain(&self, a: usize) -> f64 {
        if self.set.contains(&a) {
            return 0.0;
        }
        let mut s2 = self.set.clone();
        s2.push(a);
        self.f_of(&s2) - self.value
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(MinState { k: self.k, set: self.set.clone(), value: self.value })
    }
}

impl Objective for MinCounterexample {
    fn n(&self) -> usize {
        2 * self.k
    }

    fn name(&self) -> &str {
        "appendix-a-min"
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(MinState { k: self.k, set: Vec::new(), value: 0.0 })
    }
}

/// Appendix A.2's concrete 6-feature R² instance: `y = e₁`,
/// `x₁..x₃ = e₂..e₄`, `x₄..x₆ = (e₁+e_j)/√2`. Optimal 2-subsets pair an
/// `x_{4..6}` with its matching `x_{1..3}` for R² = 1; any 2-subset of
/// `{x₄,x₅,x₆}` reaches only 2/3.
pub fn r2_instance() -> crate::objectives::LinearRegressionObjective {
    use crate::linalg::Matrix;
    let s = (0.5f64).sqrt();
    let cols: Vec<Vec<f64>> = vec![
        vec![0.0, 1.0, 0.0, 0.0],
        vec![0.0, 0.0, 1.0, 0.0],
        vec![0.0, 0.0, 0.0, 1.0],
        vec![s, s, 0.0, 0.0],
        vec![s, 0.0, s, 0.0],
        vec![s, 0.0, 0.0, s],
    ];
    let col_refs: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
    let x = Matrix::from_cols(4, &col_refs);
    let y = vec![1.0, 0.0, 0.0, 0.0];
    crate::objectives::LinearRegressionObjective::from_parts(x, y, "appendix-a2-r2")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectives::Objective;

    #[test]
    fn min_construction_values() {
        let f = MinCounterexample::new(4);
        assert_eq!(f.n(), 8);
        // singletons: u elements worth 0, v elements worth 1
        assert_eq!(f.eval(&[0]), 0.0);
        assert_eq!(f.eval(&[4]), 1.0);
        // all-V subsets stuck at 1
        assert_eq!(f.eval(&[4, 5, 6, 7]), 1.0);
        // balanced set achieves k
        assert_eq!(f.eval(&[0, 1, 4, 5]), 4.0);
        assert_eq!(f.opt(), 4.0);
    }

    #[test]
    fn min_is_monotone() {
        let f = MinCounterexample::new(3);
        let mut st = f.empty_state();
        let mut prev = 0.0;
        for a in [3usize, 0, 4, 1, 5, 2] {
            st.insert(a);
            assert!(st.value() >= prev);
            prev = st.value();
        }
        // full ground set: u = v = 3 → min(2·3+1, 2·3) = 6
        assert_eq!(prev, 6.0);
    }

    #[test]
    fn min_weak_submodularity_ratio_half() {
        // Lemma 11: γ = 0.5 witnessed by S={u₁}, A=V:
        // Σ_a f_S(a) grows while f_S(A) = ... check the specific ratio
        let f = MinCounterexample::new(3);
        let st = f.state_for(&[0]); // S = {u_0}, f(S)=0... f({u0}) = min(3,0)=0
        let a_set: Vec<usize> = vec![3, 4, 5];
        let sum_singles: f64 = a_set.iter().map(|&a| st.gain(a)).sum();
        let set_gain = f.eval(&[0, 3, 4, 5]) - f.eval(&[0]);
        // f({u0,v*3}) = min(3, 6) = 3; singles: each v adds min(3, 2·1)=...
        // f_S(v) = min(3,2)-0 = 2 each -> sum 6, set gain 3 => ratio 2
        assert_eq!(set_gain, 3.0);
        assert_eq!(sum_singles, 6.0);
    }

    #[test]
    fn dash_terminates_and_meets_alpha_bound() {
        // DASH with α-scaled thresholds (α = 0.5 per Lemma 12) and known
        // OPT must terminate on the min-construction and, averaged over
        // seeds, clear the Theorem 10 bound (1 − 1/e^{α²})·OPT (ε = 0).
        use crate::algorithms::{Dash, DashConfig, OptEstimate};
        use crate::rng::Pcg64;
        for k in [2usize, 4] {
            let f = MinCounterexample::new(k);
            let opt = f.opt();
            let alpha = 0.5f64;
            let bound = (1.0 - (-alpha * alpha).exp()) * opt;
            let seeds = [1u64, 2, 3, 4, 5];
            let mut values = Vec::new();
            for &seed in &seeds {
                let mut rng = Pcg64::seed_from(seed);
                let r = Dash::new(DashConfig {
                    k,
                    r: 0, // auto: ⌈log₂ n⌉ blocks
                    epsilon: 0.0,
                    alpha,
                    samples: 32,
                    opt: OptEstimate::Known(opt),
                    opt_guesses: 1,
                    max_rounds: 120,
                    max_filter_iters: 0,
                })
                .run(&f, &mut rng);
                assert!(
                    !r.hit_iteration_cap,
                    "k={k} seed={seed}: DASH must terminate (rounds {})",
                    r.rounds
                );
                values.push(r.value);
            }
            let mean = crate::util::mean(&values);
            assert!(
                mean >= bound,
                "k={k}: mean value {mean} below α-bound {bound} (values {values:?})"
            );
        }
    }

    #[test]
    fn plain_submodular_thresholds_hit_round_cap() {
        // α = 1 (plain submodular thresholds) exercised under an explicit
        // round cap: the Appendix A.2 failure mode must be flagged via
        // hit_iteration_cap, never an endless loop
        use crate::algorithms::{Dash, DashConfig, OptEstimate};
        use crate::rng::Pcg64;
        for k in [2usize, 4] {
            let f = MinCounterexample::new(k);
            let mut rng = Pcg64::seed_from(3);
            let r = Dash::new(DashConfig {
                k,
                r: 1,
                epsilon: 0.0,
                alpha: 1.0,
                samples: 32,
                opt: OptEstimate::Known(f.opt()),
                opt_guesses: 1,
                max_rounds: 60,
                max_filter_iters: 0,
            })
            .run(&f, &mut rng);
            assert!(r.hit_iteration_cap, "k={k}: α=1 must hit the cap");
            assert!(r.value < f.opt(), "k={k}: α=1 must not reach OPT");
            assert!(r.rounds <= 60, "k={k}: cap must bound the rounds");
        }
    }

    #[test]
    fn r2_instance_matches_appendix() {
        let obj = r2_instance();
        // optimal pairs achieve 1
        for pair in [[0usize, 3], [1, 4], [2, 5]] {
            let v = obj.eval(&pair);
            assert!((v - 1.0).abs() < 1e-10, "pair {pair:?} -> {v}");
        }
        // singletons: e-vectors 0, mixed vectors 1/2
        for a in 0..3 {
            assert!(obj.eval(&[a]).abs() < 1e-12);
        }
        for a in 3..6 {
            assert!((obj.eval(&[a]) - 0.5).abs() < 1e-12);
        }
        // any 2-subset of the mixed vectors: 2/3
        for pair in [[3usize, 4], [3, 5], [4, 5]] {
            let v = obj.eval(&pair);
            assert!((v - 2.0 / 3.0).abs() < 1e-10, "pair {pair:?} -> {v}");
        }
    }
}
