//! Datasets: the in-memory [`Dataset`] type plus generators for the paper's
//! four workloads (D1–D4, Appendix I.2). Where the paper used proprietary
//! clinical/gene data (D2, D4) we generate synthetic analogs with matched
//! dimensions and spectra — see DESIGN.md §3 for the substitution argument.

mod dataset;
pub mod synthetic;
pub mod clinical_sim;
pub mod gene_sim;

pub use dataset::{Dataset, Task};
