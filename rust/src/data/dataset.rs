//! The core dataset container shared by all objectives and experiments.

use crate::linalg::Matrix;
use crate::rng::Pcg64;
use crate::util::csvio::CsvTable;
use std::path::Path;

/// What the response variable means.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// continuous response; objective `ℓ_reg` / R²
    Regression,
    /// binary labels in {0,1}; objective `ℓ_class`
    BinaryClassification,
    /// labels in {0..classes-1}; softmax log-likelihood
    MultiClassification { classes: usize },
    /// no response; experimental design over sample columns
    Design,
}

/// A dataset: feature matrix `x` of shape `d × n` (one *column per feature*
/// for selection problems; one column per experimental stimulus for design
/// problems) and an optional response `y` of length `d`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub x: Matrix,
    pub y: Vec<f64>,
    pub task: Task,
    /// indices of the true support when the data is synthetic (diagnostics)
    pub true_support: Vec<usize>,
}

impl Dataset {
    pub fn new(name: &str, x: Matrix, y: Vec<f64>, task: Task) -> Self {
        if !matches!(task, Task::Design) {
            assert_eq!(y.len(), x.rows(), "response length must equal sample count");
        }
        Dataset { name: name.to_string(), x, y, task, true_support: Vec::new() }
    }

    /// Number of selectable elements (feature columns / stimuli).
    pub fn n(&self) -> usize {
        self.x.cols()
    }

    /// Number of samples (rows).
    pub fn d(&self) -> usize {
        self.x.rows()
    }

    /// Standardize every column to mean 0, variance 1 (paper's preprocessing
    /// for D1/D2). Constant columns are left centered.
    pub fn normalize_columns(&mut self) {
        let d = self.d();
        for j in 0..self.n() {
            let col = self.x.col_mut(j);
            let mean = col.iter().sum::<f64>() / d as f64;
            for v in col.iter_mut() {
                *v -= mean;
            }
            let var = col.iter().map(|v| v * v).sum::<f64>() / d as f64;
            if var > 1e-12 {
                let inv = 1.0 / var.sqrt();
                for v in col.iter_mut() {
                    *v *= inv;
                }
            }
        }
    }

    /// Normalize every *row* to unit ℓ2 norm (paper's preprocessing for the
    /// experimental-design datasets, where rows are stimuli dimensions).
    pub fn normalize_rows(&mut self) {
        for i in 0..self.d() {
            let norm: f64 = (0..self.n()).map(|j| self.x.get(i, j).powi(2)).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for j in 0..self.n() {
                    let v = self.x.get(i, j) / norm;
                    self.x.set(i, j, v);
                }
            }
        }
    }

    /// Normalize every *column* to unit ℓ2 norm.
    pub fn normalize_column_norms(&mut self) {
        for j in 0..self.n() {
            let col = self.x.col_mut(j);
            let norm = crate::linalg::nrm2(col);
            if norm > 1e-12 {
                crate::linalg::scal(1.0 / norm, col);
            }
        }
    }

    /// Random row subsample (paper: "we sample 1000 rows from the dataset").
    pub fn subsample_rows(&self, rng: &mut Pcg64, rows: usize) -> Dataset {
        let rows = rows.min(self.d());
        let idx = rng.sample_indices(self.d(), rows);
        let x = self.x.select_rows(&idx);
        let y = if self.y.is_empty() {
            Vec::new()
        } else {
            idx.iter().map(|&i| self.y[i]).collect()
        };
        Dataset {
            name: format!("{}-sub{rows}", self.name),
            x,
            y,
            task: self.task,
            true_support: self.true_support.clone(),
        }
    }

    /// Train/test split by rows (for held-out classification accuracy).
    pub fn split(&self, rng: &mut Pcg64, train_frac: f64) -> (Dataset, Dataset) {
        let d = self.d();
        let n_train = ((d as f64) * train_frac).round() as usize;
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let (tr, te) = idx.split_at(n_train.clamp(1, d.saturating_sub(1).max(1)));
        let mk = |rows: &[usize], tag: &str| Dataset {
            name: format!("{}-{tag}", self.name),
            x: self.x.select_rows(rows),
            y: rows.iter().map(|&i| self.y[i]).collect(),
            task: self.task,
            true_support: self.true_support.clone(),
        };
        (mk(tr, "train"), mk(te, "test"))
    }

    /// Persist to CSV: columns `y, x0..x{n-1}` (regression/classification)
    /// or just `x*` for design data.
    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        let has_y = !self.y.is_empty();
        let mut header: Vec<String> = Vec::new();
        if has_y {
            header.push("y".into());
        }
        for j in 0..self.n() {
            header.push(format!("x{j}"));
        }
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = CsvTable::new(&header_refs);
        for i in 0..self.d() {
            let mut row = Vec::with_capacity(header.len());
            if has_y {
                row.push(self.y[i]);
            }
            for j in 0..self.n() {
                row.push(self.x.get(i, j));
            }
            t.push_f64(&row);
        }
        t.save(path)
    }

    /// Load from CSV written by [`Dataset::save_csv`].
    pub fn load_csv(path: &Path, name: &str, task: Task) -> Result<Dataset, String> {
        let t = CsvTable::load(path)?;
        let has_y = t.header.first().map(|h| h == "y").unwrap_or(false);
        let n = t.header.len() - usize::from(has_y);
        let d = t.rows.len();
        if d == 0 || n == 0 {
            return Err("empty dataset".into());
        }
        let mut x = Matrix::zeros(d, n);
        let mut y = Vec::new();
        for (i, row) in t.rows.iter().enumerate() {
            let mut cells = row.iter();
            if has_y {
                let cell = cells
                    .next()
                    .ok_or_else(|| format!("row {i} has no label cell"))?;
                y.push(cell.parse::<f64>().map_err(|e| e.to_string())?);
            }
            for (j, c) in cells.enumerate() {
                x.set(i, j, c.parse::<f64>().map_err(|e| e.to_string())?);
            }
        }
        Ok(Dataset::new(name, x, y, task))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_rows(4, 2, &[1., 10., 2., 20., 3., 30., 4., 40.]);
        Dataset::new("toy", x, vec![0.0, 1.0, 0.0, 1.0], Task::Regression)
    }

    #[test]
    fn dims() {
        let ds = toy();
        assert_eq!(ds.d(), 4);
        assert_eq!(ds.n(), 2);
    }

    #[test]
    fn normalize_columns_stats() {
        let mut ds = toy();
        ds.normalize_columns();
        for j in 0..ds.n() {
            let col = ds.x.col(j);
            let mean: f64 = col.iter().sum::<f64>() / 4.0;
            let var: f64 = col.iter().map(|v| v * v).sum::<f64>() / 4.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn normalize_rows_unit() {
        let mut ds = toy();
        ds.normalize_rows();
        for i in 0..ds.d() {
            let norm: f64 = (0..ds.n()).map(|j| ds.x.get(i, j).powi(2)).sum();
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn normalize_constant_column_safe() {
        let x = Matrix::from_rows(3, 1, &[5.0, 5.0, 5.0]);
        let mut ds = Dataset::new("c", x, vec![0.0; 3], Task::Regression);
        ds.normalize_columns();
        for i in 0..3 {
            assert_eq!(ds.x.get(i, 0), 0.0); // centered, not divided
        }
    }

    #[test]
    fn subsample_and_split() {
        let mut rng = Pcg64::seed_from(1);
        let ds = toy();
        let sub = ds.subsample_rows(&mut rng, 2);
        assert_eq!(sub.d(), 2);
        assert_eq!(sub.n(), 2);
        let (tr, te) = ds.split(&mut rng, 0.5);
        assert_eq!(tr.d() + te.d(), 4);
        assert!(tr.d() >= 1 && te.d() >= 1);
    }

    #[test]
    fn csv_round_trip() {
        let ds = toy();
        let p = std::env::temp_dir().join("dash_ds_test.csv");
        ds.save_csv(&p).unwrap();
        let back = Dataset::load_csv(&p, "toy", Task::Regression).unwrap();
        assert_eq!(back.d(), 4);
        assert_eq!(back.n(), 2);
        assert!(back.x.max_abs_diff(&ds.x) < 1e-9);
        assert_eq!(back.y, ds.y);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    #[should_panic(expected = "response length")]
    fn mismatched_response_panics() {
        let x = Matrix::zeros(3, 2);
        let _ = Dataset::new("bad", x, vec![1.0], Task::Regression);
    }
}
