//! **D4 substitute** — simulated gene presence/absence classification data.
//!
//! The paper's D4 is clinical data with presence/absence of 2,500 genes in
//! 10,633 samples, predicting one of 5 cancer-metastasis sites. The aspects
//! that drive the paper's Fig. 3 bottom row are: binary features, n ≫ k, a
//! 5-class objective whose oracle query is *expensive* (a logistic fit per
//! query — the paper reports >1 minute per marginal and days for sequential
//! greedy), and accuracy that keeps improving out to k = 200.
//!
//! We simulate: genes grouped into pathways (shared activation probability
//! per class), labels from a sparse multinomial model, features Bernoulli.

use super::{Dataset, Task};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Configuration for the simulated gene dataset.
#[derive(Debug, Clone)]
pub struct GeneConfig {
    pub samples: usize,
    pub genes: usize,
    pub classes: usize,
    /// informative genes per class
    pub informative_per_class: usize,
    /// base presence rate for background genes
    pub base_rate: f64,
    /// how strongly informative genes shift presence rate per class
    pub effect: f64,
}

impl Default for GeneConfig {
    fn default() -> Self {
        // paper dims: 2,500 genes, 10,633 samples, 5 classes. Samples
        // reduced to 3,000 for single-core tractability (oracle cost is
        // linear in samples; the accuracy-vs-k shape is preserved).
        GeneConfig {
            samples: 3000,
            genes: 2500,
            classes: 5,
            informative_per_class: 40,
            base_rate: 0.15,
            effect: 0.35,
        }
    }
}

/// Generate the D4 substitute. Labels are `0..classes-1` stored as f64 in
/// `y`; features are 0/1 presence indicators (then column-standardized by
/// the objective if desired).
pub fn gene_d4(rng: &mut Pcg64, cfg: &GeneConfig) -> Dataset {
    let d = cfg.samples;
    let n = cfg.genes;
    let c = cfg.classes.max(2);

    // assign informative genes per class (disjoint)
    let total_info = (cfg.informative_per_class * c).min(n);
    let info = rng.sample_indices(n, total_info);
    let mut class_of_gene: Vec<Option<usize>> = vec![None; n];
    for (rank, &g) in info.iter().enumerate() {
        class_of_gene[g] = Some(rank % c);
    }

    // labels roughly balanced
    let mut y = Vec::with_capacity(d);
    for i in 0..d {
        let _ = i;
        y.push(rng.gen_range_usize(0, c - 1) as f64);
    }

    let mut x = Matrix::zeros(d, n);
    for j in 0..n {
        let col = x.col_mut(j);
        match class_of_gene[j] {
            Some(cls) => {
                for (i, cell) in col.iter_mut().enumerate() {
                    let is_cls = y[i] as usize == cls;
                    let p = if is_cls {
                        (cfg.base_rate + cfg.effect).min(0.95)
                    } else {
                        cfg.base_rate
                    };
                    *cell = if rng.bernoulli(p) { 1.0 } else { 0.0 };
                }
            }
            None => {
                for cell in col.iter_mut() {
                    *cell = if rng.bernoulli(cfg.base_rate) { 1.0 } else { 0.0 };
                }
            }
        }
    }

    let mut ds = Dataset::new(
        "D4-gene-sim",
        x,
        y,
        Task::MultiClassification { classes: c },
    );
    ds.true_support = info;
    ds
}

/// A binary (2-class) reduction of the gene data, used where the binary
/// logistic objective (the paper's `ℓ_class`) is exercised directly.
pub fn gene_d4_binary(rng: &mut Pcg64, cfg: &GeneConfig) -> Dataset {
    let mut cfg2 = cfg.clone();
    cfg2.classes = 2;
    let mut ds = gene_d4(rng, &cfg2);
    ds.name = "D4-gene-sim-binary".into();
    ds.task = Task::BinaryClassification;
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> GeneConfig {
        GeneConfig {
            samples: 500,
            genes: 80,
            classes: 5,
            informative_per_class: 6,
            ..Default::default()
        }
    }

    #[test]
    fn shapes_and_binary_features() {
        let mut rng = Pcg64::seed_from(1);
        let ds = gene_d4(&mut rng, &small());
        assert_eq!(ds.d(), 500);
        assert_eq!(ds.n(), 80);
        assert!(ds.x.data().iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(matches!(ds.task, Task::MultiClassification { classes: 5 }));
    }

    #[test]
    fn labels_in_range_and_all_present() {
        let mut rng = Pcg64::seed_from(2);
        let ds = gene_d4(&mut rng, &small());
        let mut seen = [false; 5];
        for &l in &ds.y {
            let li = l as usize;
            assert!(li < 5);
            seen[li] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn informative_genes_carry_signal() {
        let mut rng = Pcg64::seed_from(3);
        let cfg = GeneConfig { samples: 2000, ..small() };
        let ds = gene_d4(&mut rng, &cfg);
        // an informative gene's presence rate within its class should exceed
        // the background rate
        let g = ds.true_support[0];
        // find its class: rate per class
        let mut rates = vec![(0.0, 0usize); 5];
        for i in 0..ds.d() {
            let cls = ds.y[i] as usize;
            rates[cls].0 += ds.x.get(i, g);
            rates[cls].1 += 1;
        }
        let per_class: Vec<f64> = rates.iter().map(|(s, c)| s / *c as f64).collect();
        let max = per_class.iter().cloned().fold(0.0, f64::max);
        let min = per_class.iter().cloned().fold(1.0, f64::min);
        assert!(max - min > 0.15, "max {max} min {min}");
    }

    #[test]
    fn binary_variant() {
        let mut rng = Pcg64::seed_from(4);
        let ds = gene_d4_binary(&mut rng, &small());
        assert_eq!(ds.task, Task::BinaryClassification);
        assert!(ds.y.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}
