//! **D2 substitute** — simulated clinical regression dataset.
//!
//! The paper's D2 is a proprietary clinical dataset: 53,500 brain-slice
//! image samples × 385 features, response = axial-axis location. What the
//! selection algorithms actually interact with is the *oracle*, so the
//! substitution only needs to preserve the statistical shape:
//!
//! - 385 features with **block correlation** (imaging features cluster into
//!   correlated groups — wider λmax/λmin spread than D1's equicorrelated
//!   design, i.e. smaller γ and a harder instance),
//! - a smooth response driven by a moderately sparse support plus dense
//!   small "background" loadings (real clinical responses are not exactly
//!   sparse), so the accuracy-vs-k curve keeps rising past small k and the
//!   RANDOM baseline does not trivially saturate (paper Fig. 2e shows late
//!   saturation),
//! - many more samples than features.

use super::{Dataset, Task};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Configuration for the simulated clinical data.
#[derive(Debug, Clone)]
pub struct ClinicalConfig {
    pub samples: usize,
    pub features: usize,
    /// number of correlated feature blocks
    pub blocks: usize,
    /// within-block correlation
    pub rho_block: f64,
    /// strong support size
    pub support: usize,
    /// std of the dense background coefficients (relative)
    pub background: f64,
    /// observation noise std relative to signal
    pub noise: f64,
}

impl Default for ClinicalConfig {
    fn default() -> Self {
        // paper dims: 385 features; sample count reduced from 53,500 to a
        // single-core-tractable 8,000 (oracle cost scales linearly in d and
        // the figure shapes are d-insensitive once d >> n)
        ClinicalConfig {
            samples: 8000,
            features: 385,
            blocks: 24,
            rho_block: 0.6,
            support: 60,
            background: 0.05,
            noise: 0.1,
        }
    }
}

/// Generate the D2 substitute.
pub fn clinical_d2(rng: &mut Pcg64, cfg: &ClinicalConfig) -> Dataset {
    let d = cfg.samples;
    let n = cfg.features;
    let blocks = cfg.blocks.max(1).min(n);
    let sr = cfg.rho_block.sqrt();
    let si = (1.0 - cfg.rho_block).sqrt();

    // per-block latent factors
    let mut factors: Vec<Vec<f64>> = Vec::with_capacity(blocks);
    for _ in 0..blocks {
        factors.push((0..d).map(|_| rng.next_gaussian()).collect());
    }

    let mut x = Matrix::zeros(d, n);
    for j in 0..n {
        let b = j % blocks;
        let f = &factors[b];
        let col = x.col_mut(j);
        for (i, c) in col.iter_mut().enumerate() {
            *c = sr * f[i] + si * rng.next_gaussian();
        }
    }

    // response: strong sparse support + dense background + smooth latent
    // (mimics the axial-position signal being predictable from many weakly
    // informative features)
    let support_idx = rng.sample_indices(n, cfg.support.min(n));
    let mut y = vec![0.0; d];
    for &j in &support_idx {
        let beta = rng.gen_range_f64(-2.0, 2.0);
        crate::linalg::axpy(beta, x.col(j), &mut y);
    }
    for j in 0..n {
        let beta = cfg.background * rng.next_gaussian();
        crate::linalg::axpy(beta, x.col(j), &mut y);
    }
    let y_rms = (crate::linalg::dot(&y, &y) / d as f64).sqrt().max(1e-9);
    for v in &mut y {
        *v += cfg.noise * y_rms * rng.next_gaussian();
    }

    let mut ds = Dataset::new("D2-clinical-sim", x, y, Task::Regression);
    ds.normalize_columns();
    ds.true_support = support_idx;
    ds
}

/// The design-problem variant of D2 (paper Fig. 4 bottom row: 1000 rows
/// sampled, rows normalized to unit ℓ2). Stimuli are the dataset *rows*;
/// we expose them as columns of a `features × 1000` matrix.
pub fn clinical_d2_design(rng: &mut Pcg64, cfg: &ClinicalConfig, stimuli: usize) -> Dataset {
    let base = clinical_d2(rng, cfg);
    let rows = rng.sample_indices(base.d(), stimuli.min(base.d()));
    // stimuli live in R^features: take selected rows as vectors
    let mut x = Matrix::zeros(base.n(), rows.len());
    for (jj, &i) in rows.iter().enumerate() {
        let col = x.col_mut(jj);
        for (f, c) in col.iter_mut().enumerate() {
            *c = base.x.get(i, f);
        }
    }
    let mut ds = Dataset::new("D2-clinical-sim-design", x, Vec::new(), Task::Design);
    // normalize each stimulus (column) to unit norm, matching the paper's
    // row normalization of the sample space
    ds.normalize_column_norms();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ClinicalConfig {
        ClinicalConfig { samples: 400, features: 50, blocks: 5, support: 10, ..Default::default() }
    }

    #[test]
    fn shapes_and_normalization() {
        let mut rng = Pcg64::seed_from(1);
        let ds = clinical_d2(&mut rng, &small_cfg());
        assert_eq!(ds.d(), 400);
        assert_eq!(ds.n(), 50);
        for j in 0..ds.n() {
            let col = ds.x.col(j);
            let mean: f64 = col.iter().sum::<f64>() / 400.0;
            assert!(mean.abs() < 1e-10);
        }
    }

    #[test]
    fn block_correlation_visible() {
        let mut rng = Pcg64::seed_from(2);
        let cfg = ClinicalConfig { samples: 3000, features: 20, blocks: 4, ..small_cfg() };
        let ds = clinical_d2(&mut rng, &cfg);
        // features 0 and 4 share block 0; features 0 and 1 do not
        let same: f64 = crate::linalg::dot(ds.x.col(0), ds.x.col(4)) / 3000.0;
        let diff: f64 = crate::linalg::dot(ds.x.col(0), ds.x.col(1)) / 3000.0;
        assert!(same > diff + 0.2, "same-block {same} vs cross-block {diff}");
    }

    #[test]
    fn design_variant_unit_columns() {
        let mut rng = Pcg64::seed_from(3);
        let ds = clinical_d2_design(&mut rng, &small_cfg(), 30);
        assert_eq!(ds.n(), 30);
        assert_eq!(ds.d(), 50); // stimuli live in feature space
        for j in 0..ds.n() {
            let norm = crate::linalg::nrm2(ds.x.col(j));
            assert!((norm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn reproducible() {
        let a = clinical_d2(&mut Pcg64::seed_from(7), &small_cfg());
        let b = clinical_d2(&mut Pcg64::seed_from(7), &small_cfg());
        assert!(a.x.max_abs_diff(&b.x) == 0.0);
    }
}
