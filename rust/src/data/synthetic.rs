//! Synthetic dataset generators (paper Appendix I.2, D1 and D3).
//!
//! Features are drawn from an equicorrelated multivariate normal: with
//! correlation ρ, each feature column is `√ρ · z_common + √(1−ρ) · z_j`,
//! which has exactly the paper's covariance structure (unit variance,
//! pairwise covariance ρ) without requiring an n×n Cholesky.

use super::{Dataset, Task};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Draw a `d × n` feature matrix with pairwise column correlation `rho`,
/// then standardize columns to mean 0 / variance 1.
pub fn correlated_features(rng: &mut Pcg64, d: usize, n: usize, rho: f64) -> Matrix {
    assert!((0.0..1.0).contains(&rho), "rho must be in [0,1)");
    let sr = rho.sqrt();
    let si = (1.0 - rho).sqrt();
    let common: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let mut x = Matrix::zeros(d, n);
    for j in 0..n {
        let col = x.col_mut(j);
        for (i, c) in col.iter_mut().enumerate() {
            *c = sr * common[i] + si * rng.next_gaussian();
        }
    }
    standardize_columns(&mut x);
    x
}

fn standardize_columns(x: &mut Matrix) {
    let d = x.rows();
    for j in 0..x.cols() {
        let col = x.col_mut(j);
        let mean = col.iter().sum::<f64>() / d as f64;
        for v in col.iter_mut() {
            *v -= mean;
        }
        let var = col.iter().map(|v| v * v).sum::<f64>() / d as f64;
        if var > 1e-12 {
            let inv = 1.0 / var.sqrt();
            for v in col.iter_mut() {
                *v *= inv;
            }
        }
    }
}

/// **D1** — synthetic regression (paper: 500 features, covariance 0.4,
/// coefficients `β ~ U(−2,2)` on a support of 100, small noise).
///
/// `d` samples, `n` features, `support` true features, correlation `rho`.
pub fn regression_d1(
    rng: &mut Pcg64,
    d: usize,
    n: usize,
    support: usize,
    rho: f64,
) -> Dataset {
    let x = correlated_features(rng, d, n, rho);
    let support_idx = rng.sample_indices(n, support.min(n));
    let mut y = vec![0.0; d];
    for &j in &support_idx {
        let beta = rng.gen_range_f64(-2.0, 2.0);
        crate::linalg::axpy(beta, x.col(j), &mut y);
    }
    // small noise term (paper: "after adding a small noise term")
    let y_norm = crate::linalg::nrm2(&y) / (d as f64).sqrt();
    let noise_scale = 0.05 * y_norm.max(1e-6);
    for v in &mut y {
        *v += noise_scale * rng.next_gaussian();
    }
    let mut ds = Dataset::new("D1-synthetic-regression", x, y, Task::Regression);
    ds.true_support = support_idx;
    ds
}

/// **D1-ed** — synthetic experimental design (paper: 256 features ×
/// 1024 samples, covariance 0.8, rows ℓ2-normalized). Columns of the
/// returned `d × n` matrix are the selectable stimuli.
pub fn design_d1(rng: &mut Pcg64, d: usize, n_stimuli: usize, rho: f64) -> Dataset {
    // generate stimuli as correlated gaussian vectors in R^d
    let x = correlated_features(rng, d, n_stimuli, rho);
    let mut ds = Dataset::new("D1-synthetic-design", x, Vec::new(), Task::Design);
    // paper: "Each row is then normalized to have ℓ2 norm of 1"
    ds.normalize_rows();
    ds
}

/// **D3** — synthetic binary classification (paper: 200 features, 50 true
/// support, coefficients U(−2,2), probabilities thresholded at 0.5).
pub fn classification_d3(
    rng: &mut Pcg64,
    d: usize,
    n: usize,
    support: usize,
    rho: f64,
) -> Dataset {
    let x = correlated_features(rng, d, n, rho);
    let support_idx = rng.sample_indices(n, support.min(n));
    let mut logits = vec![0.0; d];
    for &j in &support_idx {
        let beta = rng.gen_range_f64(-2.0, 2.0);
        crate::linalg::axpy(beta, x.col(j), &mut logits);
    }
    // scale logits to a moderate range so classes are separable but not
    // trivially (matches "map to probabilities ... threshold of 0.5")
    let scale = 2.0 / (crate::linalg::nrm2(&logits) / (d as f64).sqrt()).max(1e-9);
    let y: Vec<f64> = logits
        .iter()
        .map(|&l| {
            let p = 1.0 / (1.0 + (-l * scale).exp());
            // sample the label so the problem is stochastic, as in logistic
            // regression data-generating processes
            if rng.next_f64() < p {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let mut ds = Dataset::new("D3-synthetic-classification", x, y, Task::BinaryClassification);
    ds.true_support = support_idx;
    ds
}

/// Paper-default instantiations (sizes from Appendix I.2, sample counts
/// chosen so single-core runs stay tractable; the shape of every figure is
/// insensitive to d here).
pub mod paper {
    use super::*;

    /// D1 for Fig. 2 top row: 500 features, cov 0.4, support 100.
    pub fn d1(rng: &mut Pcg64) -> Dataset {
        regression_d1(rng, 1000, 500, 100, 0.4)
    }

    /// D1 design variant for Fig. 4 top row: 256 dims × 1024 stimuli, cov 0.8.
    pub fn d1_design(rng: &mut Pcg64) -> Dataset {
        design_d1(rng, 256, 1024, 0.8)
    }

    /// D3 for Fig. 3 top row: 200 features, support 50.
    pub fn d3(rng: &mut Pcg64) -> Dataset {
        classification_d3(rng, 800, 200, 50, 0.3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correlation_structure() {
        let mut rng = Pcg64::seed_from(1);
        let x = correlated_features(&mut rng, 4000, 8, 0.4);
        // empirical pairwise correlation should be near 0.4
        let mut corrs = Vec::new();
        for a in 0..8 {
            for b in (a + 1)..8 {
                let ca = x.col(a);
                let cb = x.col(b);
                let c: f64 = crate::linalg::dot(ca, cb) / 4000.0;
                corrs.push(c);
            }
        }
        let mean_corr = crate::util::mean(&corrs);
        assert!((mean_corr - 0.4).abs() < 0.08, "mean corr {mean_corr}");
    }

    #[test]
    fn d1_shapes_and_support() {
        let mut rng = Pcg64::seed_from(2);
        let ds = regression_d1(&mut rng, 200, 50, 10, 0.4);
        assert_eq!(ds.d(), 200);
        assert_eq!(ds.n(), 50);
        assert_eq!(ds.true_support.len(), 10);
        assert!(ds.true_support.iter().all(|&j| j < 50));
        assert_eq!(ds.task, Task::Regression);
        // response has signal: correlates with support features
        let j = ds.true_support[0];
        let c = crate::linalg::dot(ds.x.col(j), &ds.y).abs();
        assert!(c > 0.0);
    }

    #[test]
    fn d1_reproducible() {
        let a = regression_d1(&mut Pcg64::seed_from(9), 50, 20, 5, 0.4);
        let b = regression_d1(&mut Pcg64::seed_from(9), 50, 20, 5, 0.4);
        assert!(a.x.max_abs_diff(&b.x) == 0.0);
        assert_eq!(a.y, b.y);
        assert_eq!(a.true_support, b.true_support);
    }

    #[test]
    fn design_rows_normalized() {
        let mut rng = Pcg64::seed_from(3);
        let ds = design_d1(&mut rng, 16, 64, 0.8);
        assert_eq!(ds.task, Task::Design);
        assert!(ds.y.is_empty());
        for i in 0..ds.d() {
            let norm: f64 = (0..ds.n()).map(|j| ds.x.get(i, j).powi(2)).sum();
            assert!((norm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn d3_labels_binary_and_balanced_ish() {
        let mut rng = Pcg64::seed_from(4);
        let ds = classification_d3(&mut rng, 500, 40, 10, 0.3);
        assert!(ds.y.iter().all(|&v| v == 0.0 || v == 1.0));
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count();
        assert!(pos > 50 && pos < 450, "positives {pos}");
        assert_eq!(ds.task, Task::BinaryClassification);
    }

    #[test]
    fn paper_defaults_construct() {
        let mut rng = Pcg64::seed_from(5);
        let d3 = paper::d3(&mut rng);
        assert_eq!(d3.n(), 200);
        assert_eq!(d3.true_support.len(), 50);
    }
}
