//! PJRT runtime service: a dedicated thread owns the (non-`Send`) PJRT CPU
//! client and every compiled executable; the rest of the system talks to it
//! through a cloneable, thread-safe [`RuntimeClient`] handle over channels.
//!
//! This actor design is forced by FFI (`xla::PjRtClient` holds `Rc`s and
//! raw pointers) but is also the right coordinator shape: one owner for
//! device state, all callers funneling batched requests through a queue.

use crate::util::sync::Mutex;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, OnceLock};

/// Opaque id of a compiled module inside the service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModuleId(usize);

enum Req {
    Compile { path: PathBuf, reply: Sender<Result<ModuleId, String>> },
    Run {
        module: ModuleId,
        inputs: Vec<(Vec<f32>, Vec<i64>)>,
        reply: Sender<Result<Vec<f32>, String>>,
    },
    Platform { reply: Sender<Result<String, String>> },
}

/// Thread-safe handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeClient {
    tx: Arc<Mutex<Sender<Req>>>,
}

static GLOBAL: OnceLock<RuntimeClient> = OnceLock::new();

impl RuntimeClient {
    /// The process-wide runtime handle (service thread spawned on first
    /// use; PJRT client creation errors surface on the first request).
    ///
    /// The spawn expect is a fatal startup invariant (allowlisted in
    /// `audit.allow`): without its service thread the runtime has nothing
    /// to degrade to.
    #[allow(clippy::expect_used)]
    pub fn global() -> Result<RuntimeClient> {
        Ok(GLOBAL
            .get_or_init(|| {
                let (tx, rx) = channel::<Req>();
                std::thread::Builder::new()
                    .name("dash-pjrt".into())
                    .spawn(move || service_loop(rx))
                    .expect("spawn pjrt service");
                RuntimeClient { tx: Arc::new(Mutex::new(tx)) }
            })
            .clone())
    }

    fn send(&self, req: Req) -> Result<()> {
        // a caller that panicked mid-send poisons the mutex; the wrapper
        // recovers it so later callers see a clean channel error, not a
        // poisoned-lock panic (the sender itself is still valid —
        // poisoning carries no torn state)
        self.tx
            .lock()
            .send(req)
            .map_err(|_| anyhow!("pjrt service thread terminated"))
    }

    /// Backend platform name (e.g. "cpu"); also validates the client came
    /// up successfully.
    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = channel();
        self.send(Req::Platform { reply })?;
        rx.recv().context("pjrt service reply")?.map_err(|e| anyhow!(e))
    }

    /// Load an HLO **text** file and compile it, returning a module handle.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<ModuleId> {
        let (reply, rx) = channel();
        self.send(Req::Compile { path: path.to_path_buf(), reply })?;
        rx.recv().context("pjrt service reply")?.map_err(|e| anyhow!(e))
    }

    /// Execute a compiled module with f32 inputs (row-major shapes);
    /// returns the first tuple element flattened.
    pub fn run_f32(
        &self,
        module: ModuleId,
        inputs: Vec<(Vec<f32>, Vec<i64>)>,
    ) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.send(Req::Run { module, inputs, reply })?;
        rx.recv().context("pjrt service reply")?.map_err(|e| anyhow!(e))
    }
}

fn service_loop(rx: std::sync::mpsc::Receiver<Req>) {
    // the client is created lazily so construction errors can be reported
    // through a request's reply channel instead of killing the thread
    let mut client: Option<std::result::Result<xla::PjRtClient, String>> = None;
    let mut modules: Vec<xla::PjRtLoadedExecutable> = Vec::new();

    fn ensure_client(
        slot: &mut Option<std::result::Result<xla::PjRtClient, String>>,
    ) -> &std::result::Result<xla::PjRtClient, String> {
        &*slot.get_or_insert_with(|| {
            xla::PjRtClient::cpu().map_err(|e| e.to_string())
        })
    }

    while let Ok(req) = rx.recv() {
        match req {
            Req::Platform { reply } => {
                let r = match ensure_client(&mut client) {
                    Ok(c) => Ok(c.platform_name()),
                    Err(e) => Err(e.clone()),
                };
                let _ = reply.send(r);
            }
            Req::Compile { path, reply } => {
                // contain panics from the FFI layer to this request: the
                // service must answer (Err) and keep serving, never die
                // with in-flight replies dangling
                let made = ensure_client(&mut client);
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> std::result::Result<ModuleId, String> {
                        let c = made.as_ref().map_err(|e| e.clone())?;
                        let proto = xla::HloModuleProto::from_text_file(&path)
                            .map_err(|e| format!("parsing HLO text {path:?}: {e}"))?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        let exe = c
                            .compile(&comp)
                            .map_err(|e| format!("compiling {path:?}: {e}"))?;
                        modules.push(exe);
                        Ok(ModuleId(modules.len() - 1))
                    },
                ))
                .unwrap_or_else(|_| Err(format!("pjrt compile of {path:?} panicked")));
                let _ = reply.send(r);
            }
            Req::Run { module, inputs, reply } => {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> std::result::Result<Vec<f32>, String> {
                        let exe = modules
                            .get(module.0)
                            .ok_or_else(|| format!("unknown module {module:?}"))?;
                        let mut literals = Vec::with_capacity(inputs.len());
                        for (data, dims) in &inputs {
                            let numel: i64 = dims.iter().product();
                            if numel as usize != data.len() {
                                return Err(format!(
                                    "input length {} != shape {:?}",
                                    data.len(),
                                    dims
                                ));
                            }
                            let lit = xla::Literal::vec1(data);
                            let lit = if dims.len() == 1 {
                                lit
                            } else {
                                lit.reshape(dims).map_err(|e| e.to_string())?
                            };
                            literals.push(lit);
                        }
                        let result = exe
                            .execute::<xla::Literal>(&literals)
                            .map_err(|e| e.to_string())?;
                        let out =
                            result[0][0].to_literal_sync().map_err(|e| e.to_string())?;
                        // aot.py lowers with return_tuple=True → unwrap 1-tuple
                        let first = out.to_tuple1().map_err(|e| e.to_string())?;
                        first.to_vec::<f32>().map_err(|e| e.to_string())
                    },
                ))
                .unwrap_or_else(|_| Err("pjrt execute panicked".into()));
                let _ = reply.send(r);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        crate::runtime::default_artifacts_dir()
    }

    /// A non-global client over a custom service loop (error-path tests
    /// inject dead/panicking services without touching the singleton).
    fn client_with_service(
        f: impl FnOnce(std::sync::mpsc::Receiver<Req>) + Send + 'static,
    ) -> (RuntimeClient, std::thread::JoinHandle<()>) {
        let (tx, rx) = channel::<Req>();
        let handle = std::thread::Builder::new()
            .name("pjrt-test-service".into())
            .spawn(move || f(rx))
            .expect("spawn test service");
        (RuntimeClient { tx: Arc::new(Mutex::new(tx)) }, handle)
    }

    #[test]
    fn panicked_service_surfaces_errors_never_hangs() {
        // the service receives one request and dies without replying: the
        // in-flight caller must get an Err (its reply sender is dropped
        // during unwind), never block forever
        let (client, handle) = client_with_service(|rx| {
            let _first = rx.recv();
            panic!("simulated pjrt worker crash");
        });
        assert!(client.platform().is_err(), "dead service must error, not hang");
        // once the thread is fully gone, every subsequent request fails
        // cleanly on the closed channel — and keeps failing
        let _ = handle.join(); // Err(panic payload), expected
        for _ in 0..3 {
            let e = client.platform().unwrap_err().to_string();
            assert!(e.contains("terminated"), "{e}");
        }
    }

    #[test]
    fn service_that_exits_immediately_fails_cleanly() {
        let (client, handle) = client_with_service(drop);
        let _ = handle.join();
        let e = client.platform().unwrap_err().to_string();
        assert!(e.contains("terminated"), "{e}");
    }

    #[test]
    fn real_service_loop_survives_failing_requests() {
        // the real loop: a bad request is answered with Err and the loop
        // keeps serving — repeated failures stay clean Errs
        let (client, _handle) = client_with_service(service_loop);
        for _ in 0..3 {
            let e = client.run_f32(ModuleId(9999), vec![]).unwrap_err().to_string();
            assert!(e.contains("unknown module"), "{e}");
        }
    }

    #[test]
    fn client_and_compile_round_trip() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let manifest = crate::runtime::Manifest::load(&dir).unwrap();
        let client = RuntimeClient::global().unwrap();
        let platform = client.platform().unwrap().to_lowercase();
        assert!(platform.contains("cpu") || platform.contains("host"), "{platform}");
        // compile the smallest aopt artifact and execute it on identity M
        let art = manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == crate::runtime::ArtifactKind::Aopt)
            .min_by_key(|a| a.d)
            .expect("aopt artifact");
        let module = client.compile_hlo_text(&art.file).unwrap();
        let d = art.d;
        let nc = art.nc;
        // M = I, candidate 0 = 2·e_0, rest zero
        let mut m = vec![0.0f32; d * d];
        for i in 0..d {
            m[i * d + i] = 1.0;
        }
        let mut xc = vec![0.0f32; d * nc];
        xc[0] = 2.0; // row-major (d, nc): element (0, 0)
        let gains = client
            .run_f32(
                module,
                vec![
                    (m, vec![d as i64, d as i64]),
                    (xc, vec![d as i64, nc as i64]),
                    (vec![1.0f32], vec![1]),
                ],
            )
            .unwrap();
        assert_eq!(gains.len(), nc);
        // gain for x = 2e_0 with M=I, σ=1: ‖Mx‖²/(1+xᵀMx) = 4/5
        assert!((gains[0] - 0.8).abs() < 1e-5, "gain {}", gains[0]);
        assert!(gains[1..].iter().all(|&g| g.abs() < 1e-6));
    }

    #[test]
    fn handle_is_send_sync_and_clone() {
        fn assert_send_sync<T: Send + Sync + Clone>() {}
        assert_send_sync::<RuntimeClient>();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let manifest = crate::runtime::Manifest::load(&dir).unwrap();
        let client = RuntimeClient::global().unwrap();
        let module = client.compile_hlo_text(&manifest.artifacts[0].file).unwrap();
        assert!(client.run_f32(module, vec![(vec![0.0; 3], vec![2])]).is_err());
    }

    #[test]
    fn unknown_module_rejected() {
        let client = RuntimeClient::global().unwrap();
        // skip if PJRT unavailable
        if client.platform().is_err() {
            return;
        }
        assert!(client.run_f32(ModuleId(9999), vec![]).is_err());
    }
}
