//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Python never runs at serving time: `make artifacts` is the only step
//! that touches JAX, and the rust binary is self-contained afterwards.
//!
//! - [`artifact`] — `manifest.json` parsing and artifact discovery
//! - [`client`] — thin wrapper over `xla::PjRtClient` (CPU)
//! - [`executor`] — compile-once executable cache + padded execution

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{Artifact, ArtifactKind, Manifest};
pub use client::RuntimeClient;
pub use executor::{default_artifacts_dir, GainExecutor};
