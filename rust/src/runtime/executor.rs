//! High-level gain executor: pads rust-side f64 state into the fixed f32
//! shapes of an AOT artifact, runs the module, and unpads the results.
//!
//! Padding contract (validated by the python kernel tests):
//! - extra *rows* (samples) are zero — they contribute nothing to any dot;
//! - extra *basis columns* (lreg) are zero — no projection contribution;
//! - extra *candidate columns* are zero — their gain comes back 0 and is
//!   discarded;
//! - candidate batches larger than the artifact's `nc` are chunked.

use super::artifact::{Artifact, ArtifactKind, Manifest};
use super::client::{ModuleId, RuntimeClient};
use crate::linalg::Matrix;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Artifacts directory: `DASH_ARTIFACTS` env var, falling back to
/// `<crate root>/artifacts` (works from `cargo test`/`cargo run`), falling
/// back to `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("DASH_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let crate_rel = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if crate_rel.exists() {
        return crate_rel;
    }
    PathBuf::from("artifacts")
}

/// A compiled gain oracle bound to one artifact. Cheap to clone; all
/// clones share the service-resident executable.
#[derive(Clone)]
pub struct GainExecutor {
    artifact: Artifact,
    client: RuntimeClient,
    module: ModuleId,
}

impl GainExecutor {
    /// Select (smallest fitting) and compile an artifact of `kind` for a
    /// problem with `d` samples and up to `s` basis columns.
    pub fn for_kind(manifest: &Manifest, kind: ArtifactKind, d: usize, s: usize) -> Result<Self> {
        let artifact = manifest
            .select(kind, d, s)
            .with_context(|| {
                format!(
                    "no {} artifact fits d={d}, s={s}; re-run `make artifacts` \
                     with a larger profile (PROFILE=paper)",
                    kind.as_str()
                )
            })?
            .clone();
        let client = RuntimeClient::global()?;
        let module = client.compile_hlo_text(&artifact.file)?;
        Ok(GainExecutor { artifact, client, module })
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Regression gains for `cand` columns of `x` given the dense `d × s`
    /// orthonormal basis `q` (an [`IncrementalQr`](crate::linalg::IncrementalQr)
    /// basis) and residual `r`. Returns one gain per candidate.
    pub fn lreg_gains(
        &self,
        q: &Matrix,
        r: &[f64],
        x: &Matrix,
        cand: &[usize],
    ) -> Result<Vec<f64>> {
        let a = &self.artifact;
        anyhow::ensure!(a.kind == ArtifactKind::Lreg, "not an lreg artifact");
        let d = r.len();
        anyhow::ensure!(d <= a.d, "d {} exceeds artifact d {}", d, a.d);
        anyhow::ensure!(q.cols() <= a.s, "basis {} exceeds artifact s {}", q.cols(), a.s);

        // q: row-major (a.d, a.s), zero-padded
        let mut q_rm = vec![0.0f32; a.d * a.s];
        for j in 0..q.cols() {
            for (i, &v) in q.col(j).iter().enumerate() {
                q_rm[i * a.s + j] = v as f32;
            }
        }
        let mut r_pad = vec![0.0f32; a.d];
        // contiguous narrowing rides the SIMD pack kernel (bit-identical
        // to `as f32` at every dispatch level)
        crate::linalg::pack_f32(r, &mut r_pad[..d]);

        let mut out = Vec::with_capacity(cand.len());
        for chunk in cand.chunks(a.nc) {
            let mut xc = vec![0.0f32; a.d * a.nc];
            for (j, &c) in chunk.iter().enumerate() {
                let col = x.col(c);
                for (i, &v) in col.iter().enumerate() {
                    xc[i * a.nc + j] = v as f32;
                }
            }
            let gains = self.client.run_f32(
                self.module,
                vec![
                    (q_rm.clone(), vec![a.d as i64, a.s as i64]),
                    (r_pad.clone(), vec![a.d as i64]),
                    (xc, vec![a.d as i64, a.nc as i64]),
                ],
            )?;
            out.extend(gains[..chunk.len()].iter().map(|&g| g as f64));
        }
        Ok(out)
    }

    /// A-optimality gains for `cand` columns of `x` given posterior `m`.
    pub fn aopt_gains(
        &self,
        m: &Matrix,
        x: &Matrix,
        cand: &[usize],
        sigma_sq_inv: f64,
    ) -> Result<Vec<f64>> {
        let a = &self.artifact;
        anyhow::ensure!(a.kind == ArtifactKind::Aopt, "not an aopt artifact");
        let d = m.rows();
        anyhow::ensure!(d <= a.d, "d {} exceeds artifact d {}", d, a.d);

        let mut m_rm = vec![0.0f32; a.d * a.d];
        for j in 0..d {
            let col = m.col(j);
            for i in 0..d {
                m_rm[i * a.d + j] = col[i] as f32;
            }
        }
        let sig = vec![sigma_sq_inv as f32];

        let mut out = Vec::with_capacity(cand.len());
        for chunk in cand.chunks(a.nc) {
            let mut xc = vec![0.0f32; a.d * a.nc];
            for (j, &c) in chunk.iter().enumerate() {
                let col = x.col(c);
                for (i, &v) in col.iter().enumerate() {
                    xc[i * a.nc + j] = v as f32;
                }
            }
            let gains = self.client.run_f32(
                self.module,
                vec![
                    (m_rm.clone(), vec![a.d as i64, a.d as i64]),
                    (xc, vec![a.d as i64, a.nc as i64]),
                    (sig.clone(), vec![1]),
                ],
            )?;
            out.extend(gains[..chunk.len()].iter().map(|&g| g as f64));
        }
        Ok(out)
    }

    /// Score-test logistic gains for `cand` columns of `x` given working
    /// residual `resid = y − p` and IRLS weights `w = p(1−p)`.
    pub fn logistic_gains(
        &self,
        x: &Matrix,
        cand: &[usize],
        resid: &[f64],
        w: &[f64],
    ) -> Result<Vec<f64>> {
        let a = &self.artifact;
        anyhow::ensure!(a.kind == ArtifactKind::Logistic, "not a logistic artifact");
        let d = resid.len();
        anyhow::ensure!(d <= a.d, "d {} exceeds artifact d {}", d, a.d);

        let mut r_pad = vec![0.0f32; a.d];
        let mut w_pad = vec![0.0f32; a.d];
        crate::linalg::pack_f32(resid, &mut r_pad[..d]);
        crate::linalg::pack_f32(&w[..d], &mut w_pad[..d]);

        let mut out = Vec::with_capacity(cand.len());
        for chunk in cand.chunks(a.nc) {
            let mut xc = vec![0.0f32; a.d * a.nc];
            for (j, &c) in chunk.iter().enumerate() {
                let col = x.col(c);
                for (i, &v) in col.iter().enumerate() {
                    xc[i * a.nc + j] = v as f32;
                }
            }
            let gains = self.client.run_f32(
                self.module,
                vec![
                    (xc, vec![a.d as i64, a.nc as i64]),
                    (r_pad.clone(), vec![a.d as i64]),
                    (w_pad.clone(), vec![a.d as i64]),
                ],
            )?;
            out.extend(gains[..chunk.len()].iter().map(|&g| g as f64));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::objectives::Objective;

    fn manifest() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn lreg_executor_matches_native_state() {
        let Some(m) = manifest() else { return };
        let mut rng = Pcg64::seed_from(1);
        let ds = crate::data::synthetic::regression_d1(&mut rng, 100, 20, 8, 0.3);
        let obj = crate::objectives::LinearRegressionObjective::new(&ds);
        let exe = GainExecutor::for_kind(&m, ArtifactKind::Lreg, 100, 16).unwrap();

        // state after selecting a few features
        let set = vec![3usize, 7, 12];
        let st = obj.state_for(&set);
        // reconstruct basis + residual from a fresh incremental QR
        let mut qr = crate::linalg::IncrementalQr::new(100);
        for &a in &set {
            qr.push_col(ds.x.col(a));
        }
        let r = qr.residual(&ds.y);
        let cand: Vec<usize> = (0..20).filter(|a| !set.contains(a)).collect();
        let xla_gains = exe
            .lreg_gains(qr.basis(), &r, &ds.x, &cand)
            .unwrap();
        let native = st.gains(&cand);
        let y_sq = crate::linalg::dot(&ds.y, &ds.y);
        for (i, &a) in cand.iter().enumerate() {
            let xla_norm = xla_gains[i] / y_sq;
            assert!(
                (xla_norm - native[i]).abs() < 1e-4 * (1.0 + native[i].abs()),
                "cand {a}: xla {xla_norm} vs native {}",
                native[i]
            );
        }
    }

    #[test]
    fn aopt_executor_matches_native_state() {
        let Some(m) = manifest() else { return };
        let mut rng = Pcg64::seed_from(2);
        let ds = crate::data::synthetic::design_d1(&mut rng, 32, 50, 0.4);
        let obj = crate::objectives::AOptimalityObjective::new(&ds, 1.0, 1.0);
        let exe = GainExecutor::for_kind(&m, ArtifactKind::Aopt, 32, 0).unwrap();

        let set = vec![1usize, 9, 33];
        let st = obj.state_for(&set);
        // rebuild M via Sherman–Morrison like the objective does
        let mut mat = Matrix::identity(32);
        for &a in &set {
            let x = ds.x.col(a);
            let mut mx = vec![0.0; 32];
            crate::linalg::gemv(&mat, x, &mut mx);
            let xmx = crate::linalg::dot(x, &mx);
            let scale = 1.0 / (1.0 + xmx);
            for j in 0..32 {
                let c = scale * mx[j];
                for i in 0..32 {
                    let v = mat.get(i, j) - c * mx[i];
                    mat.set(i, j, v);
                }
            }
        }
        let cand: Vec<usize> = (0..50).filter(|a| !set.contains(a)).collect();
        let xla_gains = exe.aopt_gains(&mat, &ds.x, &cand, 1.0).unwrap();
        let native = st.gains(&cand);
        let prior_trace = 32.0;
        for (i, &a) in cand.iter().enumerate() {
            let xla_norm = xla_gains[i] / prior_trace;
            assert!(
                (xla_norm - native[i]).abs() < 1e-5 * (1.0 + native[i].abs()),
                "cand {a}: xla {xla_norm} vs native {}",
                native[i]
            );
        }
    }

    #[test]
    fn chunking_handles_large_batches() {
        let Some(m) = manifest() else { return };
        let mut rng = Pcg64::seed_from(3);
        // more candidates than the artifact's nc forces chunked execution
        let art = m.select(ArtifactKind::Logistic, 64, 0).unwrap().clone();
        let n = art.nc + 17;
        let ds = crate::data::synthetic::classification_d3(&mut rng, 64, n, 10, 0.2);
        let exe = GainExecutor::for_kind(&m, ArtifactKind::Logistic, 64, 0).unwrap();
        let p0 = vec![0.5; 64];
        let resid: Vec<f64> = ds.y.iter().zip(&p0).map(|(y, p)| y - p).collect();
        let w: Vec<f64> = p0.iter().map(|p| p * (1.0 - p)).collect();
        let cand: Vec<usize> = (0..n).collect();
        let gains = exe.logistic_gains(&ds.x, &cand, &resid, &w).unwrap();
        assert_eq!(gains.len(), n);
        assert!(gains.iter().all(|g| g.is_finite() && *g >= 0.0));
    }

    #[test]
    fn kind_mismatch_rejected() {
        let Some(m) = manifest() else { return };
        let exe = GainExecutor::for_kind(&m, ArtifactKind::Aopt, 16, 0).unwrap();
        let mat = Matrix::identity(16);
        let x = Matrix::zeros(16, 4);
        assert!(exe.lreg_gains(&Matrix::zeros(16, 0), &vec![0.0; 16], &x, &[0]).is_err());
        assert!(exe.aopt_gains(&mat, &x, &[0], 1.0).is_ok());
    }

    #[test]
    fn oversize_problem_rejected() {
        let Some(m) = manifest() else { return };
        let biggest = m
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Lreg)
            .map(|a| a.d)
            .max()
            .unwrap();
        assert!(GainExecutor::for_kind(&m, ArtifactKind::Lreg, biggest + 1, 1).is_err());
    }
}
