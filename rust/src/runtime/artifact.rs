//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed with the in-repo JSON substrate.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which oracle a module implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// regression gains: inputs (q[d,s], r[d], xc[d,nc]) → gains[nc]
    Lreg,
    /// A-optimality gains: inputs (m[d,d], xc[d,nc], sig[1]) → gains[nc]
    Aopt,
    /// logistic score-test gains: inputs (xc[d,nc], resid[d], w[d]) → gains[nc]
    Logistic,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "lreg" => Some(ArtifactKind::Lreg),
            "aopt" => Some(ArtifactKind::Aopt),
            "logistic" => Some(ArtifactKind::Logistic),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::Lreg => "lreg",
            ArtifactKind::Aopt => "aopt",
            ArtifactKind::Logistic => "logistic",
        }
    }
}

/// One AOT-compiled module.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub kind: ArtifactKind,
    /// path to the HLO text file (absolute once loaded)
    pub file: PathBuf,
    /// sample dimension d
    pub d: usize,
    /// padded basis columns s (lreg only; 0 otherwise)
    pub s: usize,
    /// padded candidate batch nc
    pub nc: usize,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading manifest: {e}"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let version = v.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            return Err(format!("unsupported manifest version {version}"));
        }
        let arr = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for e in arr {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or("artifact missing name")?
                .to_string();
            let kind = e
                .get("kind")
                .and_then(Json::as_str)
                .and_then(ArtifactKind::parse)
                .ok_or_else(|| format!("artifact {name}: bad kind"))?;
            let file = dir.join(
                e.get("file").and_then(Json::as_str).ok_or("artifact missing file")?,
            );
            let dims: &BTreeMap<String, Json> = e
                .get("dims")
                .and_then(Json::as_obj)
                .ok_or("artifact missing dims")?;
            let dim = |k: &str| dims.get(k).and_then(Json::as_usize).unwrap_or(0);
            artifacts.push(Artifact {
                name,
                kind,
                file,
                d: dim("d"),
                s: dim("s"),
                nc: dim("nc"),
            });
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    /// Best artifact of a kind for a problem with `d` samples and basis
    /// requirement `s`: the smallest artifact that fits (d_art ≥ d,
    /// s_art ≥ s), or `None`.
    pub fn select(&self, kind: ArtifactKind, d: usize, s: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.d >= d && (kind != ArtifactKind::Lreg || a.s >= s))
            .min_by_key(|a| (a.d, a.s, a.nc))
    }

    pub fn by_name(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "lreg_d256_nc256_s64", "kind": "lreg", "file": "lreg.hlo.txt",
         "dims": {"d": 256, "nc": 256, "s": 64}, "dtype": "f32",
         "inputs": [[256,64],[256],[256,256]], "outputs": 1},
        {"name": "aopt_d64_nc256", "kind": "aopt", "file": "aopt.hlo.txt",
         "dims": {"d": 64, "nc": 256}, "dtype": "f32",
         "inputs": [[64,64],[64,256],[1]], "outputs": 1}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts[0];
        assert_eq!(a.kind, ArtifactKind::Lreg);
        assert_eq!((a.d, a.s, a.nc), (256, 64, 256));
        assert_eq!(a.file, Path::new("/tmp/a/lreg.hlo.txt"));
    }

    #[test]
    fn select_fitting_artifact() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.select(ArtifactKind::Lreg, 100, 10).unwrap();
        assert_eq!(a.name, "lreg_d256_nc256_s64");
        // too big d: nothing fits
        assert!(m.select(ArtifactKind::Lreg, 1000, 10).is_none());
        // s too large for the lreg artifact
        assert!(m.select(ArtifactKind::Lreg, 100, 100).is_none());
        // aopt ignores s
        assert!(m.select(ArtifactKind::Aopt, 64, 999).is_some());
        assert!(m.select(ArtifactKind::Logistic, 1, 0).is_none());
    }

    #[test]
    fn by_name_lookup() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.by_name("aopt_d64_nc256").is_some());
        assert!(m.by_name("missing").is_none());
    }

    #[test]
    fn rejects_bad_versions_and_kinds() {
        assert!(Manifest::parse(r#"{"version": 2, "artifacts": []}"#, Path::new("/")).is_err());
        let bad_kind = r#"{"version": 1, "artifacts": [
            {"name": "x", "kind": "bogus", "file": "f", "dims": {}}]}"#;
        assert!(Manifest::parse(bad_kind, Path::new("/")).is_err());
    }

    #[test]
    fn kind_round_trip() {
        for k in [ArtifactKind::Lreg, ArtifactKind::Aopt, ArtifactKind::Logistic] {
            assert_eq!(ArtifactKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(ArtifactKind::parse("nope"), None);
    }

    #[test]
    fn real_manifest_if_present() {
        // integration: parse the artifacts/ manifest when built
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(a.file.exists(), "missing {:?}", a.file);
            }
        }
    }
}
