//! Micro-benchmark substrate (criterion is unavailable offline): warmup,
//! timed iterations, and a mean/p50/p95 report. Used by the targets in
//! `rust/benches/` (declared with `harness = false`).

use crate::util::timer::fmt_duration_s;
use crate::util::{mean, quantile, stddev};
use std::time::Instant;

/// One benchmark's timing summary.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchReport {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>6} iters  mean {:>9}  p50 {:>9}  p95 {:>9}  ±{:>8}",
            self.name,
            self.iters,
            fmt_duration_s(self.mean_s),
            fmt_duration_s(self.p50_s),
            fmt_duration_s(self.p95_s),
            fmt_duration_s(self.std_s),
        )
    }
}

/// Benchmark runner: `Bench::new("suite").run("case", || work())`.
pub struct Bench {
    suite: String,
    /// minimum measured iterations
    pub min_iters: usize,
    /// stop adding iterations after this much measured time (seconds)
    pub budget_s: f64,
    /// warmup iterations
    pub warmup: usize,
    pub reports: Vec<BenchReport>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Honor DASH_BENCH_FAST=1 for CI-speed runs.
        let fast = std::env::var("DASH_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Bench {
            suite: suite.to_string(),
            min_iters: if fast { 3 } else { 10 },
            budget_s: if fast { 0.5 } else { 3.0 },
            warmup: if fast { 1 } else { 2 },
            reports: Vec::new(),
        }
    }

    /// Time `f`, which should perform one complete unit of work per call.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchReport {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
            if samples.len() >= self.min_iters && start.elapsed().as_secs_f64() > self.budget_s {
                break;
            }
            if samples.len() >= 10_000 {
                break;
            }
        }
        let report = BenchReport {
            name: format!("{}/{}", self.suite, name),
            iters: samples.len(),
            mean_s: mean(&samples),
            std_s: stddev(&samples),
            p50_s: quantile(&samples, 0.5),
            p95_s: quantile(&samples, 0.95),
        };
        println!("{}", report.line());
        self.reports.push(report);
        &self.reports[self.reports.len() - 1]
    }

    /// Record an already-measured value (for end-to-end numbers computed by
    /// an experiment run rather than a closure loop).
    pub fn record(&mut self, name: &str, seconds: f64) {
        let report = BenchReport {
            name: format!("{}/{}", self.suite, name),
            iters: 1,
            mean_s: seconds,
            std_s: 0.0,
            p50_s: seconds,
            p95_s: seconds,
        };
        println!("{}", report.line());
        self.reports.push(report);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bench::new("test");
        b.min_iters = 5;
        b.budget_s = 0.01;
        b.warmup = 1;
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0);
        assert!(r.p95_s >= r.p50_s * 0.5);
        assert!(r.name.starts_with("test/"));
    }

    #[test]
    fn record_direct() {
        let mut b = Bench::new("t");
        b.record("e2e", 1.25);
        assert_eq!(b.reports.len(), 1);
        assert_eq!(b.reports[0].mean_s, 1.25);
    }
}
