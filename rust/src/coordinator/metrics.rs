//! Process metrics registry: named counters and gauges with a text
//! snapshot, fed by the leader and the experiment harness.
//!
//! Hot-path friendly: the maps are behind `util::sync::RwLock`s (poison-
//! recovering, lock-order tracked in instrumented builds) with atomic leaves, so
//! incrementing or reading an *existing* key takes only a shared read lock
//! plus one atomic op — pool workers bumping the same counter never
//! serialize on a registry-wide mutex. The write lock is taken exactly
//! once per key, on first touch.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use crate::util::sync::RwLock;

/// Named counters (monotonic) and gauges (last-write-wins, fixed-point
/// micro units for fractional values).
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: RwLock<BTreeMap<String, AtomicU64>>,
    gauges: RwLock<BTreeMap<String, AtomicI64>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        // fast path: existing key under the shared read lock
        if let Some(c) = self.counters.read().get(name) {
            c.fetch_add(by, Ordering::Relaxed);
            return;
        }
        // first touch: `entry` under the write lock (another thread may
        // have raced us to the insert; fetch_add composes either way)
        self.counters
            .write()
            .entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .read()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Set a gauge to a float value (stored as micro-units).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let micros = (value * 1e6) as i64;
        if let Some(g) = self.gauges.read().get(name) {
            g.store(micros, Ordering::Relaxed);
            return;
        }
        self.gauges
            .write()
            .entry(name.to_string())
            .or_insert_with(|| AtomicI64::new(0))
            .store(micros, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges
            .read()
            .get(name)
            .map(|g| g.load(Ordering::Relaxed) as f64 / 1e6)
            .unwrap_or(0.0)
    }

    /// Text snapshot, one `name value` per line, sorted.
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.read().iter() {
            out.push_str(&format!("{k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.gauges.read().iter() {
            out.push_str(&format!(
                "{k} {}\n",
                crate::util::fmt_f64(v.load(Ordering::Relaxed) as f64 / 1e6)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = MetricsRegistry::new();
        m.inc("oracle.queries", 5);
        m.inc("oracle.queries", 3);
        assert_eq!(m.counter("oracle.queries"), 8);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = MetricsRegistry::new();
        m.set_gauge("job.value", 0.75);
        m.set_gauge("job.value", 0.875);
        assert!((m.gauge("job.value") - 0.875).abs() < 1e-9);
        assert_eq!(m.gauge("missing"), 0.0);
    }

    #[test]
    fn snapshot_sorted_and_complete() {
        let m = MetricsRegistry::new();
        m.inc("b.count", 1);
        m.inc("a.count", 2);
        m.set_gauge("c.value", 1.5);
        let snap = m.snapshot();
        let lines: Vec<&str> = snap.lines().collect();
        assert_eq!(lines, vec!["a.count 2", "b.count 1", "c.value 1.5"]);
    }

    #[test]
    fn concurrent_increments_are_lossless() {
        // pool workers hammering one (racing first-touch) key and disjoint
        // per-worker keys: every increment must land
        let m = Arc::new(MetricsRegistry::new());
        let pool = ThreadPool::new(4);
        let m2 = Arc::clone(&m);
        pool.parallel_map(256, move |i| {
            m2.inc("shared.count", 1);
            m2.inc(&format!("worker.{}", i % 7), 2);
        });
        assert_eq!(m.counter("shared.count"), 256);
        let per_worker: u64 = (0..7).map(|w| m.counter(&format!("worker.{w}"))).sum();
        assert_eq!(per_worker, 2 * 256);
    }
}
