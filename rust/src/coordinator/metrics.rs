//! Process metrics registry: named counters and gauges with a text
//! snapshot, fed by the leader and the experiment harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// Named counters (monotonic) and gauges (last-write-wins, fixed-point
/// micro units for fractional values).
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    gauges: Mutex<BTreeMap<String, AtomicI64>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Set a gauge to a float value (stored as micro-units).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut map = self.gauges.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicI64::new(0))
            .store((value * 1e6) as i64, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|g| g.load(Ordering::Relaxed) as f64 / 1e6)
            .unwrap_or(0.0)
    }

    /// Text snapshot, one `name value` per line, sorted.
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!(
                "{k} {}\n",
                crate::util::fmt_f64(v.load(Ordering::Relaxed) as f64 / 1e6)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = MetricsRegistry::new();
        m.inc("oracle.queries", 5);
        m.inc("oracle.queries", 3);
        assert_eq!(m.counter("oracle.queries"), 8);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = MetricsRegistry::new();
        m.set_gauge("job.value", 0.75);
        m.set_gauge("job.value", 0.875);
        assert!((m.gauge("job.value") - 0.875).abs() < 1e-9);
        assert_eq!(m.gauge("missing"), 0.0);
    }

    #[test]
    fn snapshot_sorted_and_complete() {
        let m = MetricsRegistry::new();
        m.inc("b.count", 1);
        m.inc("a.count", 2);
        m.set_gauge("c.value", 1.5);
        let snap = m.snapshot();
        let lines: Vec<&str> = snap.lines().collect();
        assert_eq!(lines, vec!["a.count 2", "b.count 1", "c.value 1.5"]);
    }
}
