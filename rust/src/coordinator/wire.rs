//! v1 JSON wire protocol: the serving front as newline-delimited JSON.
//!
//! The in-process serving front ([`coordinator::serve`](crate::coordinator::serve))
//! is deterministic and typed but trapped in one address space. This module
//! defines the externally drivable form of the same API:
//!
//! - **[`ApiRequest`] / [`ApiReply`]** — the typed v1 request/reply values.
//!   They mirror the serving turns (`Sweep`, `Insert`, `Step`, `Finish`,
//!   `Metrics`) one-to-one, plus the server-level `Open` (create a session
//!   from wire specs) and `List`. The in-process
//!   [`SessionClient`](crate::coordinator::serve::SessionClient) is
//!   implemented over exactly these values
//!   ([`SessionClient::api`](crate::coordinator::serve::SessionClient::api)),
//!   so the stdio front and the in-process front are provably one API: both
//!   convert through [`ApiRequest::into_serve`] / [`ApiReply::from_serve`].
//! - **Frames** — one compact JSON object per line. Requests carry
//!   `{"v":1,"id":N,"op":...}` plus the op's fields; replies echo `v`/`id`
//!   with the reply op (errors are the `"error"` op carrying a
//!   [`SelectError`] by `kind`). Object keys serialize sorted
//!   (`util::json` uses a BTreeMap), so frames are byte-deterministic —
//!   `tests/wire_props.rs` pins the schema against
//!   `tests/golden/api_v1.jsonl`.
//! - **[`WireCore`]** — the transport-agnostic engine: decodes request
//!   lines, drives the deterministic [`SessionServer`] core
//!   (`submit` + `turn`), encodes one reply line per request in order,
//!   and owns the lane table, dataset cache, tenant quotas, and session
//!   store. Panics inside request handling are contained to a typed
//!   `client_panic` error frame ([`WireCore::line`]); the `shutdown` op
//!   drains every evictable lane to the store and ends the front's loop.
//! - **The fronts** — thin pumps over one core. [`StdioServer`] reads
//!   stdin and writes stdout (`dash serve --stdio`); the socket front
//!   ([`NetServer`](crate::coordinator::net::NetServer), `dash serve
//!   --listen`) accepts TCP or Unix-socket connections and pumps each
//!   through the same core under per-connection supervision, deadlines,
//!   and idle timeouts. Any process that can speak newline-delimited JSON
//!   over any of these transports drives selections with exact,
//!   generation-stamped semantics.
//!
//! # Protocol (v1)
//!
//! ```text
//! → {"v":1,"id":1,"op":"open","driven":true,
//!    "problem":{"dataset":"d1","k":8,"seed":3},"plan":{"algo":"greedy"}}
//! ← {"id":1,"op":"opened","session":0,"v":1}
//! → {"v":1,"id":2,"op":"step","session":0}
//! ← {"done":false,"generation":1,"id":2,"op":"stepped","v":1}
//! → {"v":1,"id":3,"op":"sweep","session":0,"candidates":[0,1,2]}
//! ← {"fresh":3,"gains":[…],"generation":1,"id":3,"op":"swept","v":1}
//! → {"v":1,"id":4,"op":"insert","session":0,"item":5,"if_generation":1}
//! ← {"error":{"kind":"rejected",…},"id":4,"op":"error","v":1}
//! ```
//!
//! Numbers ride JSON's f64: exact for the integers used here (ids,
//! generations, indices — all far below 2^53) and bit-exact for gains and
//! values (the writer emits the shortest round-tripping decimal). Non-finite
//! floats are not representable; objectives produce finite gains.
//!
//! # Session lifetime
//!
//! Wire-opened objectives are *owned by their lane*: each open wraps the
//! resolved objective in an `Arc` and hands it to the serving core
//! ([`SessionServer::open_shared`]), and the `close` op (or an eviction)
//! drops the lane — objective, state, driver, everything — and frees its
//! slot. The resident budget ([`StdioServer::with_max_sessions`], default
//! 64) counts **live** sessions only, so an open/close churn under a
//! small budget reuses slots indefinitely instead of leaking and wedging.
//!
//! Wire session ids are *not* reused: they stay stable for the life of
//! the process so an evicted session keeps its identity. Closed ids are
//! recycled for new opens (fd-style); evicted ids stay reserved until
//! closed.
//!
//! # Durability: evict and restore
//!
//! With a session store attached ([`StdioServer::with_store`]), an open
//! that would exceed the resident budget evicts the least-recently-used
//! idle lane instead of failing: the lane's [`SessionRecord`] — wire
//! specs, snapshot, and final result if its driver finished — is written
//! to disk and the lane is dropped. The next request addressed to an
//! evicted session restores it transparently: the objective is rebuilt
//! from the recorded specs (datasets are memoized, so this is cheap) and
//! the state is replayed from the snapshot's set, which reproduces the
//! state *byte-identically* (insertion order fully determines the state
//! bits — `tests/lifecycle.rs` proves resumed selections equal an
//! uninterrupted run). Lanes that cannot be rebuilt from specs — embedded
//! [`WireCore::open_objective`] lanes and driven lanes still mid-run
//! (driver state is not snapshottable) — are pinned resident and never
//! evicted.
//!
//! Admission is typed, never a panic: opens beyond a tenant's quota
//! ([`StdioServer::with_tenant_quota`]) are [`SelectError::Rejected`];
//! opens beyond the resident budget with nothing evictable are
//! [`SelectError::Backpressure`].
//!
//! [`SessionServer::open_shared`]: crate::coordinator::serve::SessionServer::open_shared
//! [`SessionRecord`]: crate::coordinator::store::SessionRecord

use crate::algorithms::{LassoConfig, OptEstimate, RoundRecord, SelectionResult};
use crate::coordinator::api::{PlanSpec, ProblemSpec, SelectError};
use crate::coordinator::leader::{Backend, Leader, ObjectiveChoice, SelectionJob};
use crate::coordinator::serve::{ServeReply, ServeRequest, ServeSummary, SessionId, SessionServer};
use crate::coordinator::session::{
    Generation, ObjectiveHandle, SessionDriver, SessionMetrics, SessionSnapshot,
};
use crate::coordinator::store::{SessionRecord, SessionStore};
use crate::data::{Dataset, Task};
use crate::experiments::{DatasetId, Scale};
use crate::objectives::Objective;
use crate::util::json::Json;
use std::sync::Arc;

/// Wire protocol version; requests with any other `v` are rejected with a
/// [`SelectError::Protocol`] reply.
pub const WIRE_VERSION: u64 = 1;

/// Largest integer a v1 frame can carry faithfully (JSON numbers are
/// f64): ids, generations, and indices must stay at or below 2^53 − 1.
/// Decoders reject larger values as [`SelectError::Protocol`]; encoders
/// clamp ids here so an out-of-contract id produces a deliverable frame
/// instead of one the peer must reject.
pub const MAX_WIRE_INT: u64 = (1 << 53) - 1;

// ---------------------------------------------------------------------------
// Wire specs (the serializable face of ProblemSpec / PlanSpec)
// ---------------------------------------------------------------------------

/// Wire form of a [`ProblemSpec`]: datasets travel by experiment id
/// (`d1`, `d2-design`, …) + scale + seed, not by value. Optional fields
/// default exactly as [`ProblemSpec::builder`] does.
#[derive(Debug, Clone, PartialEq)]
pub struct WireProblem {
    /// experiment dataset id (`d1`, `d1-design`, `d2`, `d2-design`, `d3`, `d4`)
    pub dataset: String,
    /// `quick` (default) or `paper`
    pub scale: Option<String>,
    /// `lreg` | `r2` | `logistic` | `ovr-softmax` | `aopt`; default derived
    /// from the dataset's task
    pub objective: Option<String>,
    /// A-optimality prior β² (aopt only; default 1.0)
    pub beta_sq: Option<f64>,
    /// A-optimality noise σ² (aopt only; default 1.0)
    pub sigma_sq: Option<f64>,
    /// `native` (default) or `xla`
    pub backend: Option<String>,
    pub k: usize,
    pub seed: u64,
}

impl WireProblem {
    /// Minimal problem: dataset + k, everything else defaulted.
    pub fn new(dataset: &str, k: usize, seed: u64) -> WireProblem {
        WireProblem {
            dataset: dataset.to_string(),
            scale: None,
            objective: None,
            beta_sq: None,
            sigma_sq: None,
            backend: None,
            k,
            seed,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("dataset", self.dataset.as_str().into()),
            ("k", self.k.into()),
            ("seed", self.seed.into()),
        ];
        if let Some(s) = &self.scale {
            pairs.push(("scale", s.as_str().into()));
        }
        if let Some(o) = &self.objective {
            pairs.push(("objective", o.as_str().into()));
        }
        if let Some(b) = self.beta_sq {
            pairs.push(("beta_sq", b.into()));
        }
        if let Some(s) = self.sigma_sq {
            pairs.push(("sigma_sq", s.into()));
        }
        if let Some(b) = &self.backend {
            pairs.push(("backend", b.as_str().into()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<WireProblem, SelectError> {
        Ok(WireProblem {
            dataset: need_str(j, "dataset")?.to_string(),
            scale: opt_str(j, "scale")?,
            objective: opt_str(j, "objective")?,
            beta_sq: opt_f64(j, "beta_sq")?,
            sigma_sq: opt_f64(j, "sigma_sq")?,
            backend: opt_str(j, "backend")?,
            k: need_usize(j, "k")?,
            // same default as ProblemSpec::builder, so the two documented
            // surfaces can never silently diverge
            seed: opt_u64(j, "seed")?.unwrap_or(1),
        })
    }

    /// Build the dataset and validate into a [`ProblemSpec`]. Every name
    /// field (dataset, scale, objective, backend) is validated *before*
    /// the dataset is synthesized, so a typo'd open never pays for a
    /// paper-scale build it then throws away.
    pub fn resolve(&self) -> Result<ProblemSpec, SelectError> {
        self.resolve_cached(&mut DatasetCache::new())
    }

    /// [`WireProblem::resolve`] with dataset memoization: identical
    /// `(dataset, scale, seed)` opens share one synthesized [`Dataset`]
    /// instead of paying for (and pinning) a fresh build each time — the
    /// [`StdioServer`] routes every spec open through its own cache.
    pub fn resolve_cached(&self, cache: &mut DatasetCache) -> Result<ProblemSpec, SelectError> {
        let id = DatasetId::parse(&self.dataset)
            .ok_or_else(|| SelectError::invalid(format!("unknown dataset '{}'", self.dataset)))?;
        let scale = match &self.scale {
            None => Scale::Quick,
            Some(s) => Scale::parse(s)
                .ok_or_else(|| SelectError::invalid(format!("unknown scale '{s}'")))?,
        };
        let aopt = ObjectiveChoice::Aopt {
            beta_sq: self.beta_sq.unwrap_or(1.0),
            sigma_sq: self.sigma_sq.unwrap_or(1.0),
        };
        let named_objective = match &self.objective {
            Some(name) => Some(match name.as_str() {
                "lreg" => ObjectiveChoice::Lreg,
                "r2" => ObjectiveChoice::R2,
                "logistic" => ObjectiveChoice::Logistic,
                "ovr-softmax" => ObjectiveChoice::OvrSoftmax,
                "aopt" => aopt.clone(),
                other => {
                    return Err(SelectError::invalid(format!("unknown objective '{other}'")))
                }
            }),
            None => None,
        };
        // priors only parameterize the aopt objective; naming any other
        // objective alongside them is a contradiction to reject, never a
        // silent drop
        if (self.beta_sq.is_some() || self.sigma_sq.is_some())
            && matches!(&named_objective, Some(o) if !matches!(o, ObjectiveChoice::Aopt { .. }))
        {
            return Err(SelectError::invalid(format!(
                "beta_sq/sigma_sq apply only to the aopt objective, not '{}'",
                self.objective.as_deref().unwrap_or("")
            )));
        }
        let backend = match self.backend.as_deref() {
            None => Backend::Native,
            Some(name) => Backend::parse(name)
                .ok_or_else(|| SelectError::invalid(format!("unknown backend '{name}'")))?,
        };
        // the one k check that needs no dataset; k ≤ n waits for the build
        if self.k == 0 {
            return Err(SelectError::invalid("k must be >= 1"));
        }
        let key = (id, scale, self.seed);
        let (dataset, cached) = match cache.iter().find(|(k, _)| *k == key) {
            Some((_, ds)) => (Arc::clone(ds), true),
            None => (Arc::new(id.build(scale, self.seed)), false),
        };
        let objective = match named_objective {
            Some(o) => Some(o),
            // priors without an objective name: they only apply to aopt, so
            // honor them when that is the dataset's natural objective and
            // reject (instead of silently dropping them) otherwise
            None if self.beta_sq.is_some() || self.sigma_sq.is_some() => {
                if dataset.task == Task::Design {
                    Some(aopt)
                } else {
                    return Err(SelectError::invalid(
                        "beta_sq/sigma_sq apply only to the aopt objective; \
                         set \"objective\":\"aopt\" explicitly",
                    ));
                }
            }
            None => None,
        };
        let mut b =
            ProblemSpec::builder(dataset).backend(backend).k(self.k).seed(self.seed);
        if let Some(objective) = objective {
            b = b.objective(objective);
        }
        let spec = b.build()?;
        // memoize only specs that validated end to end: a stream of
        // rejected opens (k > n, bad priors) must not grow the cache —
        // successful opens are bounded by the server's session budget
        if !cached {
            cache.push((key, Arc::clone(&spec.dataset)));
        }
        Ok(spec)
    }
}

/// Memo of synthesized datasets keyed by `(dataset id, scale, seed)` —
/// see [`WireProblem::resolve_cached`].
pub type DatasetCache = Vec<((DatasetId, Scale, u64), Arc<Dataset>)>;

/// Wire form of a [`PlanSpec`]: the algorithm name plus optional tuning.
/// Unset knobs take the algorithm's defaults; knobs that do not apply are
/// ignored, exactly as in [`PlanSpec::builder`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WirePlan {
    /// CLI/wire algorithm name ([`PlanKind::parse`](crate::coordinator::api::PlanKind::parse))
    pub algo: String,
    pub epsilon: Option<f64>,
    pub alpha: Option<f64>,
    pub samples: Option<usize>,
    pub r: Option<usize>,
    pub max_rounds: Option<usize>,
    pub threads: Option<usize>,
    pub trials: Option<usize>,
    pub serial_prefix: Option<bool>,
    /// early-stop gain threshold (greedy variants)
    pub min_gain: Option<f64>,
    /// known OPT value (dash, adaptive-sampling); absent = the Appendix G
    /// guess ladder
    pub opt: Option<f64>,
    /// LASSO path tuning (lasso only)
    pub path_len: Option<usize>,
    pub lambda_min_ratio: Option<f64>,
    pub max_iters: Option<usize>,
    pub tol: Option<f64>,
}

impl WirePlan {
    pub fn new(algo: &str) -> WirePlan {
        WirePlan { algo: algo.to_string(), ..WirePlan::default() }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("algo", self.algo.as_str().into())];
        if let Some(v) = self.epsilon {
            pairs.push(("epsilon", v.into()));
        }
        if let Some(v) = self.alpha {
            pairs.push(("alpha", v.into()));
        }
        if let Some(v) = self.samples {
            pairs.push(("samples", v.into()));
        }
        if let Some(v) = self.r {
            pairs.push(("r", v.into()));
        }
        if let Some(v) = self.max_rounds {
            pairs.push(("max_rounds", v.into()));
        }
        if let Some(v) = self.threads {
            pairs.push(("threads", v.into()));
        }
        if let Some(v) = self.trials {
            pairs.push(("trials", v.into()));
        }
        if let Some(v) = self.serial_prefix {
            pairs.push(("serial_prefix", v.into()));
        }
        if let Some(v) = self.min_gain {
            pairs.push(("min_gain", v.into()));
        }
        if let Some(v) = self.opt {
            pairs.push(("opt", v.into()));
        }
        if let Some(v) = self.path_len {
            pairs.push(("path_len", v.into()));
        }
        if let Some(v) = self.lambda_min_ratio {
            pairs.push(("lambda_min_ratio", v.into()));
        }
        if let Some(v) = self.max_iters {
            pairs.push(("max_iters", v.into()));
        }
        if let Some(v) = self.tol {
            pairs.push(("tol", v.into()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<WirePlan, SelectError> {
        Ok(WirePlan {
            algo: need_str(j, "algo")?.to_string(),
            epsilon: opt_f64(j, "epsilon")?,
            alpha: opt_f64(j, "alpha")?,
            samples: opt_usize(j, "samples")?,
            r: opt_usize(j, "r")?,
            max_rounds: opt_usize(j, "max_rounds")?,
            threads: opt_usize(j, "threads")?,
            trials: opt_usize(j, "trials")?,
            serial_prefix: opt_bool(j, "serial_prefix")?,
            min_gain: opt_f64(j, "min_gain")?,
            opt: opt_f64(j, "opt")?,
            path_len: opt_usize(j, "path_len")?,
            lambda_min_ratio: opt_f64(j, "lambda_min_ratio")?,
            max_iters: opt_usize(j, "max_iters")?,
            tol: opt_f64(j, "tol")?,
        })
    }

    /// Validate into a [`PlanSpec`].
    pub fn resolve(&self) -> Result<PlanSpec, SelectError> {
        let mut b = PlanSpec::parse(&self.algo)?;
        if let Some(v) = self.epsilon {
            b = b.epsilon(v);
        }
        if let Some(v) = self.alpha {
            b = b.alpha(v);
        }
        if let Some(v) = self.samples {
            b = b.samples(v);
        }
        if let Some(v) = self.r {
            b = b.r(v);
        }
        if let Some(v) = self.max_rounds {
            b = b.max_rounds(v);
        }
        if let Some(v) = self.threads {
            b = b.threads(v);
        }
        if let Some(v) = self.trials {
            b = b.trials(v);
        }
        if let Some(v) = self.serial_prefix {
            b = b.serial_prefix(v);
        }
        if let Some(v) = self.min_gain {
            b = b.min_gain(v);
        }
        if let Some(v) = self.opt {
            b = b.opt(OptEstimate::Known(v));
        }
        if self.path_len.is_some()
            || self.lambda_min_ratio.is_some()
            || self.max_iters.is_some()
            || self.tol.is_some()
        {
            let d = LassoConfig::default();
            b = b.lasso_config(LassoConfig {
                path_len: self.path_len.unwrap_or(d.path_len),
                lambda_min_ratio: self.lambda_min_ratio.unwrap_or(d.lambda_min_ratio),
                max_iters: self.max_iters.unwrap_or(d.max_iters),
                tol: self.tol.unwrap_or(d.tol),
            });
        }
        b.build()
    }
}

// ---------------------------------------------------------------------------
// Typed v1 requests / replies
// ---------------------------------------------------------------------------

/// One v1 API request. The five session-addressed ops mirror
/// [`ServeRequest`] one-to-one ([`ApiRequest::into_serve`]); `Open`/`List`
/// are server-level and handled by the front that owns the
/// [`SessionServer`].
#[derive(Debug, Clone, PartialEq)]
pub enum ApiRequest {
    /// Create a session from wire specs; `driven` attaches the plan's
    /// stepwise driver (`step`/`finish`), otherwise the lane takes raw
    /// sweep/insert traffic. `tenant` names the quota bucket the session
    /// is charged to (absent = the `"default"` tenant). `session` pins
    /// the new session to an exact id: the open is rejected if that id is
    /// already in use — the router's global-id allocation token (plain
    /// clients leave it absent and take whatever id the server picks).
    Open {
        problem: WireProblem,
        plan: WirePlan,
        driven: bool,
        tenant: Option<String>,
        session: Option<usize>,
    },
    /// Enumerate open sessions (resident and evicted).
    List,
    /// Close a session: drop its lane — objective, state, driver — and
    /// free its slot in the resident budget. Later requests addressed to
    /// the id are [`SelectError::UnknownSession`].
    Close { session: usize },
    /// Marginal gains for `candidates` at the session's current generation.
    Sweep { session: usize, candidates: Vec<usize> },
    /// Grow the session's solution set. `if_generation` pins the insert:
    /// it applies only while the session is still at that generation,
    /// otherwise the reply is a [`SelectError::StaleGeneration`] —
    /// optimistic concurrency for clients racing other writers.
    Insert { session: usize, item: usize, if_generation: Option<u64> },
    /// Advance the session's attached driver by one adaptive round.
    Step { session: usize },
    /// Finalize the attached driver (idempotent once stepped to done).
    Finish { session: usize },
    /// Point-in-time session snapshot.
    Metrics { session: usize },
    /// Liveness probe: answered with [`ApiReply::Pong`] and no side
    /// effects. Reconnecting clients use it to confirm a fresh transport
    /// before resuming session traffic.
    Ping,
    /// Graceful drain: snapshot every evictable lane to the session store,
    /// stop taking new work, and answer [`ApiReply::Stopping`]. The front
    /// exits after the in-flight turn completes.
    Shutdown,
    /// Test-only fault injection: panic inside the request handler.
    /// Rejected unless the front opted in ([`WireCore::with_fault_ops`]);
    /// the chaos harness uses it to prove panic containment.
    Crash { message: String },
}

/// Summary row of one open session ([`ApiReply::Sessions`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionInfo {
    pub session: usize,
    /// result-label of the lane's algorithm (`sds_ma`, `dash`, …)
    pub algorithm: String,
    pub driven: bool,
    /// the lane's driver has been finalized
    pub finished: bool,
    pub generation: u64,
    pub set_len: usize,
    /// quota bucket the session is charged to
    pub tenant: String,
    /// `true` while the session is live in the serving core; `false`
    /// while it sits evicted in the session store (a request addressed
    /// to it restores it)
    pub resident: bool,
}

/// One v1 API reply. `Error` carries the [`SelectError`] a request was
/// answered with; every other variant mirrors a [`ServeReply`]
/// ([`ApiReply::from_serve`]) or a server-level op.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiReply {
    Opened { session: usize },
    Sessions { sessions: Vec<SessionInfo> },
    Closed { session: usize },
    Swept { gains: Vec<f64>, generation: u64, fresh: usize },
    Inserted { grew: bool, generation: u64 },
    Stepped { done: bool, generation: u64 },
    Finished { result: SelectionResult },
    Snapshot { snapshot: SessionSnapshot },
    /// Liveness probe answer.
    Pong,
    /// Graceful-drain acknowledgment: `persisted` evictable lanes were
    /// snapshotted to the store before the front stopped.
    Stopping { persisted: usize },
    Error { error: SelectError },
}

impl ApiRequest {
    /// The frame's `op` string.
    pub fn op(&self) -> &'static str {
        match self {
            ApiRequest::Open { .. } => "open",
            ApiRequest::List => "list",
            ApiRequest::Close { .. } => "close",
            ApiRequest::Sweep { .. } => "sweep",
            ApiRequest::Insert { .. } => "insert",
            ApiRequest::Step { .. } => "step",
            ApiRequest::Finish { .. } => "finish",
            ApiRequest::Metrics { .. } => "metrics",
            ApiRequest::Ping => "ping",
            ApiRequest::Shutdown => "shutdown",
            ApiRequest::Crash { .. } => "crash",
        }
    }

    /// Convert a session-addressed request into its serving-core form.
    /// Server-level ops (`Open`, `List`) have no session target and are
    /// rejected here — the owning front handles them before this point.
    pub fn into_serve(self) -> Result<(SessionId, ServeRequest), SelectError> {
        match self {
            ApiRequest::Sweep { session, candidates } => {
                Ok((SessionId(session), ServeRequest::Sweep { candidates }))
            }
            ApiRequest::Insert { session, item, if_generation } => {
                Ok((SessionId(session), ServeRequest::Insert { item, if_generation }))
            }
            ApiRequest::Step { session } => Ok((SessionId(session), ServeRequest::Step)),
            ApiRequest::Finish { session } => Ok((SessionId(session), ServeRequest::Finish)),
            ApiRequest::Metrics { session } => Ok((SessionId(session), ServeRequest::Metrics)),
            ApiRequest::Close { session } => Ok((SessionId(session), ServeRequest::Close)),
            ApiRequest::Open { .. }
            | ApiRequest::List
            | ApiRequest::Ping
            | ApiRequest::Shutdown
            | ApiRequest::Crash { .. } => Err(SelectError::Rejected(
                "open/list/ping/shutdown/crash are server-level requests, not addressed to a \
                 session"
                    .into(),
            )),
        }
    }

    /// Encode one newline-free request frame. `id` is clamped to
    /// [`MAX_WIRE_INT`] (the JSON-faithful integer range).
    pub fn encode(&self, id: u64) -> String {
        let id = id.min(MAX_WIRE_INT);
        let mut pairs: Vec<(&str, Json)> =
            vec![("v", WIRE_VERSION.into()), ("id", id.into()), ("op", self.op().into())];
        match self {
            ApiRequest::Open { problem, plan, driven, tenant, session } => {
                pairs.push(("driven", (*driven).into()));
                pairs.push(("problem", problem.to_json()));
                pairs.push(("plan", plan.to_json()));
                if let Some(t) = tenant {
                    pairs.push(("tenant", t.as_str().into()));
                }
                if let Some(s) = session {
                    pairs.push(("session", (*s).into()));
                }
            }
            ApiRequest::List => {}
            ApiRequest::Close { session } => {
                pairs.push(("session", (*session).into()));
            }
            ApiRequest::Sweep { session, candidates } => {
                pairs.push(("session", (*session).into()));
                pairs.push(("candidates", Json::arr_usize(candidates)));
            }
            ApiRequest::Insert { session, item, if_generation } => {
                pairs.push(("session", (*session).into()));
                pairs.push(("item", (*item).into()));
                if let Some(g) = if_generation {
                    pairs.push(("if_generation", (*g).into()));
                }
            }
            ApiRequest::Step { session }
            | ApiRequest::Finish { session }
            | ApiRequest::Metrics { session } => {
                pairs.push(("session", (*session).into()));
            }
            ApiRequest::Ping | ApiRequest::Shutdown => {}
            ApiRequest::Crash { message } => {
                if !message.is_empty() {
                    pairs.push(("message", message.as_str().into()));
                }
            }
        }
        Json::obj(pairs).to_string_compact()
    }

    /// Decode one request frame: `(id, request)`. Any malformed input —
    /// bad JSON, wrong `v`, unknown `op`, missing or mistyped fields — is
    /// a [`SelectError::Protocol`].
    pub fn decode(line: &str) -> Result<(u64, ApiRequest), SelectError> {
        let j = Json::parse(line.trim())
            .map_err(|e| SelectError::Protocol(format!("bad frame: {e}")))?;
        let v = need_u64(&j, "v")?;
        if v != WIRE_VERSION {
            return Err(SelectError::Protocol(format!(
                "unsupported protocol version {v} (this server speaks v{WIRE_VERSION})"
            )));
        }
        let id = opt_u64(&j, "id")?.unwrap_or(0);
        let req = match need_str(&j, "op")? {
            "open" => ApiRequest::Open {
                problem: WireProblem::from_json(need(&j, "problem")?)?,
                plan: WirePlan::from_json(need(&j, "plan")?)?,
                driven: opt_bool(&j, "driven")?.unwrap_or(false),
                tenant: opt_str(&j, "tenant")?,
                session: opt_usize(&j, "session")?,
            },
            "list" => ApiRequest::List,
            "close" => ApiRequest::Close { session: need_usize(&j, "session")? },
            "sweep" => ApiRequest::Sweep {
                session: need_usize(&j, "session")?,
                candidates: need_usize_arr(&j, "candidates")?,
            },
            "insert" => ApiRequest::Insert {
                session: need_usize(&j, "session")?,
                item: need_usize(&j, "item")?,
                if_generation: opt_u64(&j, "if_generation")?,
            },
            "step" => ApiRequest::Step { session: need_usize(&j, "session")? },
            "finish" => ApiRequest::Finish { session: need_usize(&j, "session")? },
            "metrics" => ApiRequest::Metrics { session: need_usize(&j, "session")? },
            "ping" => ApiRequest::Ping,
            "shutdown" => ApiRequest::Shutdown,
            "crash" => ApiRequest::Crash {
                message: opt_str(&j, "message")?.unwrap_or_default(),
            },
            other => return Err(SelectError::Protocol(format!("unknown op '{other}'"))),
        };
        Ok((id, req))
    }
}

impl ApiReply {
    /// The frame's `op` string.
    pub fn op(&self) -> &'static str {
        match self {
            ApiReply::Opened { .. } => "opened",
            ApiReply::Sessions { .. } => "sessions",
            ApiReply::Closed { .. } => "closed",
            ApiReply::Swept { .. } => "swept",
            ApiReply::Inserted { .. } => "inserted",
            ApiReply::Stepped { .. } => "stepped",
            ApiReply::Finished { .. } => "finished",
            ApiReply::Snapshot { .. } => "snapshot",
            ApiReply::Pong => "pong",
            ApiReply::Stopping { .. } => "stopping",
            ApiReply::Error { .. } => "error",
        }
    }

    /// Lift a serving-core reply into its wire form — the shared exit path
    /// of the in-process client and the stdio front.
    pub fn from_serve(reply: ServeReply) -> ApiReply {
        match reply {
            ServeReply::Sweep { gains, generation, round_fresh } => {
                ApiReply::Swept { gains, generation, fresh: round_fresh }
            }
            ServeReply::Insert { grew, generation } => ApiReply::Inserted { grew, generation },
            ServeReply::Step { done, generation } => ApiReply::Stepped { done, generation },
            ServeReply::Finish { result } => ApiReply::Finished { result },
            ServeReply::Metrics { snapshot } => ApiReply::Snapshot { snapshot },
            ServeReply::Closed { session } => ApiReply::Closed { session },
        }
    }

    /// Encode one newline-free reply frame (echoing the request's `id`,
    /// clamped to [`MAX_WIRE_INT`]).
    pub fn encode(&self, id: u64) -> String {
        let id = id.min(MAX_WIRE_INT);
        let mut pairs: Vec<(&str, Json)> =
            vec![("v", WIRE_VERSION.into()), ("id", id.into()), ("op", self.op().into())];
        match self {
            ApiReply::Opened { session } | ApiReply::Closed { session } => {
                pairs.push(("session", (*session).into()))
            }
            ApiReply::Sessions { sessions } => {
                pairs.push((
                    "sessions",
                    Json::Arr(sessions.iter().map(session_info_to_json).collect()),
                ));
            }
            ApiReply::Swept { gains, generation, fresh } => {
                pairs.push(("gains", Json::arr_f64(gains)));
                pairs.push(("generation", (*generation).into()));
                pairs.push(("fresh", (*fresh).into()));
            }
            ApiReply::Inserted { grew, generation } => {
                pairs.push(("grew", (*grew).into()));
                pairs.push(("generation", (*generation).into()));
            }
            ApiReply::Stepped { done, generation } => {
                pairs.push(("done", (*done).into()));
                pairs.push(("generation", (*generation).into()));
            }
            ApiReply::Finished { result } => pairs.push(("result", result_to_json(result))),
            ApiReply::Snapshot { snapshot } => {
                pairs.push(("snapshot", snapshot_to_json(snapshot)))
            }
            ApiReply::Pong => {}
            ApiReply::Stopping { persisted } => pairs.push(("persisted", (*persisted).into())),
            ApiReply::Error { error } => pairs.push(("error", error_to_json(error))),
        }
        Json::obj(pairs).to_string_compact()
    }

    /// Decode one reply frame: `(id, reply)`.
    pub fn decode(line: &str) -> Result<(u64, ApiReply), SelectError> {
        let j = Json::parse(line.trim())
            .map_err(|e| SelectError::Protocol(format!("bad frame: {e}")))?;
        let v = need_u64(&j, "v")?;
        if v != WIRE_VERSION {
            return Err(SelectError::Protocol(format!(
                "unsupported protocol version {v} (this client speaks v{WIRE_VERSION})"
            )));
        }
        let id = opt_u64(&j, "id")?.unwrap_or(0);
        let reply = match need_str(&j, "op")? {
            "opened" => ApiReply::Opened { session: need_usize(&j, "session")? },
            "closed" => ApiReply::Closed { session: need_usize(&j, "session")? },
            "sessions" => ApiReply::Sessions {
                sessions: need(&j, "sessions")?
                    .as_arr()
                    .ok_or_else(|| SelectError::Protocol("'sessions' must be an array".into()))?
                    .iter()
                    .map(session_info_from_json)
                    .collect::<Result<Vec<_>, _>>()?,
            },
            "swept" => ApiReply::Swept {
                gains: need_f64_arr(&j, "gains")?,
                generation: need_u64(&j, "generation")?,
                fresh: need_usize(&j, "fresh")?,
            },
            "inserted" => ApiReply::Inserted {
                grew: need_bool(&j, "grew")?,
                generation: need_u64(&j, "generation")?,
            },
            "stepped" => ApiReply::Stepped {
                done: need_bool(&j, "done")?,
                generation: need_u64(&j, "generation")?,
            },
            "finished" => ApiReply::Finished { result: result_from_json(need(&j, "result")?)? },
            "snapshot" => {
                ApiReply::Snapshot { snapshot: snapshot_from_json(need(&j, "snapshot")?)? }
            }
            "pong" => ApiReply::Pong,
            "stopping" => ApiReply::Stopping { persisted: need_usize(&j, "persisted")? },
            "error" => ApiReply::Error { error: error_from_json(need(&j, "error")?)? },
            other => return Err(SelectError::Protocol(format!("unknown op '{other}'"))),
        };
        Ok((id, reply))
    }
}

// ---------------------------------------------------------------------------
// Payload codecs
// ---------------------------------------------------------------------------

fn session_info_to_json(s: &SessionInfo) -> Json {
    Json::obj(vec![
        ("session", s.session.into()),
        ("algorithm", s.algorithm.as_str().into()),
        ("driven", s.driven.into()),
        ("finished", s.finished.into()),
        ("generation", s.generation.into()),
        ("set_len", s.set_len.into()),
        ("tenant", s.tenant.as_str().into()),
        ("resident", s.resident.into()),
    ])
}

fn session_info_from_json(j: &Json) -> Result<SessionInfo, SelectError> {
    Ok(SessionInfo {
        session: need_usize(j, "session")?,
        algorithm: need_str(j, "algorithm")?.to_string(),
        driven: need_bool(j, "driven")?,
        finished: need_bool(j, "finished")?,
        generation: need_u64(j, "generation")?,
        set_len: need_usize(j, "set_len")?,
        tenant: need_str(j, "tenant")?.to_string(),
        resident: need_bool(j, "resident")?,
    })
}

/// Wire form of a [`SelectionResult`] — every field, history included, so
/// a result decoded from the wire equals the in-process one.
pub fn result_to_json(r: &SelectionResult) -> Json {
    Json::obj(vec![
        ("algorithm", r.algorithm.as_str().into()),
        ("set", Json::arr_usize(&r.set)),
        ("value", r.value.into()),
        ("rounds", r.rounds.into()),
        ("queries", r.queries.into()),
        ("wall_s", r.wall_s.into()),
        ("hit_iteration_cap", r.hit_iteration_cap.into()),
        (
            "history",
            Json::Arr(
                r.history
                    .iter()
                    .map(|rec| {
                        Json::obj(vec![
                            ("round", rec.round.into()),
                            ("value", rec.value.into()),
                            ("queries", rec.queries.into()),
                            ("wall_s", rec.wall_s.into()),
                            ("set_size", rec.set_size.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

pub fn result_from_json(j: &Json) -> Result<SelectionResult, SelectError> {
    let history = need(j, "history")?
        .as_arr()
        .ok_or_else(|| SelectError::Protocol("'history' must be an array".into()))?
        .iter()
        .map(|rec| {
            Ok(RoundRecord {
                round: need_usize(rec, "round")?,
                value: need_f64(rec, "value")?,
                queries: need_usize(rec, "queries")?,
                wall_s: need_f64(rec, "wall_s")?,
                set_size: need_usize(rec, "set_size")?,
            })
        })
        .collect::<Result<Vec<_>, SelectError>>()?;
    Ok(SelectionResult {
        algorithm: need_str(j, "algorithm")?.to_string(),
        set: need_usize_arr(j, "set")?,
        value: need_f64(j, "value")?,
        rounds: need_usize(j, "rounds")?,
        queries: need_usize(j, "queries")?,
        wall_s: need_f64(j, "wall_s")?,
        hit_iteration_cap: need_bool(j, "hit_iteration_cap")?,
        history,
    })
}

/// Wire form of a [`SessionSnapshot`] — generation, set, value bits, and
/// metrics. The session store persists this verbatim in its records.
pub fn snapshot_to_json(s: &SessionSnapshot) -> Json {
    let m = &s.metrics;
    Json::obj(vec![
        ("generation", s.generation.0.into()),
        ("set", Json::arr_usize(&s.set)),
        ("value", s.value.into()),
        (
            "metrics",
            Json::obj(vec![
                ("sweeps", m.sweeps.into()),
                ("swept_candidates", m.swept_candidates.into()),
                ("cache_hits", m.cache_hits.into()),
                ("fresh_queries", m.fresh_queries.into()),
                ("inserts", m.inserts.into()),
                ("sample_rounds", m.sample_rounds.into()),
                ("prefix_rounds", m.prefix_rounds.into()),
                ("fork_sweeps", m.fork_sweeps.into()),
            ]),
        ),
    ])
}

pub fn snapshot_from_json(j: &Json) -> Result<SessionSnapshot, SelectError> {
    let m = need(j, "metrics")?;
    Ok(SessionSnapshot {
        generation: Generation(need_u64(j, "generation")?),
        set: need_usize_arr(j, "set")?,
        value: need_f64(j, "value")?,
        metrics: SessionMetrics {
            sweeps: need_usize(m, "sweeps")?,
            swept_candidates: need_usize(m, "swept_candidates")?,
            cache_hits: need_usize(m, "cache_hits")?,
            fresh_queries: need_usize(m, "fresh_queries")?,
            inserts: need_usize(m, "inserts")?,
            sample_rounds: need_usize(m, "sample_rounds")?,
            prefix_rounds: need_usize(m, "prefix_rounds")?,
            fork_sweeps: need_usize(m, "fork_sweeps")?,
        },
    })
}

/// Encode a [`SelectError`] as its wire object: a stable `kind`, the
/// display `message`, and the variant's structured payload (`reason`,
/// `session`, `pinned`/`actual`).
pub fn error_to_json(e: &SelectError) -> Json {
    let mut pairs: Vec<(&str, Json)> =
        vec![("kind", e.kind().into()), ("message", e.to_string().into())];
    match e {
        SelectError::InvalidSpec(m)
        | SelectError::Backpressure(m)
        | SelectError::Backend(m)
        | SelectError::Rejected(m)
        | SelectError::ClientPanic(m)
        | SelectError::Deadline(m)
        | SelectError::Protocol(m) => pairs.push(("reason", m.as_str().into())),
        SelectError::UnknownSession(s) => pairs.push(("session", (*s).into())),
        SelectError::StaleGeneration { pinned, actual } => {
            pairs.push(("pinned", (*pinned).into()));
            pairs.push(("actual", (*actual).into()));
        }
        SelectError::Disconnected => {}
    }
    Json::obj(pairs)
}

/// Decode a wire error object back into the exact [`SelectError`].
pub fn error_from_json(j: &Json) -> Result<SelectError, SelectError> {
    let reason = || -> Result<String, SelectError> { Ok(need_str(j, "reason")?.to_string()) };
    match need_str(j, "kind")? {
        "invalid_spec" => Ok(SelectError::InvalidSpec(reason()?)),
        "unknown_session" => Ok(SelectError::UnknownSession(need_usize(j, "session")?)),
        "stale_generation" => Ok(SelectError::StaleGeneration {
            pinned: need_u64(j, "pinned")?,
            actual: need_u64(j, "actual")?,
        }),
        "backpressure" => Ok(SelectError::Backpressure(reason()?)),
        "backend" => Ok(SelectError::Backend(reason()?)),
        "rejected" => Ok(SelectError::Rejected(reason()?)),
        "client_panic" => Ok(SelectError::ClientPanic(reason()?)),
        "deadline" => Ok(SelectError::Deadline(reason()?)),
        "disconnected" => Ok(SelectError::Disconnected),
        "protocol" => Ok(SelectError::Protocol(reason()?)),
        other => Err(SelectError::Protocol(format!("unknown error kind '{other}'"))),
    }
}

// ---------------------------------------------------------------------------
// Decode helpers
// ---------------------------------------------------------------------------

pub(crate) fn need<'a>(j: &'a Json, key: &str) -> Result<&'a Json, SelectError> {
    j.get(key)
        .ok_or_else(|| SelectError::Protocol(format!("missing field '{key}'")))
}

pub(crate) fn need_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, SelectError> {
    need(j, key)?
        .as_str()
        .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must be a string")))
}

pub(crate) fn need_usize(j: &Json, key: &str) -> Result<usize, SelectError> {
    need(j, key)?
        .as_usize()
        .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must be a non-negative integer")))
}

pub(crate) fn need_u64(j: &Json, key: &str) -> Result<u64, SelectError> {
    need(j, key)?
        .as_u64()
        .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must be a non-negative integer")))
}

pub(crate) fn need_f64(j: &Json, key: &str) -> Result<f64, SelectError> {
    need(j, key)?
        .as_f64()
        .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must be a number")))
}

pub(crate) fn need_bool(j: &Json, key: &str) -> Result<bool, SelectError> {
    need(j, key)?
        .as_bool()
        .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must be a boolean")))
}

pub(crate) fn need_usize_arr(j: &Json, key: &str) -> Result<Vec<usize>, SelectError> {
    need(j, key)?
        .as_arr()
        .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must be an array")))?
        .iter()
        .map(|v| {
            v.as_usize().ok_or_else(|| {
                SelectError::Protocol(format!("field '{key}' must hold non-negative integers"))
            })
        })
        .collect()
}

pub(crate) fn need_f64_arr(j: &Json, key: &str) -> Result<Vec<f64>, SelectError> {
    need(j, key)?
        .as_arr()
        .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must be an array")))?
        .iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| SelectError::Protocol(format!("field '{key}' must hold numbers")))
        })
        .collect()
}

fn opt_str(j: &Json, key: &str) -> Result<Option<String>, SelectError> {
    match j.get(key) {
        None => Ok(None),
        Some(_) => Ok(Some(need_str(j, key)?.to_string())),
    }
}

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>, SelectError> {
    match j.get(key) {
        None => Ok(None),
        Some(_) => Ok(Some(need_f64(j, key)?)),
    }
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, SelectError> {
    match j.get(key) {
        None => Ok(None),
        Some(_) => Ok(Some(need_usize(j, key)?)),
    }
}

fn opt_u64(j: &Json, key: &str) -> Result<Option<u64>, SelectError> {
    match j.get(key) {
        None => Ok(None),
        Some(_) => Ok(Some(need_u64(j, key)?)),
    }
}

fn opt_bool(j: &Json, key: &str) -> Result<Option<bool>, SelectError> {
    match j.get(key) {
        None => Ok(None),
        Some(_) => Ok(Some(need_bool(j, key)?)),
    }
}

// ---------------------------------------------------------------------------
// StdioServer — the v1 front over the deterministic serving core
// ---------------------------------------------------------------------------

/// Best-effort id of a frame that failed to decode: a malformed frame
/// with a perfectly readable `id` (missing field, unknown op, wrong
/// version) still gets its error reply correlated to the request.
pub(crate) fn readable_frame_id(line: &str) -> u64 {
    Json::parse(line.trim())
        .ok()
        .and_then(|j| j.get("id").and_then(Json::as_u64))
        .unwrap_or(0)
}

/// Tenant an open is charged to when the frame names none.
pub const DEFAULT_TENANT: &str = "default";

/// Resident bookkeeping for one live wire session.
struct LaneMeta {
    /// slot in the serving core (internal; wire ids are stable, slots are
    /// recycled by the core's own free list)
    slot: SessionId,
    algorithm: String,
    driven: bool,
    tenant: String,
    seed: u64,
    /// wire specs to rebuild the objective from on restore; `None` for
    /// embedded [`WireCore::open_objective`] lanes, which are pinned
    /// resident (nothing to rebuild them from)
    specs: Option<(WireProblem, WirePlan)>,
    /// LRU stamp: the front's logical clock at the lane's last request
    last_used: u64,
}

/// List-row cache for a session that sits evicted in the store (the
/// authoritative copy is the [`SessionRecord`](crate::coordinator::store::SessionRecord)
/// on disk).
struct EvictedMeta {
    algorithm: String,
    driven: bool,
    tenant: String,
    finished: bool,
    generation: u64,
    set_len: usize,
}

/// Lifecycle state of one wire session id.
enum WireLane {
    /// live in the serving core
    Live(LaneMeta),
    /// snapshotted to the session store; restored on next request
    Evicted(EvictedMeta),
    /// closed; the id is recyclable by a later open
    Closed,
}

/// The transport-agnostic v1 wire core: decodes request frames, drives the
/// deterministic [`SessionServer`] core (`submit` + `turn`), and encodes
/// one reply frame per request, in order. Both serving fronts are thin
/// loops over it — [`StdioServer`] pumps stdin/stdout, the socket front
/// ([`NetServer`](crate::coordinator::net::NetServer)) pumps connection
/// handlers through one core — so the two transports are provably one
/// code path. The protocol tests drive it directly (no process, no
/// threads).
///
/// Sessions opened over the wire resolve their dataset/objective through
/// the leader ([`Leader::objective`]) and are **owned by their lane**: the
/// `close` op drops them, and with a session store attached
/// ([`WireCore::with_store`]) idle lanes are evicted to disk and
/// restored on demand — see the module docs for the full lifecycle.
///
/// # Fault containment
///
/// [`WireCore::line`] catches panics raised inside request handling and
/// answers with a typed [`SelectError::ClientPanic`] frame instead of
/// unwinding through the serving loop — one poisoned request cannot take
/// down the front or the other lanes. The test-only `crash` op (gated by
/// [`WireCore::with_fault_ops`]) exists to prove exactly that.
pub struct WireCore {
    leader: Leader,
    server: SessionServer<'static>,
    /// wire id → lifecycle state; indices are the public session ids
    lanes: Vec<WireLane>,
    /// identical (dataset, scale, seed) opens share one synthesized dataset
    datasets: DatasetCache,
    /// cap on *live* sessions (evicted sessions don't count)
    max_sessions: usize,
    /// cap on sessions (live + evicted) owned by any one tenant
    max_per_tenant: usize,
    store: Option<SessionStore>,
    /// logical LRU clock, bumped once per session-addressed request
    clock: u64,
    /// `shutdown` op (or a drain signal) was observed: the owning front
    /// stops its loop after the in-flight reply
    draining: bool,
    /// serve the test-only `crash` fault-injection op
    fault_ops: bool,
    /// lifetime eviction / restore counters (observability for benches
    /// and soaks)
    pub evictions: u64,
    pub restores: u64,
    /// requests answered with [`SelectError::ClientPanic`] after a
    /// contained handler panic
    pub contained_panics: u64,
}

impl WireCore {
    pub fn new(leader: Leader) -> WireCore {
        WireCore {
            leader,
            server: SessionServer::new(),
            lanes: Vec::new(),
            datasets: DatasetCache::new(),
            max_sessions: 64,
            max_per_tenant: usize::MAX,
            store: None,
            clock: 0,
            draining: false,
            fault_ops: false,
            evictions: 0,
            restores: 0,
            contained_panics: 0,
        }
    }

    /// Cap on *live* sessions. Without a store, opens beyond it are
    /// answered with [`SelectError::Backpressure`]; with one, they evict
    /// the least-recently-used idle lane first.
    pub fn with_max_sessions(mut self, max_sessions: usize) -> WireCore {
        self.max_sessions = max_sessions.max(1);
        self
    }

    /// Attach a session store, enabling evict/restore durability. Records
    /// already in the store — left by a previous process's drain, or by
    /// write-through persistence before a crash — are adopted as evicted
    /// lanes, so a restarted server resumes the same session ids
    /// transparently. Records that fail to load are quarantined by the
    /// store and skipped; they never poison adoption of their neighbors.
    pub fn with_store(mut self, store: SessionStore) -> WireCore {
        for id in store.list() {
            let Ok(record) = store.load(id) else {
                // load() has quarantined the corrupt record; the id stays
                // closed (recyclable) instead of wedging the whole store
                continue;
            };
            while self.lanes.len() <= id {
                self.lanes.push(WireLane::Closed);
            }
            self.lanes[id] = WireLane::Evicted(EvictedMeta {
                algorithm: record.algorithm,
                driven: record.driven,
                tenant: record.tenant,
                finished: record.finished,
                generation: record.snapshot.generation.0,
                set_len: record.snapshot.set.len(),
            });
        }
        self.store = Some(store);
        self
    }

    /// Cap on sessions (live + evicted) any one tenant may own; opens
    /// beyond it are answered with [`SelectError::Rejected`]. Unlimited
    /// by default.
    pub fn with_tenant_quota(mut self, max_per_tenant: usize) -> WireCore {
        self.max_per_tenant = max_per_tenant.max(1);
        self
    }

    /// Serve the test-only `crash` op (panic inside the handler). Off by
    /// default: production fronts reject the op as
    /// [`SelectError::Rejected`]; the fault-injection harness turns it on
    /// to prove panic containment.
    pub fn with_fault_ops(mut self, fault_ops: bool) -> WireCore {
        self.fault_ops = fault_ops;
        self
    }

    /// The leader resolving this front's objectives and pooling its sweeps.
    pub fn leader(&self) -> &Leader {
        &self.leader
    }

    /// The attached session store, if durability is enabled.
    pub fn store(&self) -> Option<&SessionStore> {
        self.store.as_ref()
    }

    /// Live (resident) session count — the number charged against
    /// [`StdioServer::with_max_sessions`].
    pub fn live_sessions(&self) -> usize {
        self.server.sessions()
    }

    /// Open a lane from wire specs (the `open` op). `pin` demands an
    /// exact wire id for the new session — the router's global-id
    /// allocation: the open is rejected if the id is already in use here
    /// or in the shared session store (unpinned opens take the first
    /// recyclable id as before).
    pub fn open_spec(
        &mut self,
        problem: &WireProblem,
        plan: &WirePlan,
        driven: bool,
        tenant: Option<&str>,
        pin: Option<usize>,
    ) -> Result<usize, SelectError> {
        // cheap rejections first: an over-quota, malformed-plan, or
        // id-colliding open must not pay for the dataset build and
        // objective construction it is about to throw away
        let tenant = tenant.unwrap_or(DEFAULT_TENANT).to_string();
        self.check_tenant_quota(&tenant)?;
        if let Some(id) = pin {
            self.check_pin_free(id)?;
        }
        let plan_spec = plan.resolve()?;
        if driven && !plan_spec.kind().has_driver() {
            return Err(SelectError::invalid(format!(
                "{} has no stepwise driver to serve",
                plan_spec.kind().name()
            )));
        }
        self.ensure_capacity()?;
        let problem_spec = problem.resolve_cached(&mut self.datasets)?;
        let job = SelectionJob::new(&problem_spec, &plan_spec);
        job.validate()?;
        let driver = if driven {
            Some(Leader::driver_for(&job).ok_or_else(|| {
                SelectError::invalid(format!(
                    "{} has no stepwise driver to serve",
                    job.algorithm.label()
                ))
            })?)
        } else {
            None
        };
        let objective: Arc<dyn Objective> = Arc::from(self.leader.objective(&job)?);
        let label = job.algorithm.label().to_string();
        let seed = job.seed;
        self.install_lane(
            objective,
            driver,
            seed,
            &label,
            tenant,
            Some((problem.clone(), plan.clone())),
            pin,
        )
    }

    /// Reject a pinned open whose id is already claimed — by a lane here
    /// (live or evicted) or by a record in the shared session store
    /// (another worker's session). The `already in use` marker in the
    /// message is the router's retry signal.
    fn check_pin_free(&self, id: usize) -> Result<(), SelectError> {
        let lane_free =
            self.lanes.get(id).map_or(true, |l| matches!(l, WireLane::Closed));
        let store_free = self.store.as_ref().map_or(true, |s| !s.contains(id));
        if lane_free && store_free {
            Ok(())
        } else {
            Err(SelectError::Rejected(format!("session id {id} is already in use")))
        }
    }

    /// Open a lane over an already-built objective — the embedding hook
    /// the byte-identity and accounting tests use to serve instrumented
    /// objectives (e.g. `CountingObjective`) through the wire codec. The
    /// lane owns the objective (dropped on close); having no wire specs
    /// to rebuild from, it is pinned resident and never evicted.
    pub fn open_objective(
        &mut self,
        objective: Box<dyn Objective>,
        driver: Option<Box<dyn SessionDriver>>,
        seed: u64,
        label: &str,
    ) -> Result<usize, SelectError> {
        self.check_tenant_quota(DEFAULT_TENANT)?;
        self.ensure_capacity()?;
        self.install_lane(
            Arc::from(objective),
            driver,
            seed,
            label,
            DEFAULT_TENANT.to_string(),
            None,
            None,
        )
    }

    /// Hand an owned objective to the serving core and record the lane —
    /// the choke point every open (spec or embedded, fresh or restored
    /// via [`WireCore::restore_lane`]'s own path) funnels through. `pin`
    /// installs at that exact wire id (rejecting a raced-away id) instead
    /// of recycling the first closed slot.
    #[allow(clippy::too_many_arguments)]
    fn install_lane(
        &mut self,
        objective: Arc<dyn Objective>,
        driver: Option<Box<dyn SessionDriver>>,
        seed: u64,
        label: &str,
        tenant: String,
        specs: Option<(WireProblem, WirePlan)>,
        pin: Option<usize>,
    ) -> Result<usize, SelectError> {
        // re-check the pin under the same borrow that installs: an open
        // can restore/adopt sessions between the cheap early check and
        // here, and a conflicting install would orphan a server slot
        if let Some(id) = pin {
            self.check_pin_free(id)?;
        }
        let driven = driver.is_some();
        let slot = match driver {
            Some(driver) => self.server.open_driven_shared(
                objective,
                self.leader.executor().clone(),
                driver,
                seed,
            ),
            None => self.server.open_shared(objective, self.leader.executor().clone()),
        };
        self.clock += 1;
        let meta = LaneMeta {
            slot,
            algorithm: label.to_string(),
            driven,
            tenant,
            seed,
            specs,
            last_used: self.clock,
        };
        // closed ids are recycled fd-style; evicted ids stay reserved;
        // pinned ids land exactly where asked, padding with closed slots
        let wire_id = match pin {
            Some(id) => {
                while self.lanes.len() <= id {
                    self.lanes.push(WireLane::Closed);
                }
                self.lanes[id] = WireLane::Live(meta);
                id
            }
            None => match self.lanes.iter().position(|l| matches!(l, WireLane::Closed)) {
                Some(i) => {
                    self.lanes[i] = WireLane::Live(meta);
                    i
                }
                None => {
                    self.lanes.push(WireLane::Live(meta));
                    self.lanes.len() - 1
                }
            },
        };
        // write-through: the lane is durable from birth, so a hard kill
        // right after the open still restores it on restart
        self.persist_lane(wire_id);
        Ok(wire_id)
    }

    /// Reject an open that would take `tenant` over its quota. Both live
    /// and evicted sessions count — eviction frees memory, not the
    /// tenant's claim.
    fn check_tenant_quota(&self, tenant: &str) -> Result<(), SelectError> {
        let owned = self
            .lanes
            .iter()
            .filter(|l| match l {
                WireLane::Live(m) => m.tenant == tenant,
                WireLane::Evicted(m) => m.tenant == tenant,
                WireLane::Closed => false,
            })
            .count();
        if owned >= self.max_per_tenant {
            return Err(SelectError::Rejected(format!(
                "tenant '{tenant}' is at its session quota ({owned} open, max {})",
                self.max_per_tenant
            )));
        }
        Ok(())
    }

    /// Make room for one more live session: free ride if under budget,
    /// otherwise evict the least-recently-used idle lane — or answer
    /// [`SelectError::Backpressure`] when there is no store or nothing
    /// evictable.
    fn ensure_capacity(&mut self) -> Result<(), SelectError> {
        if self.server.sessions() < self.max_sessions {
            return Ok(());
        }
        if self.store.is_none() {
            return Err(SelectError::Backpressure(format!(
                "session budget exhausted ({} live, max {}); close a session, or serve \
                 with a session store to enable eviction",
                self.server.sessions(),
                self.max_sessions
            )));
        }
        // evictable: spec-opened (rebuildable), and not a driver mid-run
        // (driver state is not snapshottable; finished drivers are fine —
        // their result rides the record)
        let victim = self
            .lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| match l {
                WireLane::Live(m) if m.specs.is_some() => {
                    let finished = self.server.finished(m.slot).unwrap_or(false);
                    if m.driven && !finished {
                        None
                    } else {
                        Some((i, m.last_used))
                    }
                }
                _ => None,
            })
            .min_by_key(|&(_, stamp)| stamp)
            .map(|(i, _)| i);
        match victim {
            Some(i) => self.evict_lane(i),
            None => Err(SelectError::Backpressure(format!(
                "session budget exhausted ({} live, max {}) and every live lane is \
                 pinned (embedded or mid-run)",
                self.server.sessions(),
                self.max_sessions
            ))),
        }
    }

    /// Build the durable [`SessionRecord`] of one live lane, or `None`
    /// when the lane has nothing durable: embedded lanes (no wire specs
    /// to rebuild from) and driven lanes still mid-run (driver state is
    /// not snapshottable). The one record-assembly path shared by
    /// eviction, write-through persistence, and graceful drain.
    fn record_for(&self, wire_id: usize) -> Option<SessionRecord> {
        let m = match self.lanes.get(wire_id) {
            Some(WireLane::Live(m)) => m,
            _ => return None,
        };
        let (problem, plan) = m.specs.clone()?;
        let finished = self.server.finished(m.slot).unwrap_or(false);
        if m.driven && !finished {
            return None;
        }
        let snapshot = self.server.session(m.slot)?.snapshot();
        let result = self.server.result(m.slot).cloned();
        Some(SessionRecord {
            session: wire_id,
            tenant: m.tenant.clone(),
            algorithm: m.algorithm.clone(),
            driven: m.driven,
            finished,
            seed: m.seed,
            problem,
            plan,
            snapshot,
            result,
        })
    }

    /// Write-through persistence: with a store attached, mirror one live
    /// lane's state to its disk record after a state-changing request, so
    /// a hard kill (SIGKILL, power loss) loses at most the in-flight
    /// request. Best-effort by design: the live lane is authoritative and
    /// a failed mirror write must not fail the request that already
    /// applied — the eviction path still surfaces persist errors typed.
    fn persist_lane(&mut self, wire_id: usize) {
        let Some(store) = self.store.as_ref() else { return };
        if let Some(record) = self.record_for(wire_id) {
            let _ = store.save(&record);
        }
    }

    /// Snapshot one live lane to the store and drop it from the core. A
    /// failed persist keeps the lane resident (the error propagates to
    /// the open that wanted the slot).
    fn evict_lane(&mut self, wire_id: usize) -> Result<(), SelectError> {
        let record = self.record_for(wire_id).ok_or_else(|| match self.lanes.get(wire_id) {
            Some(WireLane::Live(_)) => SelectError::Rejected(format!(
                "session {wire_id} is pinned resident (no wire specs to restore from, or \
                 driver mid-run)"
            )),
            _ => SelectError::UnknownSession(wire_id),
        })?;
        let slot = match &self.lanes[wire_id] {
            WireLane::Live(m) => m.slot,
            _ => return Err(SelectError::UnknownSession(wire_id)),
        };
        let evicted = EvictedMeta {
            algorithm: record.algorithm.clone(),
            driven: record.driven,
            tenant: record.tenant.clone(),
            finished: record.finished,
            generation: record.snapshot.generation.0,
            set_len: record.snapshot.set.len(),
        };
        let store = self.store.as_ref().ok_or_else(|| {
            SelectError::Backend("no session store configured for eviction".into())
        })?;
        store.save(&record)?;
        self.server.close(slot)?;
        self.lanes[wire_id] = WireLane::Evicted(evicted);
        self.evictions += 1;
        Ok(())
    }

    /// Graceful drain (the `shutdown` op or a drain signal): snapshot
    /// every evictable live lane to the store, then mark the core
    /// draining so the owning front stops its loop after the in-flight
    /// reply. Returns the number of lanes persisted by this call. Lanes
    /// that cannot be persisted — embedded, driver mid-run, or a failing
    /// disk — stay live until the process exits; already-evicted lanes
    /// are durable without further work. Idempotent.
    pub fn drain(&mut self) -> usize {
        self.draining = true;
        let mut persisted = 0;
        if self.store.is_some() {
            for wire_id in 0..self.lanes.len() {
                if matches!(self.lanes[wire_id], WireLane::Live(_))
                    && self.evict_lane(wire_id).is_ok()
                {
                    persisted += 1;
                }
            }
        }
        persisted
    }

    /// Whether a graceful drain was requested ([`WireCore::drain`] ran);
    /// the owning front's loop exits once this is set.
    pub fn draining(&self) -> bool {
        self.draining
    }

    /// Bring an evicted session back: rebuild the objective from its
    /// recorded specs and replay the snapshot into a fresh live lane
    /// (byte-identical state — see the module docs). May itself evict
    /// another idle lane to make room.
    fn restore_lane(&mut self, wire_id: usize) -> Result<SessionId, SelectError> {
        self.ensure_capacity()?;
        let record = self
            .store
            .as_ref()
            .ok_or_else(|| {
                SelectError::Backend("no session store configured for restore".into())
            })?
            .load(wire_id)?;
        let problem_spec = record.problem.resolve_cached(&mut self.datasets)?;
        let plan_spec = record.plan.resolve()?;
        let job = SelectionJob::new(&problem_spec, &plan_spec);
        let objective: Arc<dyn Objective> = Arc::from(self.leader.objective(&job)?);
        let slot = self.server.open_restored(
            ObjectiveHandle::Shared(objective),
            self.leader.executor().clone(),
            &record.snapshot,
            record.result,
        )?;
        self.clock += 1;
        self.lanes[wire_id] = WireLane::Live(LaneMeta {
            slot,
            algorithm: record.algorithm,
            driven: record.driven,
            tenant: record.tenant,
            seed: record.seed,
            specs: Some((record.problem, record.plan)),
            last_used: self.clock,
        });
        self.restores += 1;
        // the disk record is now stale relative to the live lane; it is
        // overwritten on the next eviction and removed on close
        Ok(slot)
    }

    /// Close a session (the `close` op): drop the lane — live or evicted —
    /// and delete its store record. The id becomes recyclable.
    pub fn close_session(&mut self, wire_id: usize) -> Result<(), SelectError> {
        match self.lanes.get(wire_id) {
            Some(WireLane::Live(m)) => {
                let slot = m.slot;
                self.server.close(slot)?;
            }
            Some(WireLane::Evicted(_)) => {}
            _ => {
                // shared-store close: an id this core never adopted but
                // whose record lives in the store (written by another
                // worker, or by a previous life of this one) is closed by
                // deleting the record — the router broadcasts closes, so
                // any worker must be able to retire any stored session
                if self.store.as_ref().is_some_and(|s| s.contains(wire_id)) {
                    if let Some(store) = self.store.as_ref() {
                        store.remove(wire_id);
                    }
                    return Ok(());
                }
                return Err(SelectError::UnknownSession(wire_id));
            }
        }
        if let Some(store) = self.store.as_ref() {
            store.remove(wire_id);
        }
        self.lanes[wire_id] = WireLane::Closed;
        Ok(())
    }

    /// Map a public wire id to its live serving-core slot, restoring the
    /// session first if it sits evicted. Bumps the LRU stamp.
    ///
    /// An id this core has never seen (or saw closed) whose record exists
    /// in the attached store is **adopted**: marked evicted and restored
    /// on the spot. Adoption is how failover works on a shared store — a
    /// session written through by a worker that later died is picked up
    /// lazily, at first request, by whichever worker the router re-placed
    /// it on; `restore_lane` reads the record from disk at that moment,
    /// so the adopting worker resumes from the dead worker's last
    /// persisted write.
    fn resolve_session(&mut self, wire_id: usize) -> Result<SessionId, SelectError> {
        if matches!(self.lanes.get(wire_id), Some(WireLane::Evicted(_))) {
            return self.restore_lane(wire_id);
        }
        let adoptable = self.lanes.get(wire_id).map_or(true, |l| matches!(l, WireLane::Closed));
        if adoptable {
            if let Some(store) = self.store.as_ref() {
                if store.contains(wire_id) {
                    let record = store.load(wire_id)?;
                    while self.lanes.len() <= wire_id {
                        self.lanes.push(WireLane::Closed);
                    }
                    self.lanes[wire_id] = WireLane::Evicted(EvictedMeta {
                        algorithm: record.algorithm,
                        driven: record.driven,
                        tenant: record.tenant,
                        finished: record.finished,
                        generation: record.snapshot.generation.0,
                        set_len: record.snapshot.set.len(),
                    });
                    return self.restore_lane(wire_id);
                }
            }
        }
        self.clock += 1;
        let clock = self.clock;
        match self.lanes.get_mut(wire_id) {
            Some(WireLane::Live(m)) => {
                m.last_used = clock;
                Ok(m.slot)
            }
            _ => Err(SelectError::UnknownSession(wire_id)),
        }
    }

    /// Serve one typed request (shared by [`WireCore::line`] and the
    /// protocol tests).
    pub fn handle(&mut self, req: ApiRequest) -> Result<ApiReply, SelectError> {
        match req {
            ApiRequest::Open { problem, plan, driven, tenant, session } => self
                .open_spec(&problem, &plan, driven, tenant.as_deref(), session)
                .map(|session| ApiReply::Opened { session }),
            ApiRequest::Close { session } => {
                self.close_session(session).map(|()| ApiReply::Closed { session })
            }
            ApiRequest::List => {
                let mut sessions = Vec::new();
                for (i, lane) in self.lanes.iter().enumerate() {
                    match lane {
                        WireLane::Live(m) => {
                            let snap = self
                                .server
                                .session(m.slot)
                                .ok_or(SelectError::UnknownSession(i))?
                                .snapshot();
                            sessions.push(SessionInfo {
                                session: i,
                                algorithm: m.algorithm.clone(),
                                driven: m.driven,
                                finished: self.server.finished(m.slot).unwrap_or(false),
                                generation: snap.generation.0,
                                set_len: snap.set.len(),
                                tenant: m.tenant.clone(),
                                resident: true,
                            });
                        }
                        WireLane::Evicted(m) => sessions.push(SessionInfo {
                            session: i,
                            algorithm: m.algorithm.clone(),
                            driven: m.driven,
                            finished: m.finished,
                            generation: m.generation,
                            set_len: m.set_len,
                            tenant: m.tenant.clone(),
                            resident: false,
                        }),
                        WireLane::Closed => {}
                    }
                }
                Ok(ApiReply::Sessions { sessions })
            }
            ApiRequest::Ping => Ok(ApiReply::Pong),
            ApiRequest::Shutdown => Ok(ApiReply::Stopping { persisted: self.drain() }),
            ApiRequest::Crash { message } => {
                if self.fault_ops {
                    panic!("injected handler fault: {message}");
                }
                Err(SelectError::Rejected(
                    "crash is a test-only fault-injection op; this server does not serve it"
                        .into(),
                ))
            }
            other => {
                let mutating = matches!(
                    other,
                    ApiRequest::Insert { .. } | ApiRequest::Step { .. } | ApiRequest::Finish { .. }
                );
                let (SessionId(wire_id), sreq) = other.into_serve()?;
                let slot = self.resolve_session(wire_id)?;
                let rx = self.server.submit(slot, sreq);
                self.server.turn();
                let reply = rx.recv().map_err(|_| SelectError::Disconnected)??;
                if mutating {
                    self.persist_lane(wire_id);
                }
                Ok(ApiReply::from_serve(reply))
            }
        }
    }

    /// Serve one request line, producing exactly one reply line. Framing
    /// errors echo the frame's `id` whenever it is readable (pipelined
    /// clients correlate replies by id even for rejected frames); only
    /// frames whose id cannot be parsed at all are answered with id 0.
    ///
    /// A panic raised inside request handling is **contained** here: it is
    /// caught and answered as a typed [`SelectError::ClientPanic`] frame,
    /// so one poisoned request can never unwind through — and take down —
    /// the serving loop or the other lanes.
    pub fn line(&mut self, line: &str) -> String {
        match ApiRequest::decode(line) {
            Ok((id, req)) => {
                let outcome =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.handle(req)));
                match outcome {
                    Ok(Ok(reply)) => reply.encode(id),
                    Ok(Err(error)) => ApiReply::Error { error }.encode(id),
                    Err(payload) => {
                        self.contained_panics += 1;
                        let error = SelectError::ClientPanic(panic_message(payload));
                        ApiReply::Error { error }.encode(id)
                    }
                }
            }
            Err(error) => ApiReply::Error { error }.encode(readable_frame_id(line)),
        }
    }

    /// Traffic counters plus a snapshot of every session.
    pub fn summary(&self) -> ServeSummary {
        self.server.summary()
    }
}

/// Render a caught panic payload (`&str` and `String` are what `panic!`
/// produces) for the typed [`SelectError::ClientPanic`] reply.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// StdioServer — the stdio front over the shared core
// ---------------------------------------------------------------------------

/// The stdio front: a [`WireCore`] pumped by a blocking line loop over any
/// `BufRead`/`Write` pair — `dash serve --stdio` wires it to stdin/stdout.
/// Dereferences to its [`WireCore`], so the protocol tests (and embedders)
/// drive `handle`/`line` and read the counters directly; the socket front
/// ([`NetServer`](crate::coordinator::net::NetServer)) serves the very
/// same core over connections instead, keeping the two transports one
/// code path.
pub struct StdioServer {
    core: WireCore,
}

impl StdioServer {
    pub fn new(leader: Leader) -> StdioServer {
        StdioServer { core: WireCore::new(leader) }
    }

    /// See [`WireCore::with_max_sessions`].
    pub fn with_max_sessions(mut self, max_sessions: usize) -> StdioServer {
        self.core = self.core.with_max_sessions(max_sessions);
        self
    }

    /// See [`WireCore::with_store`].
    pub fn with_store(mut self, store: SessionStore) -> StdioServer {
        self.core = self.core.with_store(store);
        self
    }

    /// See [`WireCore::with_tenant_quota`].
    pub fn with_tenant_quota(mut self, max_per_tenant: usize) -> StdioServer {
        self.core = self.core.with_tenant_quota(max_per_tenant);
        self
    }

    /// See [`WireCore::with_fault_ops`].
    pub fn with_fault_ops(mut self, fault_ops: bool) -> StdioServer {
        self.core = self.core.with_fault_ops(fault_ops);
        self
    }

    /// Unwrap into the transport-agnostic core (the socket front serves
    /// it from there).
    pub fn into_core(self) -> WireCore {
        self.core
    }

    /// The transport loop: one reply line per non-blank request line,
    /// flushed as produced, until EOF or a graceful drain (the `shutdown`
    /// op answers `stopping`, persists every evictable lane, and ends the
    /// loop). A client that closes its read end early (broken pipe) is a
    /// routine disconnect, not a transport error. Returns the serving
    /// summary.
    pub fn run<R, W>(mut self, input: R, out: &mut W) -> std::io::Result<ServeSummary>
    where
        R: std::io::BufRead,
        W: std::io::Write,
    {
        for line in input.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = self.core.line(&line);
            if let Err(e) = writeln!(out, "{reply}").and_then(|_| out.flush()) {
                if e.kind() == std::io::ErrorKind::BrokenPipe {
                    break;
                }
                return Err(e);
            }
            if self.core.draining() {
                break;
            }
        }
        Ok(self.core.summary())
    }
}

impl std::ops::Deref for StdioServer {
    type Target = WireCore;
    fn deref(&self) -> &WireCore {
        &self.core
    }
}

impl std::ops::DerefMut for StdioServer {
    fn deref_mut(&mut self) -> &mut WireCore {
        &mut self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::api::PlanKind;
    use crate::coordinator::leader::AlgorithmChoice;

    #[test]
    fn request_frames_round_trip() {
        let reqs = vec![
            ApiRequest::Open {
                problem: WireProblem::new("d1", 8, 3),
                plan: WirePlan::new("greedy"),
                driven: true,
                tenant: None,
                session: None,
            },
            ApiRequest::Open {
                problem: WireProblem::new("d1", 8, 3),
                plan: WirePlan::new("greedy"),
                driven: false,
                tenant: Some("acme".into()),
                session: None,
            },
            ApiRequest::Open {
                problem: WireProblem::new("d1", 8, 3),
                plan: WirePlan::new("greedy"),
                driven: false,
                tenant: None,
                session: Some(7),
            },
            ApiRequest::List,
            ApiRequest::Sweep { session: 0, candidates: vec![0, 2, 5] },
            ApiRequest::Insert { session: 1, item: 3, if_generation: Some(2) },
            ApiRequest::Insert { session: 1, item: 3, if_generation: None },
            ApiRequest::Step { session: 0 },
            ApiRequest::Finish { session: 0 },
            ApiRequest::Metrics { session: 2 },
            ApiRequest::Close { session: 1 },
        ];
        for (i, req) in reqs.into_iter().enumerate() {
            let line = req.encode(i as u64);
            assert!(!line.contains('\n'));
            let (id, back) = ApiRequest::decode(&line).unwrap();
            assert_eq!(id, i as u64);
            assert_eq!(back, req);
        }
    }

    #[test]
    fn oversized_ids_clamp_to_the_faithful_range() {
        let line = ApiRequest::List.encode(u64::MAX);
        let (id, _) = ApiRequest::decode(&line).unwrap();
        assert_eq!(id, MAX_WIRE_INT);
        let line = ApiReply::Opened { session: 0 }.encode(u64::MAX);
        let (id, _) = ApiReply::decode(&line).unwrap();
        assert_eq!(id, MAX_WIRE_INT);
    }

    #[test]
    fn malformed_frames_are_protocol_errors() {
        for line in [
            "not json",
            "{}",
            r#"{"v":2,"op":"list"}"#,
            r#"{"v":1,"op":"warp"}"#,
            r#"{"v":1,"op":"sweep","session":0}"#,
            r#"{"v":1,"op":"sweep","session":0,"candidates":[1.5]}"#,
            r#"{"v":1,"op":"insert","session":0}"#,
            r#"{"v":1,"op":"open","problem":{"k":3},"plan":{"algo":"dash"}}"#,
        ] {
            match ApiRequest::decode(line) {
                Err(SelectError::Protocol(_)) => {}
                other => panic!("{line}: expected protocol error, got {other:?}"),
            }
        }
    }

    #[test]
    fn every_error_kind_round_trips() {
        let errors = vec![
            SelectError::InvalidSpec("k must be >= 1".into()),
            SelectError::UnknownSession(9),
            SelectError::StaleGeneration { pinned: 3, actual: 4 },
            SelectError::Backpressure("session budget exhausted".into()),
            SelectError::Backend("artifacts not built".into()),
            SelectError::Rejected("driver-owned".into()),
            SelectError::ClientPanic("assertion failed: left == right".into()),
            SelectError::Disconnected,
            SelectError::Protocol("bad frame".into()),
        ];
        for e in errors {
            let reply = ApiReply::Error { error: e.clone() };
            let line = reply.encode(7);
            let (id, back) = ApiReply::decode(&line).unwrap();
            assert_eq!(id, 7);
            assert_eq!(back, reply, "{e:?}");
        }
    }

    #[test]
    fn wire_plan_resolves_every_algorithm_name() {
        for kind in PlanKind::all() {
            let plan = WirePlan::new(kind.name()).resolve().unwrap();
            assert_eq!(plan.kind(), *kind);
        }
        assert!(WirePlan::new("nope").resolve().is_err());
    }

    #[test]
    fn wire_plan_resolves_extended_knobs() {
        // every PlanBuilder knob is reachable over the wire
        let mut p = WirePlan::new("greedy");
        p.min_gain = Some(0.25);
        match p.resolve().unwrap().algorithm_for(3) {
            AlgorithmChoice::Greedy(c) => assert!((c.min_gain - 0.25).abs() < 1e-12),
            other => panic!("unexpected {other:?}"),
        }
        let mut p = WirePlan::new("dash");
        p.opt = Some(7.5);
        match p.resolve().unwrap().algorithm_for(3) {
            AlgorithmChoice::Dash(c) => assert_eq!(c.opt, OptEstimate::Known(7.5)),
            other => panic!("unexpected {other:?}"),
        }
        let mut p = WirePlan::new("lasso");
        p.path_len = Some(10);
        p.tol = Some(1e-5);
        match p.resolve().unwrap().algorithm_for(3) {
            AlgorithmChoice::Lasso(c) => {
                assert_eq!(c.path_len, 10);
                assert!((c.tol - 1e-5).abs() < 1e-18);
                assert_eq!(c.max_iters, LassoConfig::default().max_iters);
            }
            other => panic!("unexpected {other:?}"),
        }
        // wire-supplied knobs go through the same validation as builders
        let mut p = WirePlan::new("dash");
        p.opt = Some(-1.0);
        assert!(matches!(p.resolve().unwrap_err(), SelectError::InvalidSpec(_)));
    }

    #[test]
    fn priors_without_objective_resolve_or_reject() {
        // design dataset: priors flow into the default aopt objective
        let mut p = WireProblem::new("d1-design", 5, 1);
        p.beta_sq = Some(2.5);
        p.sigma_sq = Some(0.5);
        match p.resolve().unwrap().objective {
            ObjectiveChoice::Aopt { beta_sq, sigma_sq } => {
                assert!((beta_sq - 2.5).abs() < 1e-12);
                assert!((sigma_sq - 0.5).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        // non-design dataset: priors without "objective":"aopt" are an
        // error, never silently dropped
        let mut p = WireProblem::new("d1", 5, 1);
        p.beta_sq = Some(2.0);
        assert!(matches!(p.resolve().unwrap_err(), SelectError::InvalidSpec(_)));
        // ...and priors alongside an explicit non-aopt objective likewise
        let mut p = WireProblem::new("d1", 5, 1);
        p.objective = Some("lreg".into());
        p.sigma_sq = Some(0.5);
        let e = p.resolve().unwrap_err();
        assert!(e.to_string().contains("aopt"), "{e}");
    }

    #[test]
    fn repeated_opens_share_one_dataset_build() {
        let mut cache = DatasetCache::new();
        let p = WireProblem::new("d1", 5, 1);
        let a = p.resolve_cached(&mut cache).unwrap();
        let b = p.resolve_cached(&mut cache).unwrap();
        assert_eq!(cache.len(), 1, "one build serves identical opens");
        assert!(Arc::ptr_eq(&a.dataset, &b.dataset));
        // a different seed is a different dataset
        let c = WireProblem::new("d1", 5, 2).resolve_cached(&mut cache).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(!Arc::ptr_eq(&a.dataset, &c.dataset));
    }

    #[test]
    fn driven_open_without_driver_rejects_cheaply() {
        let mut server = StdioServer::new(Leader::with_threads(1));
        let err = server
            .open_spec(&WireProblem::new("d1", 5, 1), &WirePlan::new("lasso"), true, None, None)
            .unwrap_err();
        assert!(err.to_string().contains("no stepwise driver"), "{err}");
        assert_eq!(server.summary().sessions.len(), 0);
    }

    #[test]
    fn close_frees_the_budget_so_churn_never_wedges() {
        let mut server = StdioServer::new(Leader::with_threads(1)).with_max_sessions(2);
        let problem = WireProblem::new("d1", 4, 1);
        let plan = WirePlan::new("greedy");
        let a = server.open_spec(&problem, &plan, false, None, None).unwrap();
        let b = server.open_spec(&problem, &plan, false, None, None).unwrap();
        assert_eq!((a, b), (0, 1));
        // budget full, no store: the third open is typed backpressure
        let err = server.open_spec(&problem, &plan, false, None, None).unwrap_err();
        assert!(matches!(err, SelectError::Backpressure(_)), "{err:?}");
        // churn open/close under the full budget: live count stays flat
        // and closed ids are recycled, so this can run forever
        for _ in 0..10 {
            match server.handle(ApiRequest::Close { session: a }).unwrap() {
                ApiReply::Closed { session } => assert_eq!(session, a),
                other => panic!("unexpected {other:?}"),
            }
            assert_eq!(server.live_sessions(), 1);
            let reopened = server.open_spec(&problem, &plan, false, None, None).unwrap();
            assert_eq!(reopened, a, "closed ids are recycled fd-style");
            assert_eq!(server.live_sessions(), 2);
        }
        // closed twice is UnknownSession, as is any later request to it
        server.close_session(b).unwrap();
        assert!(matches!(
            server.close_session(b).unwrap_err(),
            SelectError::UnknownSession(s) if s == b
        ));
        assert!(matches!(
            server.handle(ApiRequest::Metrics { session: b }).unwrap_err(),
            SelectError::UnknownSession(s) if s == b
        ));
    }

    #[test]
    fn tenant_quotas_reject_typed_not_panic() {
        let mut server = StdioServer::new(Leader::with_threads(1)).with_tenant_quota(2);
        let problem = WireProblem::new("d1", 4, 1);
        let plan = WirePlan::new("greedy");
        let a = server.open_spec(&problem, &plan, false, Some("acme"), None).unwrap();
        server.open_spec(&problem, &plan, false, Some("acme"), None).unwrap();
        // third session for the same tenant: typed rejection
        let err = server.open_spec(&problem, &plan, false, Some("acme"), None).unwrap_err();
        assert!(matches!(err, SelectError::Rejected(_)), "{err:?}");
        assert!(err.to_string().contains("acme"), "{err}");
        // other tenants (and the default bucket) are unaffected
        server.open_spec(&problem, &plan, false, Some("zen"), None).unwrap();
        server.open_spec(&problem, &plan, false, None, None).unwrap();
        // closing frees the tenant's claim
        server.close_session(a).unwrap();
        server.open_spec(&problem, &plan, false, Some("acme"), None).unwrap();
        // list reports each lane's tenant
        match server.handle(ApiRequest::List).unwrap() {
            ApiReply::Sessions { sessions } => {
                assert_eq!(sessions.len(), 4);
                assert_eq!(
                    sessions.iter().filter(|s| s.tenant == "acme").count(),
                    2,
                    "{sessions:?}"
                );
                assert!(sessions.iter().all(|s| s.resident));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn over_budget_opens_evict_lru_and_requests_restore() {
        let dir = std::env::temp_dir()
            .join(format!("dash-wire-evict-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SessionStore::open(&dir).unwrap();
        let mut server = StdioServer::new(Leader::with_threads(1))
            .with_max_sessions(2)
            .with_store(store);
        let problem = WireProblem::new("d1", 4, 1);
        let plan = WirePlan::new("greedy");
        let a = server.open_spec(&problem, &plan, false, None, None).unwrap();
        let b = server.open_spec(&problem, &plan, false, None, None).unwrap();
        // grow session a so its restored state is distinguishable
        let (grew, generation) = match server
            .handle(ApiRequest::Insert { session: a, item: 3, if_generation: None })
            .unwrap()
        {
            ApiReply::Inserted { grew, generation } => (grew, generation),
            other => panic!("unexpected {other:?}"),
        };
        assert!(grew);
        // touch b last so a... no: a was touched by the insert, so b is
        // the LRU victim for the next over-budget open
        let c = server.open_spec(&problem, &plan, false, None, None).unwrap();
        assert_eq!(server.evictions, 1);
        assert_eq!(server.live_sessions(), 2);
        assert!(server.store().unwrap().contains(b), "victim persisted");
        match server.handle(ApiRequest::List).unwrap() {
            ApiReply::Sessions { sessions } => {
                let row = |id: usize| sessions.iter().find(|s| s.session == id).unwrap().clone();
                assert!(row(a).resident);
                assert!(!row(b).resident, "{sessions:?}");
                assert!(row(c).resident);
            }
            other => panic!("unexpected {other:?}"),
        }
        // a request addressed to the evicted session restores it (and
        // evicts another victim to make room); its state replays exactly
        match server.handle(ApiRequest::Metrics { session: b }).unwrap() {
            ApiReply::Snapshot { snapshot } => assert_eq!(snapshot.set, Vec::<usize>::new()),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(server.restores, 1);
        assert_eq!(server.evictions, 2);
        // the restored session keeps its id and serves writes
        match server.handle(ApiRequest::Metrics { session: a }).unwrap() {
            ApiReply::Snapshot { snapshot } => {
                assert_eq!(snapshot.set, vec![3]);
                assert_eq!(snapshot.generation.0, generation);
            }
            other => panic!("unexpected {other:?}"),
        }
        // close removes the store record for evicted sessions too
        match server.handle(ApiRequest::List).unwrap() {
            ApiReply::Sessions { sessions } => {
                let evicted: Vec<usize> = sessions
                    .iter()
                    .filter(|s| !s.resident)
                    .map(|s| s.session)
                    .collect();
                assert_eq!(evicted.len(), 1);
                assert!(server.store().unwrap().contains(evicted[0]));
                server.close_session(evicted[0]).unwrap();
                assert!(!server.store().unwrap().contains(evicted[0]));
            }
            other => panic!("unexpected {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinned_lanes_backpressure_instead_of_evicting() {
        use crate::data::synthetic;
        use crate::objectives::LinearRegressionObjective;
        use crate::rng::Pcg64;
        let dir = std::env::temp_dir()
            .join(format!("dash-wire-pinned-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut server = StdioServer::new(Leader::with_threads(1))
            .with_max_sessions(1)
            .with_store(SessionStore::open(&dir).unwrap());
        // an embedded lane has no wire specs to restore from, so a
        // further open cannot evict it: typed backpressure, not a panic
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 40, 12, 6, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        server.open_objective(Box::new(obj), None, 0, "lreg").unwrap();
        let err = server
            .open_spec(&WireProblem::new("d1", 4, 1), &WirePlan::new("greedy"), false, None, None)
            .unwrap_err();
        assert!(matches!(err, SelectError::Backpressure(_)), "{err:?}");
        assert!(err.to_string().contains("pinned"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wire_problem_rejects_unknowns() {
        assert!(WireProblem::new("d99", 5, 1).resolve().is_err());
        let mut p = WireProblem::new("d1", 5, 1);
        p.scale = Some("galactic".into());
        assert!(p.resolve().is_err());
        let mut p = WireProblem::new("d1", 5, 1);
        p.objective = Some("entropy".into());
        assert!(p.resolve().is_err());
        let mut p = WireProblem::new("d1", 5, 1);
        p.backend = Some("tpu".into());
        assert!(p.resolve().is_err());
    }

    #[test]
    fn ping_answers_pong_with_no_side_effects() {
        let mut core = WireCore::new(Leader::with_threads(1));
        assert!(matches!(core.handle(ApiRequest::Ping).unwrap(), ApiReply::Pong));
        assert_eq!(core.live_sessions(), 0);
        let line = core.line(&ApiRequest::Ping.encode(9));
        assert_eq!(line, ApiReply::Pong.encode(9));
    }

    #[test]
    fn crash_op_is_gated_and_contained() {
        // production default: the op is refused, nothing panics
        let mut core = WireCore::new(Leader::with_threads(1));
        let err = core.handle(ApiRequest::Crash { message: "boom".into() }).unwrap_err();
        assert!(matches!(err, SelectError::Rejected(_)), "{err:?}");
        assert_eq!(core.contained_panics, 0);

        // fault-ops front: the injected panic is contained to a typed
        // client_panic reply and the core keeps serving
        let mut core = WireCore::new(Leader::with_threads(1)).with_fault_ops(true);
        let a = core
            .open_spec(&WireProblem::new("d1", 4, 1), &WirePlan::new("greedy"), false, None, None)
            .unwrap();
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // keep the injected panic quiet
        let line = core.line(&ApiRequest::Crash { message: "boom".into() }.encode(3));
        std::panic::set_hook(hook);
        let (id, reply) = ApiReply::decode(&line).unwrap();
        assert_eq!(id, 3);
        match reply {
            ApiReply::Error { error: SelectError::ClientPanic(m) } => {
                assert!(m.contains("boom"), "{m}")
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(core.contained_panics, 1);
        // the lane opened before the contained panic still serves
        match core.handle(ApiRequest::Metrics { session: a }).unwrap() {
            ApiReply::Snapshot { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn shutdown_drains_evictable_lanes_and_ends_the_stdio_loop() {
        let dir = std::env::temp_dir()
            .join(format!("dash-wire-drain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut server = StdioServer::new(Leader::with_threads(1))
            .with_store(SessionStore::open(&dir).unwrap());
        let a = server
            .open_spec(&WireProblem::new("d1", 4, 1), &WirePlan::new("greedy"), false, None, None)
            .unwrap();
        server.handle(ApiRequest::Insert { session: a, item: 2, if_generation: None }).unwrap();
        let want = match server.handle(ApiRequest::Metrics { session: a }).unwrap() {
            ApiReply::Snapshot { snapshot } => snapshot,
            other => panic!("unexpected {other:?}"),
        };
        // a shutdown frame persists the lane, answers stopping, and ends
        // the loop — frames queued after it are never consumed
        let input = format!(
            "{}\n{}\n",
            ApiRequest::Shutdown.encode(1),
            ApiRequest::Metrics { session: a }.encode(2)
        );
        let mut out = Vec::new();
        let _ = server.run(input.as_bytes(), &mut out).unwrap();
        let out = String::from_utf8(out).unwrap();
        let mut lines = out.lines();
        let (id, reply) = ApiReply::decode(lines.next().unwrap()).unwrap();
        assert_eq!(id, 1);
        assert_eq!(reply, ApiReply::Stopping { persisted: 1 });
        assert!(lines.next().is_none(), "the loop must stop at the drain");

        // a fresh core on the same store adopts the drained session with
        // identical list metadata and byte-identical restored state
        let mut core = WireCore::new(Leader::with_threads(1))
            .with_store(SessionStore::open(&dir).unwrap());
        match core.handle(ApiRequest::List).unwrap() {
            ApiReply::Sessions { sessions } => {
                assert_eq!(sessions.len(), 1);
                assert_eq!(sessions[0].session, a);
                assert!(!sessions[0].resident);
                assert_eq!(sessions[0].set_len, 1);
                assert_eq!(sessions[0].generation, want.generation.0);
                assert!(!sessions[0].driven);
            }
            other => panic!("unexpected {other:?}"),
        }
        match core.handle(ApiRequest::Metrics { session: a }).unwrap() {
            ApiReply::Snapshot { snapshot } => {
                assert_eq!(snapshot.set, want.set);
                assert_eq!(snapshot.generation, want.generation);
                assert_eq!(snapshot.value.to_bits(), want.value.to_bits());
            }
            other => panic!("unexpected {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_through_persistence_survives_a_hard_kill() {
        // a hard kill never runs drain; adoption must work from the
        // write-through records alone (lane durable from birth and after
        // every mutating op)
        let dir = std::env::temp_dir()
            .join(format!("dash-wire-writethrough-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut core = WireCore::new(Leader::with_threads(1))
            .with_store(SessionStore::open(&dir).unwrap());
        let a = core
            .open_spec(&WireProblem::new("d1", 4, 1), &WirePlan::new("greedy"), false, None, None)
            .unwrap();
        assert!(core.store().unwrap().contains(a), "durable from birth");
        core.handle(ApiRequest::Insert { session: a, item: 5, if_generation: None }).unwrap();
        drop(core); // the "kill": no drain, no eviction

        let mut core = WireCore::new(Leader::with_threads(1))
            .with_store(SessionStore::open(&dir).unwrap());
        match core.handle(ApiRequest::Metrics { session: a }).unwrap() {
            ApiReply::Snapshot { snapshot } => assert_eq!(snapshot.set, vec![5]),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(core.restores, 1);
        // adopted ids are reserved: a new open takes the next free id
        let b = core
            .open_spec(&WireProblem::new("d1", 4, 1), &WirePlan::new("greedy"), false, None, None)
            .unwrap();
        assert_ne!(a, b);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
