//! Concurrent serving front for selection sessions: many clients, few
//! pooled oracle rounds.
//!
//! The paper's framework wins by turning polynomially many independent
//! queries into a handful of adaptive rounds; this module applies the same
//! discipline to *request traffic*. A [`SessionServer`] owns a set of
//! [`SelectionSession`]s (each optionally driven by a stepwise
//! [`SessionDriver`]); clients hold cloneable, thread-safe
//! [`SessionClient`] handles (std `mpsc` channels, mirroring
//! `runtime/client.rs` — tokio is unavailable offline) and submit
//! [`ServeRequest`]s: `Sweep`, `Insert`, `Step`, `Finish`, `Metrics`.
//!
//! # The serving loop
//!
//! The server is a single-owner actor. Its loop drains everything queued
//! since the previous turn and services the batch as one **turn** with a
//! fixed two-phase order:
//!
//! 1. **reads, coalesced** — all `Sweep` requests for one session are
//!    merged into a single candidate union (ascending, deduped) and served
//!    by **one** pooled [`BatchExecutor`] round through the session's
//!    generation cache; each requester gets its own candidates' gains
//!    sliced out of the round. `Metrics` reads are answered from the same
//!    pre-write state.
//! 2. **writes, in arrival order** — `Insert`, `Step`, and `Finish`
//!    requests are applied in the deterministic total order of arrival.
//!
//! # The generation contract, served
//!
//! Every sweep reply is **generation-stamped**: it carries the generation
//! its gains were computed at, so a reply raced by a concurrent `insert`
//! is impossible to observe stale — the stamp tells the client exactly
//! which solution set the gains describe. Because reads precede writes
//! inside a turn, and a client blocks on each reply before submitting its
//! next request, a client always observes its own inserts ("read your
//! writes"): its later sweeps are served at a generation ≥ the one its
//! insert reply reported. Stale-generation *cache* hits remain impossible
//! by the session contract ([`SelectionSession::insert`] bumps the
//! generation); `tests/serve_interleave.rs` replays hundreds of seeded
//! client interleavings against the deterministic core and checks every
//! reply bitwise.
//!
//! # Driver-owned lanes
//!
//! A lane opened with a driver belongs to that driver until it is
//! finished: clients may `Step`, `Finish` (only once the driver has
//! stepped to `Done`), and read `Metrics`, but raw `Sweep`/`Insert`
//! traffic is rejected — client cache warming or set growth would
//! silently break the documented byte-identical-to-solo determinism of
//! the driven run. Once finished, the lane's final state is frozen:
//! `Sweep` becomes a legal read-only observation, `Insert` stays
//! rejected.
//!
//! # Backpressure
//!
//! Clients talk to the loop over a **bounded** queue
//! ([`ServeConfig::queue_bound`]): when the server lags, `submit` blocks
//! the client instead of growing an unbounded backlog. Replies travel
//! over per-request unbounded channels, so the server itself never
//! blocks on a slow client.
//!
//! # Determinism
//!
//! Given the order requests enter the queue and the turn boundaries, the
//! serving outcome is a pure function: the same schedule replays to the
//! same replies, bit for bit. The threaded loop ([`SessionServer::run`])
//! only decides *which* schedule happens; the deterministic core
//! ([`SessionServer::submit`] + [`SessionServer::turn`]) is what the
//! concurrency harness drives directly.

use crate::algorithms::SelectionResult;
use crate::coordinator::api::SelectError;
use crate::coordinator::session::{
    ObjectiveHandle, SelectionSession, SessionDriver, SessionSnapshot, StepOutcome,
};
use crate::coordinator::wire::{ApiReply, ApiRequest};
use crate::objectives::Objective;
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::Arc;

/// Index of one session inside a [`SessionServer`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId(pub usize);

/// A client request against one served session.
#[derive(Debug, Clone)]
pub enum ServeRequest {
    /// Marginal gains for these candidates at the session's current
    /// generation (coalesced with concurrent sweeps of the same session).
    Sweep { candidates: Vec<usize> },
    /// Grow the session's solution set: `S ← S ∪ {item}`. When
    /// `if_generation` is set, the insert applies only while the session
    /// is still at that generation; otherwise it is answered with
    /// [`SelectError::StaleGeneration`] — optimistic concurrency for
    /// clients racing other writers.
    Insert { item: usize, if_generation: Option<u64> },
    /// Advance the session's attached driver by one adaptive round.
    Step,
    /// Finalize the attached driver into a [`SelectionResult`]. Rejected
    /// until the driver has stepped to `Done` (some drivers cannot
    /// finalize mid-run); idempotent afterwards — repeated finishes
    /// return the same result.
    Finish,
    /// Point-in-time [`SessionSnapshot`] of the session.
    Metrics,
    /// Close the session: the lane (session state, driver, and the lane's
    /// share of the objective) is dropped and its slot freed for reuse.
    /// Later requests against the id are [`SelectError::UnknownSession`].
    Close,
}

/// Reply to one [`ServeRequest`].
#[derive(Debug, Clone)]
pub enum ServeReply {
    /// Gains in the request's candidate order, stamped with the generation
    /// they were computed at; `round_fresh` is the number of oracle
    /// queries the whole coalesced round issued (0 = served from cache).
    Sweep { gains: Vec<f64>, generation: u64, round_fresh: usize },
    /// Whether the set grew, and the generation after the insert.
    Insert { grew: bool, generation: u64 },
    /// Whether the driver has terminated, and the generation after the
    /// step.
    Step { done: bool, generation: u64 },
    Finish { result: SelectionResult },
    Metrics { snapshot: SessionSnapshot },
    /// The session was closed and its slot freed.
    Closed { session: usize },
}

/// One queued request plus its reply slot. Serving failures are the
/// unified [`SelectError`] (`Rejected`, `UnknownSession`,
/// `StaleGeneration`, `Disconnected`, …): rejection is per-request — the
/// session and every other client keep serving.
pub struct Envelope {
    session: SessionId,
    req: ServeRequest,
    reply: Sender<Result<ServeReply, SelectError>>,
}

impl Envelope {
    /// Build a request envelope and the receiver its reply will arrive on.
    pub fn new(
        session: SessionId,
        req: ServeRequest,
    ) -> (Envelope, Receiver<Result<ServeReply, SelectError>>) {
        let (reply, rx) = channel();
        (Envelope { session, req, reply }, rx)
    }
}

/// Server-side traffic counters (single-writer: the serving loop).
#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    /// requests accepted into the queue
    pub requests: usize,
    /// individual `Sweep` requests received
    pub sweep_requests: usize,
    /// pooled sweep rounds actually issued (one per session with sweep
    /// traffic per turn) — coalescing makes this ≤ `sweep_requests`
    pub coalesced_rounds: usize,
    /// total union candidates covered by those rounds
    pub coalesced_candidates: usize,
    /// `Insert` requests applied
    pub inserts: usize,
    /// `Step` requests applied
    pub steps: usize,
    /// `Finish` requests answered
    pub finishes: usize,
    /// `Metrics` requests answered
    pub metrics_reads: usize,
    /// `Close` requests applied (lanes dropped, slots freed)
    pub closes: usize,
    /// requests answered with [`SelectError::Rejected`]
    pub rejected: usize,
    /// serving turns (batches drained)
    pub turns: usize,
}

/// End-of-serve report: traffic counters plus one snapshot per session.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub metrics: ServeMetrics,
    pub sessions: Vec<SessionSnapshot>,
}

/// Bounded-queue serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Depth of the client→server request queue. Submissions block once
    /// this many requests are in flight (backpressure), so a burst of
    /// clients cannot grow an unbounded backlog.
    pub queue_bound: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { queue_bound: 256 }
    }
}

struct Lane<'o> {
    session: SelectionSession<'o>,
    driver: Option<Box<dyn SessionDriver>>,
    rng: Pcg64,
    /// the driver reported [`StepOutcome::Done`]; gates `Finish` (some
    /// drivers cannot finalize mid-run)
    done: bool,
    /// set by the first `Finish`; later finishes replay it
    result: Option<SelectionResult>,
}

/// The serving actor: owns every lane (session + optional driver + rng)
/// and services queued requests in deterministic turns. See the module
/// docs for the two-phase turn order and the generation contract.
///
/// Lanes live in slots: [`SessionServer::close`] (or a
/// [`ServeRequest::Close`]) drops a lane — including its share of the
/// objective, for lanes opened through the `Arc`-owning constructors —
/// and pushes the slot onto a free list, so an open/close churn reuses
/// slots instead of growing the lane table. Slot ids are therefore
/// reused after close, like file descriptors.
#[derive(Default)]
pub struct SessionServer<'o> {
    lanes: Vec<Option<Lane<'o>>>,
    free: Vec<usize>,
    pending: Vec<Envelope>,
    pub metrics: ServeMetrics,
}

impl<'o> SessionServer<'o> {
    pub fn new() -> Self {
        SessionServer {
            lanes: Vec::new(),
            free: Vec::new(),
            pending: Vec::new(),
            metrics: ServeMetrics::default(),
        }
    }

    /// Open an ad-hoc session (raw sweep/insert traffic, no driver).
    pub fn open(&mut self, obj: &'o dyn Objective, exec: BatchExecutor) -> SessionId {
        self.open_lane(ObjectiveHandle::Borrowed(obj), exec, None, 0)
    }

    /// Open a session with an attached stepwise driver; `Step` requests
    /// advance it (rng seeded from `seed`, exactly as a solo `drive()`
    /// with `Pcg64::seed_from(seed)` would be).
    pub fn open_driven(
        &mut self,
        obj: &'o dyn Objective,
        exec: BatchExecutor,
        driver: Box<dyn SessionDriver>,
        seed: u64,
    ) -> SessionId {
        self.open_lane(ObjectiveHandle::Borrowed(obj), exec, Some(driver), seed)
    }

    /// Open an ad-hoc session that co-owns its objective: the `Arc` is
    /// dropped with the lane on [`SessionServer::close`]. This is the wire
    /// front's open path — no borrow ties the lane to a caller scope, so
    /// lanes can come and go for the life of the server.
    pub fn open_shared(&mut self, obj: Arc<dyn Objective>, exec: BatchExecutor) -> SessionId {
        self.open_lane(ObjectiveHandle::Shared(obj), exec, None, 0)
    }

    /// [`SessionServer::open_driven`] with a co-owned objective.
    pub fn open_driven_shared(
        &mut self,
        obj: Arc<dyn Objective>,
        exec: BatchExecutor,
        driver: Box<dyn SessionDriver>,
        seed: u64,
    ) -> SessionId {
        self.open_lane(ObjectiveHandle::Shared(obj), exec, Some(driver), seed)
    }

    /// Reopen a lane from a persisted snapshot: the session state is
    /// rebuilt by replaying the snapshot's set (byte-identical by the
    /// insertion-order contract, see [`SelectionSession::restore`]), and a
    /// persisted final result — for a driven lane that finished before it
    /// was evicted — freezes the lane exactly as a served `Finish` would
    /// have left it.
    pub fn open_restored(
        &mut self,
        obj: ObjectiveHandle<'o>,
        exec: BatchExecutor,
        snapshot: &SessionSnapshot,
        result: Option<SelectionResult>,
    ) -> Result<SessionId, SelectError> {
        let session = SelectionSession::restore(obj, exec, snapshot)?;
        let done = result.is_some();
        Ok(self.install(Lane { session, driver: None, rng: Pcg64::seed_from(0), done, result }))
    }

    fn open_lane(
        &mut self,
        obj: ObjectiveHandle<'o>,
        exec: BatchExecutor,
        driver: Option<Box<dyn SessionDriver>>,
        seed: u64,
    ) -> SessionId {
        self.install(Lane {
            session: SelectionSession::with_handle(obj, exec),
            driver,
            rng: Pcg64::seed_from(seed),
            done: false,
            result: None,
        })
    }

    fn install(&mut self, lane: Lane<'o>) -> SessionId {
        match self.free.pop() {
            Some(slot) => {
                self.lanes[slot] = Some(lane);
                SessionId(slot)
            }
            None => {
                self.lanes.push(Some(lane));
                SessionId(self.lanes.len() - 1)
            }
        }
    }

    /// Close a session now: drop the lane and free its slot. The serving
    /// equivalent is a [`ServeRequest::Close`], which applies in the write
    /// phase of a turn; this direct form is for single-owner callers (the
    /// wire front) that sequence requests themselves.
    pub fn close(&mut self, id: SessionId) -> Result<(), SelectError> {
        self.close_slot(id).map(|_| ())
    }

    fn close_slot(&mut self, id: SessionId) -> Result<ServeReply, SelectError> {
        let slot = self.lanes.get_mut(id.0).ok_or(SelectError::UnknownSession(id.0))?;
        if slot.take().is_none() {
            return Err(SelectError::UnknownSession(id.0));
        }
        self.free.push(id.0);
        self.metrics.closes += 1;
        Ok(ServeReply::Closed { session: id.0 })
    }

    /// Number of live (open, un-closed) sessions.
    pub fn sessions(&self) -> usize {
        self.lanes.iter().flatten().count()
    }

    /// Read access to one served session (assertions, snapshots); `None`
    /// for unknown or closed ids.
    pub fn session(&self, id: SessionId) -> Option<&SelectionSession<'o>> {
        self.lanes.get(id.0).and_then(|l| l.as_ref()).map(|l| &l.session)
    }

    /// Requests queued for the next turn.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Whether the lane's driver has been finalized (`None` for an unknown
    /// or closed session) — the wire front's `list` op reads this.
    pub fn finished(&self, id: SessionId) -> Option<bool> {
        self.lanes.get(id.0).and_then(|l| l.as_ref()).map(|l| l.result.is_some())
    }

    /// The lane's finalized result, if its driver has finished — what the
    /// wire front persists when it evicts a finished driven lane.
    pub fn result(&self, id: SessionId) -> Option<&SelectionResult> {
        self.lanes.get(id.0).and_then(|l| l.as_ref()).and_then(|l| l.result.as_ref())
    }

    /// Queue a request, returning the receiver its reply arrives on after
    /// the next [`SessionServer::turn`]. This is the deterministic-core
    /// entry the concurrency harness drives directly.
    pub fn submit(
        &mut self,
        session: SessionId,
        req: ServeRequest,
    ) -> Receiver<Result<ServeReply, SelectError>> {
        let (env, rx) = Envelope::new(session, req);
        self.enqueue(env);
        rx
    }

    /// Queue an already-built envelope (the transport loop's entry).
    pub fn enqueue(&mut self, env: Envelope) {
        self.metrics.requests += 1;
        self.pending.push(env);
    }

    /// Service every pending request as one turn: coalesced reads first,
    /// then writes in arrival order. No-op when nothing is pending.
    pub fn turn(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.metrics.turns += 1;
        let batch = std::mem::take(&mut self.pending);

        // partition: reads grouped per lane (coalescing unit), writes in
        // arrival order; unknown (or already-closed) sessions rejected
        // immediately
        let mut reads: Vec<Vec<Envelope>> = (0..self.lanes.len()).map(|_| Vec::new()).collect();
        let mut writes: Vec<Envelope> = Vec::new();
        for env in batch {
            if self.lanes.get(env.session.0).map_or(true, |l| l.is_none()) {
                self.metrics.rejected += 1;
                let _ = env.reply.send(Err(SelectError::UnknownSession(env.session.0)));
                continue;
            }
            match env.req {
                ServeRequest::Sweep { .. } | ServeRequest::Metrics => reads[env.session.0].push(env),
                _ => writes.push(env),
            }
        }

        // phase A — reads. All of a lane's sweep requests are served by ONE
        // pooled round over the union of their candidates, every reply
        // stamped with the turn-entry generation.
        for (lane_idx, lane_reads) in reads.into_iter().enumerate() {
            if lane_reads.is_empty() {
                continue;
            }
            // validate first: an out-of-range candidate is a rejected
            // request, never a panic inside the objective state that would
            // tear down every other client's session; empty sweeps are
            // answered directly so no-op requests cannot pollute the
            // round/coalescing accounting; sweeps on a still-running
            // driven lane are rejected — client cache traffic would
            // silently perturb the driver's byte-identical-to-solo run
            // the slot is still live here: closes are writes, and writes
            // apply after the read phase
            let (n, generation, driver_owned) = match self.lanes[lane_idx].as_ref() {
                Some(lane) => (
                    lane.session.objective().n(),
                    lane.session.generation().0,
                    lane.driver.is_some(),
                ),
                None => {
                    for env in lane_reads {
                        self.metrics.rejected += 1;
                        let _ = env.reply.send(Err(SelectError::UnknownSession(lane_idx)));
                    }
                    continue;
                }
            };
            let mut valid: Vec<Envelope> = Vec::with_capacity(lane_reads.len());
            for env in lane_reads {
                if let ServeRequest::Sweep { candidates } = &env.req {
                    if driver_owned {
                        self.metrics.rejected += 1;
                        let _ = env.reply.send(Err(SelectError::Rejected(
                            "session is driver-owned until finished; sweep it after Finish"
                                .into(),
                        )));
                        continue;
                    }
                    if candidates.is_empty() {
                        let _ = env.reply.send(Ok(ServeReply::Sweep {
                            gains: Vec::new(),
                            generation,
                            round_fresh: 0,
                        }));
                        continue;
                    }
                    if let Some(&bad) = candidates.iter().find(|&&a| a >= n) {
                        self.metrics.rejected += 1;
                        let _ = env.reply.send(Err(SelectError::Rejected(format!(
                            "candidate {bad} out of range (ground set 0..{n})"
                        ))));
                        continue;
                    }
                }
                valid.push(env);
            }
            let lane_reads = valid;
            let mut union: Vec<usize> = Vec::new();
            let mut nsweeps = 0usize;
            for env in &lane_reads {
                if let ServeRequest::Sweep { candidates } = &env.req {
                    nsweeps += 1;
                    union.extend_from_slice(candidates);
                }
            }
            union.sort_unstable();
            union.dedup();
            let Some(lane) = self.lanes[lane_idx].as_mut() else {
                // unreachable by the read-before-write turn order; dropping
                // the envelopes surfaces as Disconnected, never a panic
                continue;
            };
            let round = if nsweeps > 0 {
                self.metrics.sweep_requests += nsweeps;
                self.metrics.coalesced_rounds += 1;
                self.metrics.coalesced_candidates += union.len();
                Some(lane.session.sweep(&union))
            } else {
                None
            };
            for env in lane_reads {
                match env.req {
                    ServeRequest::Sweep { candidates } => {
                        // a coalescing miss (candidate absent from the
                        // union, or a round that was never issued) costs
                        // this one request a typed rejection — never the
                        // serve loop
                        let reply = match round.as_ref() {
                            Some(round) => slice_gains(&candidates, &union, &round.gains).map(
                                |gains| ServeReply::Sweep {
                                    gains,
                                    generation: round.generation.0,
                                    round_fresh: round.fresh,
                                },
                            ),
                            None => Err(SelectError::Rejected(
                                "sweep request reached the reply loop without a pooled round"
                                    .into(),
                            )),
                        };
                        if reply.is_err() {
                            self.metrics.rejected += 1;
                        }
                        let _ = env.reply.send(reply);
                    }
                    ServeRequest::Metrics => {
                        self.metrics.metrics_reads += 1;
                        let _ = env
                            .reply
                            .send(Ok(ServeReply::Metrics { snapshot: lane.session.snapshot() }));
                    }
                    ref other => {
                        self.metrics.rejected += 1;
                        let _ = env.reply.send(Err(SelectError::Rejected(format!(
                            "{other:?} is not a read request; the read bucket holds only \
                             sweep/metrics"
                        ))));
                    }
                }
            }
        }

        // phase B — writes, in arrival order.
        for env in writes {
            // a close earlier in this turn's write order frees the slot;
            // later writes against the same id reject as unknown
            if matches!(env.req, ServeRequest::Close) {
                let reply = self.close_slot(env.session);
                if reply.is_err() {
                    self.metrics.rejected += 1;
                }
                let _ = env.reply.send(reply);
                continue;
            }
            let Some(lane) = self.lanes.get_mut(env.session.0).and_then(|l| l.as_mut()) else {
                self.metrics.rejected += 1;
                let _ = env.reply.send(Err(SelectError::UnknownSession(env.session.0)));
                continue;
            };
            let reply = match env.req {
                ServeRequest::Insert { item, if_generation } => {
                    let n = lane.session.objective().n();
                    let current = lane.session.generation().0;
                    if lane.driver.is_some() || lane.result.is_some() {
                        // a driven lane's mutations belong to its driver;
                        // after finish the result must stay immutable
                        Err(SelectError::Rejected(
                            "driven session: the solution set grows only through its driver"
                                .into(),
                        ))
                    } else if item >= n {
                        Err(SelectError::Rejected(format!(
                            "element {item} out of range (ground set 0..{n})"
                        )))
                    } else if if_generation.is_some_and(|pinned| pinned != current) {
                        // generation-pinned insert raced another writer:
                        // reject without mutating, so the client can
                        // re-sweep and decide against fresh gains
                        Err(SelectError::StaleGeneration {
                            pinned: if_generation.unwrap_or(0),
                            actual: current,
                        })
                    } else {
                        self.metrics.inserts += 1;
                        let grew = lane.session.insert(item);
                        Ok(ServeReply::Insert {
                            grew,
                            generation: lane.session.generation().0,
                        })
                    }
                }
                ServeRequest::Step => {
                    if lane.result.is_some() {
                        // already finished: stepping is a no-op, like a
                        // terminated driver's step
                        self.metrics.steps += 1;
                        Ok(ServeReply::Step {
                            done: true,
                            generation: lane.session.generation().0,
                        })
                    } else if let Some(driver) = lane.driver.as_mut() {
                        self.metrics.steps += 1;
                        let done =
                            driver.step(&mut lane.session, &mut lane.rng) == StepOutcome::Done;
                        if done {
                            lane.done = true;
                        }
                        Ok(ServeReply::Step { done, generation: lane.session.generation().0 })
                    } else {
                        Err(SelectError::Rejected("session has no driver to step".into()))
                    }
                }
                ServeRequest::Finish => {
                    // finish only a driver that has stepped to Done: some
                    // drivers (DASH's guess ladder) cannot finalize mid-run,
                    // and a premature finish must reject, not panic the loop
                    if lane.result.is_none() && lane.done {
                        if let Some(driver) = lane.driver.take() {
                            lane.result = Some(driver.finish(&mut lane.session));
                        }
                    }
                    match &lane.result {
                        Some(result) => {
                            self.metrics.finishes += 1;
                            Ok(ServeReply::Finish { result: result.clone() })
                        }
                        None if lane.driver.is_some() => Err(SelectError::Rejected(
                            "driver has not terminated; step it to Done before finishing"
                                .into(),
                        )),
                        None => {
                            Err(SelectError::Rejected("session has no driver to finish".into()))
                        }
                    }
                }
                ref other => Err(SelectError::Rejected(format!(
                    "{other:?} is not a write request; the write bucket holds only \
                     insert/step/finish"
                ))),
            };
            if reply.is_err() {
                self.metrics.rejected += 1;
            }
            let _ = env.reply.send(reply);
        }
    }

    /// Traffic counters plus a snapshot of every live session (closed
    /// lanes left no state to snapshot).
    pub fn summary(&self) -> ServeSummary {
        ServeSummary {
            metrics: self.metrics.clone(),
            sessions: self.lanes.iter().flatten().map(|l| l.session.snapshot()).collect(),
        }
    }

    /// The threaded serving loop: block for the next request, drain
    /// everything else already queued (the coalescing window), service the
    /// batch as one turn, repeat until every client handle is dropped.
    /// Consumes the server and returns the final [`ServeSummary`].
    pub fn run(mut self, rx: Receiver<Envelope>) -> ServeSummary {
        while let Ok(env) = rx.recv() {
            self.enqueue(env);
            while let Ok(more) = rx.try_recv() {
                self.enqueue(more);
            }
            self.turn();
        }
        self.summary()
    }
}

/// Slice one request's gains back out of a pooled round. `union` is the
/// sorted, deduped candidate union the round was issued over; every
/// requested candidate must appear in it and the round must carry one gain
/// per union entry. A miss means the coalescing bookkeeping is wrong for
/// this request — that is a typed [`SelectError::Rejected`] for the one
/// caller, never a panic that would tear down every other client's lane.
fn slice_gains(
    candidates: &[usize],
    union: &[usize],
    gains: &[f64],
) -> Result<Vec<f64>, SelectError> {
    candidates
        .iter()
        .map(|a| {
            let i = union.binary_search(a).map_err(|_| {
                SelectError::Rejected(format!(
                    "candidate {a} missing from the coalesced sweep union"
                ))
            })?;
            gains.get(i).copied().ok_or_else(|| {
                SelectError::Rejected(format!(
                    "pooled round carries {} gains for a union of {} candidates",
                    gains.len(),
                    union.len()
                ))
            })
        })
        .collect()
}

/// Gains slice of one coalesced round, as seen by a single client.
#[derive(Debug, Clone)]
pub struct SweptGains {
    /// `f_S(a)` per requested candidate, in request order
    pub gains: Vec<f64>,
    /// generation the gains were computed at
    pub generation: u64,
    /// oracle queries the whole coalesced round issued
    pub round_fresh: usize,
}

/// Cloneable, thread-safe handle to one served session. Every method
/// blocks until its reply arrives (or the server is gone). Clone freely —
/// clones share the bounded request queue; [`SessionClient::for_session`]
/// retargets a handle at another session of the same server.
///
/// The handle is a thin veneer over the typed v1 values: every method
/// builds an [`ApiRequest`] and matches an [`ApiReply`] through
/// [`SessionClient::api`], the same conversions the stdio wire front uses
/// — the two fronts are one API by construction.
#[derive(Clone)]
pub struct SessionClient {
    tx: SyncSender<Envelope>,
    session: SessionId,
}

impl SessionClient {
    pub fn new(tx: SyncSender<Envelope>, session: SessionId) -> Self {
        SessionClient { tx, session }
    }

    /// The session this handle targets.
    pub fn id(&self) -> SessionId {
        self.session
    }

    /// A handle to another session of the same server.
    pub fn for_session(&self, session: SessionId) -> SessionClient {
        SessionClient { tx: self.tx.clone(), session }
    }

    /// Issue one typed v1 request and block for its typed reply. The
    /// request is converted through [`ApiRequest::into_serve`] and the
    /// reply through [`ApiReply::from_serve`] — exactly the conversions
    /// the stdio front applies per line. Server-level ops (`Open`/`List`)
    /// are not session-addressed and are rejected; the request's own
    /// `session` field is honored (it may target any session of this
    /// server, like [`SessionClient::for_session`]).
    pub fn api(&self, req: ApiRequest) -> Result<ApiReply, SelectError> {
        let (session, sreq) = req.into_serve()?;
        let (env, rx) = Envelope::new(session, sreq);
        self.tx.send(env).map_err(|_| SelectError::Disconnected)?;
        let reply = rx.recv().map_err(|_| SelectError::Disconnected)??;
        Ok(ApiReply::from_serve(reply))
    }

    /// Generation-stamped marginal gains for `candidates` (one coalesced
    /// pooled round shared with every concurrent sweep of this session).
    pub fn sweep(&self, candidates: &[usize]) -> Result<SweptGains, SelectError> {
        let req =
            ApiRequest::Sweep { session: self.session.0, candidates: candidates.to_vec() };
        match self.api(req)? {
            ApiReply::Swept { gains, generation, fresh } => {
                Ok(SweptGains { gains, generation, round_fresh: fresh })
            }
            other => Err(SelectError::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// `S ← S ∪ {item}`; returns `(grew, generation after the insert)`.
    pub fn insert(&self, item: usize) -> Result<(bool, u64), SelectError> {
        self.insert_req(item, None)
    }

    /// Generation-pinned insert: applies only while the session is still
    /// at `generation` (e.g. the stamp of the sweep that chose `item`),
    /// otherwise fails with [`SelectError::StaleGeneration`] and mutates
    /// nothing.
    pub fn insert_at(&self, item: usize, generation: u64) -> Result<(bool, u64), SelectError> {
        self.insert_req(item, Some(generation))
    }

    fn insert_req(
        &self,
        item: usize,
        if_generation: Option<u64>,
    ) -> Result<(bool, u64), SelectError> {
        let req = ApiRequest::Insert { session: self.session.0, item, if_generation };
        match self.api(req)? {
            ApiReply::Inserted { grew, generation } => Ok((grew, generation)),
            other => Err(SelectError::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Advance the attached driver one adaptive round; `Ok(true)` once it
    /// has terminated.
    pub fn step(&self) -> Result<bool, SelectError> {
        match self.api(ApiRequest::Step { session: self.session.0 })? {
            ApiReply::Stepped { done, .. } => Ok(done),
            other => Err(SelectError::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Finalize the attached driver (idempotent).
    pub fn finish(&self) -> Result<SelectionResult, SelectError> {
        match self.api(ApiRequest::Finish { session: self.session.0 })? {
            ApiReply::Finished { result } => Ok(result),
            other => Err(SelectError::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Step the attached driver to termination, then finish — the served
    /// equivalent of [`drive`](crate::coordinator::session::drive).
    pub fn drive(&self) -> Result<SelectionResult, SelectError> {
        while !self.step()? {}
        self.finish()
    }

    /// Close the session: its lane (state, driver, and the lane's share of
    /// the objective) is dropped and the slot freed for reuse. Every later
    /// request against this id — from this handle or any clone — is
    /// answered with [`SelectError::UnknownSession`].
    pub fn close(&self) -> Result<(), SelectError> {
        match self.api(ApiRequest::Close { session: self.session.0 })? {
            ApiReply::Closed { .. } => Ok(()),
            other => Err(SelectError::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Point-in-time snapshot of the session.
    pub fn metrics(&self) -> Result<SessionSnapshot, SelectError> {
        match self.api(ApiRequest::Metrics { session: self.session.0 })? {
            ApiReply::Snapshot { snapshot } => Ok(snapshot),
            other => Err(SelectError::Protocol(format!("unexpected reply {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Greedy, GreedyConfig};
    use crate::coordinator::session::drive;
    use crate::data::synthetic;
    use crate::objectives::{LinearRegressionObjective, ObjectiveState};

    fn obj() -> LinearRegressionObjective {
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 70, 24, 8, 0.3);
        LinearRegressionObjective::new(&ds)
    }

    #[test]
    fn coalesced_sweeps_share_one_round_and_stamp_generations() {
        let o = obj();
        let exec = BatchExecutor::sequential();
        let mut server = SessionServer::new();
        let lane = server.open(&o, exec.clone());
        let rx_a = server.submit(lane, ServeRequest::Sweep { candidates: vec![0, 1, 2] });
        let rx_b = server.submit(lane, ServeRequest::Sweep { candidates: vec![2, 3] });
        let rx_ins = server.submit(lane, ServeRequest::Insert { item: 1, if_generation: None });
        server.turn();
        // one pooled round served both sweeps, before the insert
        assert_eq!(server.metrics.sweep_requests, 2);
        assert_eq!(server.metrics.coalesced_rounds, 1);
        assert_eq!(server.session(lane).unwrap().metrics.sweeps, 1);
        let truth = o.empty_state().gains(&[0, 1, 2, 3]);
        match rx_a.recv().unwrap().unwrap() {
            ServeReply::Sweep { gains, generation, .. } => {
                assert_eq!(generation, 0);
                for (g, t) in gains.iter().zip(&truth[..3]) {
                    assert_eq!(g.to_bits(), t.to_bits());
                }
            }
            other => panic!("unexpected {other:?}"),
        }
        match rx_b.recv().unwrap().unwrap() {
            ServeReply::Sweep { gains, generation, .. } => {
                assert_eq!(generation, 0);
                assert_eq!(gains.len(), 2);
                assert_eq!(gains[0].to_bits(), truth[2].to_bits());
            }
            other => panic!("unexpected {other:?}"),
        }
        match rx_ins.recv().unwrap().unwrap() {
            ServeReply::Insert { grew, generation } => {
                assert!(grew);
                assert_eq!(generation, 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        // next turn's sweeps are stamped with the new generation
        let rx = server.submit(lane, ServeRequest::Sweep { candidates: vec![0] });
        server.turn();
        match rx.recv().unwrap().unwrap() {
            ServeReply::Sweep { generation, .. } => assert_eq!(generation, 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    /// The reply loop's gain slicing is a typed rejection on any
    /// malformed candidate — a request whose candidate misses the
    /// coalesced union, or a round carrying too few gains, costs that one
    /// request an `Err`, never a serve-loop panic.
    #[test]
    fn malformed_candidates_slice_to_typed_rejections() {
        let union = vec![2usize, 5, 9];
        let gains = vec![0.25, 0.5, 0.75];
        // the good path round-trips in request order
        let ok = slice_gains(&[9, 2], &union, &gains).unwrap();
        assert_eq!(ok[0].to_bits(), 0.75f64.to_bits());
        assert_eq!(ok[1].to_bits(), 0.25f64.to_bits());
        // candidate absent from the union
        match slice_gains(&[2, 7], &union, &gains) {
            Err(SelectError::Rejected(msg)) => assert!(msg.contains("7"), "got: {msg}"),
            other => panic!("expected a typed rejection, got {other:?}"),
        }
        // round shorter than the union it claims to cover
        match slice_gains(&[9], &union, &gains[..2]) {
            Err(SelectError::Rejected(msg)) => assert!(msg.contains("union"), "got: {msg}"),
            other => panic!("expected a typed rejection, got {other:?}"),
        }
        // out-of-range candidates through the public server front reject
        // per-request while the loop keeps serving the lane
        let o = obj();
        let mut server = SessionServer::new();
        let lane = server.open(&o, BatchExecutor::sequential());
        let n = o.n();
        let rx_bad = server.submit(lane, ServeRequest::Sweep { candidates: vec![0, n + 3] });
        let rx_ok = server.submit(lane, ServeRequest::Sweep { candidates: vec![0] });
        server.turn();
        assert!(matches!(rx_bad.recv().unwrap(), Err(SelectError::Rejected(_))));
        assert!(rx_ok.recv().unwrap().is_ok(), "one bad request must not poison the round");
        assert_eq!(server.metrics.rejected, 1);
    }

    #[test]
    fn driven_lane_matches_solo_drive() {
        let o = obj();
        let cfg = GreedyConfig { k: 5, ..Default::default() };
        let solo = {
            let mut s = SelectionSession::new(&o, BatchExecutor::sequential());
            drive(Greedy::driver(cfg.clone(), "sds_ma"), &mut s, &mut Pcg64::seed_from(0))
        };
        let mut server = SessionServer::new();
        let lane = server.open_driven(
            &o,
            BatchExecutor::sequential(),
            Greedy::driver(cfg, "sds_ma"),
            0,
        );
        // a driver-owned lane rejects premature finishes and raw traffic —
        // per-request, never a loop-killing panic
        let rx_early_fin = server.submit(lane, ServeRequest::Finish);
        let rx_ins = server.submit(lane, ServeRequest::Insert { item: 0, if_generation: None });
        let rx_sweep = server.submit(lane, ServeRequest::Sweep { candidates: vec![0, 1] });
        server.turn();
        assert!(matches!(rx_early_fin.recv().unwrap(), Err(SelectError::Rejected(_))));
        assert!(matches!(rx_ins.recv().unwrap(), Err(SelectError::Rejected(_))));
        assert!(matches!(rx_sweep.recv().unwrap(), Err(SelectError::Rejected(_))));
        loop {
            let rx = server.submit(lane, ServeRequest::Step);
            server.turn();
            match rx.recv().unwrap().unwrap() {
                ServeReply::Step { done, .. } => {
                    if done {
                        break;
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        let rx = server.submit(lane, ServeRequest::Finish);
        // finish twice: idempotent
        let rx2 = server.submit(lane, ServeRequest::Finish);
        server.turn();
        let r1 = match rx.recv().unwrap().unwrap() {
            ServeReply::Finish { result } => result,
            other => panic!("unexpected {other:?}"),
        };
        let r2 = match rx2.recv().unwrap().unwrap() {
            ServeReply::Finish { result } => result,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(solo.set, r1.set);
        assert_eq!(solo.value.to_bits(), r1.value.to_bits());
        assert_eq!(solo.rounds, r1.rounds);
        assert_eq!(solo.queries, r1.queries);
        assert_eq!(r1.set, r2.set);
        // a step after finish is a terminated no-op
        let rx = server.submit(lane, ServeRequest::Step);
        server.turn();
        match rx.recv().unwrap().unwrap() {
            ServeReply::Step { done, .. } => assert!(done),
            other => panic!("unexpected {other:?}"),
        }
        // once finished, the frozen lane serves read-only sweeps but still
        // rejects inserts
        let rx_sweep = server.submit(lane, ServeRequest::Sweep { candidates: vec![0, 1] });
        let rx_ins = server.submit(lane, ServeRequest::Insert { item: 0, if_generation: None });
        server.turn();
        match rx_sweep.recv().unwrap().unwrap() {
            ServeReply::Sweep { gains, generation, .. } => {
                assert_eq!(gains.len(), 2);
                assert_eq!(generation, r1.set.len() as u64);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(rx_ins.recv().unwrap(), Err(SelectError::Rejected(_))));
    }

    #[test]
    fn invalid_requests_are_rejected_not_fatal() {
        let o = obj();
        let mut server = SessionServer::new();
        let lane = server.open(&o, BatchExecutor::sequential());
        let rx_bad = server.submit(SessionId(9), ServeRequest::Metrics);
        let rx_step = server.submit(lane, ServeRequest::Step);
        let rx_fin = server.submit(lane, ServeRequest::Finish);
        server.turn();
        assert!(matches!(rx_bad.recv().unwrap(), Err(SelectError::UnknownSession(9))));
        assert!(matches!(rx_step.recv().unwrap(), Err(SelectError::Rejected(_))));
        assert!(matches!(rx_fin.recv().unwrap(), Err(SelectError::Rejected(_))));
        assert_eq!(server.metrics.rejected, 3);
        assert_eq!(server.metrics.steps, 0, "rejected steps are not counted as applied");
        assert_eq!(server.metrics.finishes, 0, "rejected finishes are not counted");
        // out-of-range traffic from one client is rejected per-request —
        // never a panic that would tear down the other clients' sessions —
        // and in-range requests in the same turn are still served
        let rx_bad_sweep =
            server.submit(lane, ServeRequest::Sweep { candidates: vec![0, o.n()] });
        let rx_ok_sweep = server.submit(lane, ServeRequest::Sweep { candidates: vec![0] });
        let rx_bad_ins = server.submit(lane, ServeRequest::Insert { item: o.n() + 3, if_generation: None });
        server.turn();
        assert!(matches!(rx_bad_sweep.recv().unwrap(), Err(SelectError::Rejected(_))));
        assert!(matches!(rx_ok_sweep.recv().unwrap(), Ok(ServeReply::Sweep { .. })));
        assert!(matches!(rx_bad_ins.recv().unwrap(), Err(SelectError::Rejected(_))));
        assert_eq!(server.metrics.rejected, 5);
        assert_eq!(server.metrics.sweep_requests, 1, "rejected sweeps are not counted");
        assert_eq!(server.metrics.inserts, 0, "rejected inserts are not applied");
        // an empty sweep is answered directly: no pooled round, no
        // coalescing-accounting skew
        let rx_empty = server.submit(lane, ServeRequest::Sweep { candidates: Vec::new() });
        server.turn();
        match rx_empty.recv().unwrap().unwrap() {
            ServeReply::Sweep { gains, round_fresh, .. } => {
                assert!(gains.is_empty());
                assert_eq!(round_fresh, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(server.metrics.sweep_requests, 1, "empty sweeps are not rounds");
        assert_eq!(server.metrics.coalesced_rounds, 1);
        // the lane still serves after rejections; a dropped reply receiver
        // must not wedge the turn either
        drop(server.submit(lane, ServeRequest::Sweep { candidates: vec![0, 1] }));
        server.turn();
        let rx = server.submit(lane, ServeRequest::Insert { item: 2, if_generation: None });
        server.turn();
        assert!(matches!(
            rx.recv().unwrap().unwrap(),
            ServeReply::Insert { grew: true, generation: 1 }
        ));
    }

    #[test]
    fn close_frees_the_slot_and_later_requests_reject() {
        let o = obj();
        let mut server = SessionServer::new();
        let a = server.open(&o, BatchExecutor::sequential());
        let b = server.open(&o, BatchExecutor::sequential());
        assert_eq!(server.sessions(), 2);
        // a close is a write: reads queued in the same turn are served
        // first, writes after the close in arrival order reject as unknown
        let rx_sweep = server.submit(a, ServeRequest::Sweep { candidates: vec![0, 1] });
        let rx_close = server.submit(a, ServeRequest::Close);
        let rx_ins = server.submit(a, ServeRequest::Insert { item: 0, if_generation: None });
        server.turn();
        assert!(matches!(rx_sweep.recv().unwrap(), Ok(ServeReply::Sweep { .. })));
        assert!(
            matches!(rx_close.recv().unwrap(), Ok(ServeReply::Closed { session }) if session == a.0)
        );
        assert!(matches!(rx_ins.recv().unwrap(), Err(SelectError::UnknownSession(_))));
        assert_eq!(server.sessions(), 1);
        assert!(server.session(a).is_none());
        assert!(server.session(b).is_some());
        // the closed id stays unknown; a double close rejects, not panics
        let rx = server.submit(a, ServeRequest::Metrics);
        let rx2 = server.submit(a, ServeRequest::Close);
        server.turn();
        assert!(matches!(rx.recv().unwrap(), Err(SelectError::UnknownSession(_))));
        assert!(matches!(rx2.recv().unwrap(), Err(SelectError::UnknownSession(_))));
        // the freed slot is reused by the next open (fd-style), so churn
        // does not grow the lane table
        let c = server.open(&o, BatchExecutor::sequential());
        assert_eq!(c, a);
        assert_eq!(server.sessions(), 2);
        assert_eq!(server.metrics.closes, 1);
        let rx = server.submit(c, ServeRequest::Insert { item: 2, if_generation: None });
        server.turn();
        assert!(matches!(
            rx.recv().unwrap().unwrap(),
            ServeReply::Insert { grew: true, generation: 1 }
        ));
        // the summary covers only live lanes
        assert_eq!(server.summary().sessions.len(), 2);
    }

    #[test]
    fn shared_lane_drops_its_objective_share_on_close() {
        let o: Arc<dyn Objective> = Arc::new(obj());
        let mut server = SessionServer::new();
        let lane = server.open_shared(Arc::clone(&o), BatchExecutor::sequential());
        assert_eq!(Arc::strong_count(&o), 2);
        server.close(lane).unwrap();
        assert_eq!(
            Arc::strong_count(&o),
            1,
            "closing the lane must drop its objective share"
        );
        assert_eq!(server.sessions(), 0);
        assert!(matches!(server.close(lane), Err(SelectError::UnknownSession(_))));
    }

    #[test]
    fn restored_lane_matches_the_snapshot_bitwise() {
        let o = obj();
        let exec = BatchExecutor::sequential();
        let mut server = SessionServer::new();
        let a = server.open(&o, exec.clone());
        for item in [4usize, 9, 2] {
            let rx = server.submit(a, ServeRequest::Insert { item, if_generation: None });
            server.turn();
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = server.session(a).unwrap().snapshot();
        server.close(a).unwrap();
        let b = server
            .open_restored(ObjectiveHandle::Borrowed(&o), exec, &snap, None)
            .unwrap();
        let restored = server.session(b).unwrap().snapshot();
        assert_eq!(restored.set, snap.set);
        assert_eq!(restored.generation, snap.generation);
        assert_eq!(restored.value.to_bits(), snap.value.to_bits());
        assert_eq!(restored.metrics, snap.metrics);
        // a corrupted snapshot set is a typed error, not a panic
        let mut bad = snap.clone();
        bad.set.push(o.n() + 7);
        let err = server
            .open_restored(ObjectiveHandle::Borrowed(&o), BatchExecutor::sequential(), &bad, None)
            .unwrap_err();
        assert!(matches!(err, SelectError::Backend(_)), "{err:?}");
    }

    #[test]
    fn client_handles_are_send_and_clone() {
        fn assert_send<T: Send>() {}
        fn assert_clone<T: Clone>() {}
        assert_send::<SessionClient>();
        assert_clone::<SessionClient>();
        assert_send::<Envelope>();
    }
}
