//! Multi-worker session router: one process speaking plain v1 frames on
//! both sides, placing sessions across N worker processes that each run
//! the unchanged [`NetServer`](crate::coordinator::net::NetServer) front.
//!
//! # Placement
//!
//! A session's home worker is a pure function of `(session id, worker
//! address)`: rendezvous (highest-random-weight) hashing over the
//! currently-live workers — [`place`]. Because the hash is keyed by the
//! worker's *address*, not its position on the command line, the mapping
//! is stable across router restarts and across `--worker` reorderings,
//! and removing one worker re-places only that worker's sessions (the
//! classic rendezvous property). The router itself keeps **no session
//! table**: every request re-derives the placement, so a freshly
//! restarted router routes exactly like its predecessor.
//!
//! # Id allocation and translation
//!
//! The router allocates globally-unique session ids from a monotonic
//! counter (seeded above every id the workers already hold) and forwards
//! each `open` **pinned** to that exact id (the `session` field of the
//! wire `open`); a worker installs the lane at the pinned index or
//! rejects with an `already in use` marker, which makes the pin the
//! allocation token — two racing opens can never share an id. Because
//! the pinned id *is* the worker-local id, id translation between the
//! client-facing and worker-facing frames is the identity by
//! construction: session-addressed frames are forwarded verbatim.
//!
//! # Failover
//!
//! All workers share one session store directory, and every mutating
//! request is written through to it by the owning worker. When a worker
//! dies (a request exhausts its per-worker retries), the router marks it
//! dead and re-derives the placement over the survivors; the next
//! request for each of the dead worker's sessions lands on its new home,
//! which **adopts** the session from the shared store at that moment —
//! restoring the dead worker's last persisted write byte-identically
//! (set, generation, value bits), the same evict→restore contract the
//! single-server restart tests pin. A background probe re-pings dead
//! workers and folds them back into the placement when they return.
//!
//! # What is *not* replicated
//!
//! The store holds one durable record per session; there is no log
//! shipping and no consensus. Consequences worth knowing:
//!
//! - **In-flight state**: a request the dying worker had applied but not
//!   yet written through is lost — at-least-once replay semantics, as on
//!   single-server restart.
//! - **Split brain on false death**: a worker the router *believed* dead
//!   (e.g. a network partition) still holds its live lanes; if it
//!   returns, two workers can briefly hold the same session. Unpinned
//!   inserts through both could fork the selection. Generation-pinned
//!   inserts (`if_generation`) are the cross-process concurrency token:
//!   a write against a forked copy answers `stale_generation` instead of
//!   applying, so pinned clients cannot diverge silently. For the same
//!   reason, do not mix direct unpinned opens against a worker with
//!   routed traffic — the router's id counter cannot see ids it did not
//!   allocate until a collision heals it.
//! - **Driver state**: driven sessions mid-run are not snapshottable
//!   (same as single-server); their failover resumes from the last
//!   persisted round.

use super::api::SelectError;
use super::net::{Listener, NetConfig, RetryPolicy, Stream, WireClient};
use super::wire::{readable_frame_id, ApiReply, ApiRequest, SessionInfo, WirePlan, WireProblem};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Placement
// ---------------------------------------------------------------------------

/// Rendezvous weight of `(addr, session)`: FNV-1a over the address bytes,
/// mixed with the session id through a splitmix64 finalizer. Pure and
/// stable — the placement tests pin it across router restarts.
fn rendezvous_weight(addr: &str, session: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in addr.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = h ^ (session as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Place `session` among `addrs` by rendezvous hashing: the index of the
/// address with the highest [`rendezvous_weight`] (ties broken by the
/// lexicographically smaller address, so the choice is total). `None`
/// only for an empty slice.
pub fn place(session: usize, addrs: &[&str]) -> Option<usize> {
    let mut best: Option<(u64, usize)> = None;
    for (i, addr) in addrs.iter().enumerate() {
        let w = rendezvous_weight(addr, session);
        let wins = match best {
            None => true,
            Some((bw, bi)) => w > bw || (w == bw && *addr < addrs[bi]),
        };
        if wins {
            best = Some((w, i));
        }
    }
    best.map(|(_, i)| i)
}

// ---------------------------------------------------------------------------
// Configuration, counters, summary
// ---------------------------------------------------------------------------

/// Robustness knobs of the router front.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Client-facing framing hygiene (frame cap, idle timeout, slow-loris
    /// deadline, poll tick). The per-request *reply* deadline is enforced
    /// by the workers, not re-imposed here.
    pub net: NetConfig,
    /// Per-request retry policy against one worker. Deliberately snappier
    /// than [`RetryPolicy::default`]: exhausting it is the death signal
    /// that triggers re-placement, so a long ladder here would stall
    /// failover.
    pub worker_retry: RetryPolicy,
    /// Cadence of the dead-worker resurrection probe.
    pub probe_interval: Duration,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            net: NetConfig::default(),
            worker_retry: RetryPolicy {
                max_attempts: 4,
                base_backoff: Duration::from_millis(5),
                max_backoff: Duration::from_millis(50),
            },
            probe_interval: Duration::from_millis(250),
        }
    }
}

/// What a [`Router::serve`] loop did before it drained.
#[derive(Debug)]
pub struct RouterSummary {
    /// client connections accepted over the router's lifetime
    pub connections: u64,
    /// request frames decoded and dispatched
    pub requests: u64,
    /// sessions opened (ids allocated and pinned)
    pub opens: u64,
    /// requests re-placed after their worker was marked dead
    pub failovers: u64,
    /// live→dead worker transitions observed
    pub worker_deaths: u64,
    /// dead→live transitions (probe or in-line revival)
    pub worker_revivals: u64,
    /// handler threads reaped by the supervisor after a panic
    pub handler_panics: u64,
}

#[derive(Default)]
struct RouterCounters {
    connections: AtomicU64,
    requests: AtomicU64,
    opens: AtomicU64,
    failovers: AtomicU64,
    worker_deaths: AtomicU64,
    worker_revivals: AtomicU64,
    handler_panics: AtomicU64,
}

struct WorkerState {
    addr: String,
    dead: AtomicBool,
}

/// State shared by every connection handler and the probe thread.
struct RouterShared {
    workers: Vec<WorkerState>,
    /// next global session id; opens take `fetch_add` tickets
    next_id: AtomicUsize,
    /// router-initiated drain (a `shutdown` frame)
    stopping: AtomicBool,
    retry: RetryPolicy,
    counters: RouterCounters,
}

impl RouterShared {
    fn live_addrs(&self) -> Vec<(usize, &str)> {
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, w)| !w.dead.load(Ordering::SeqCst))
            .map(|(i, w)| (i, w.addr.as_str()))
            .collect()
    }

    /// Placement of `session` among the currently-live workers, as an
    /// index into `self.workers`.
    fn place_live(&self, session: usize) -> Option<usize> {
        let live = self.live_addrs();
        let addrs: Vec<&str> = live.iter().map(|(_, a)| *a).collect();
        place(session, &addrs).map(|i| live[i].0)
    }

    fn mark_dead(&self, worker: usize) {
        if !self.workers[worker].dead.swap(true, Ordering::SeqCst) {
            self.counters.worker_deaths.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn mark_live(&self, worker: usize) {
        if self.workers[worker].dead.swap(false, Ordering::SeqCst) {
            self.counters.worker_revivals.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Ping every dead worker once; revive the ones that answer. Returns
    /// how many came back.
    fn probe_dead(&self, seed: u64) -> usize {
        let once = RetryPolicy {
            max_attempts: 1,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(1),
        };
        let mut revived = 0;
        for (i, w) in self.workers.iter().enumerate() {
            if !w.dead.load(Ordering::SeqCst) {
                continue;
            }
            let mut probe = WireClient::connect(&w.addr, seed ^ i as u64).with_policy(once);
            if probe.ping().is_ok() {
                self.mark_live(i);
                revived += 1;
            }
        }
        revived
    }

    /// Advance the id counter past every session id `sessions` reports —
    /// both the startup seeding pass and the collision-healing path on a
    /// pinned-open rejection.
    fn absorb_ids(&self, sessions: &[SessionInfo]) {
        if let Some(max) = sessions.iter().map(|s| s.session).max() {
            self.next_id.fetch_max(max + 1, Ordering::SeqCst);
        }
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// The router front: binds a client-facing listener and forwards v1
/// frames to the worker fleet per the module-level placement/failover
/// contract. Construction mirrors [`NetServer`]: `bind` → builder knobs
/// → [`Router::serve`].
///
/// [`NetServer`]: crate::coordinator::net::NetServer
pub struct Router {
    listener: Listener,
    config: RouterConfig,
    workers: Vec<String>,
    stop: &'static AtomicBool,
}

impl Router {
    /// Bind the client-facing listener (`host:port` or `unix:/path`) over
    /// a non-empty worker address list.
    pub fn bind(addr: &str, workers: &[&str]) -> std::io::Result<Router> {
        if workers.is_empty() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "a router needs at least one --worker address",
            ));
        }
        Ok(Router {
            listener: Listener::bind(addr)?,
            config: RouterConfig::default(),
            workers: workers.iter().map(|w| w.to_string()).collect(),
            stop: super::net::drain_flag(),
        })
    }

    /// Replace the robustness knobs.
    pub fn with_config(mut self, config: RouterConfig) -> Router {
        self.config = config;
        self
    }

    /// Use a caller-owned drain flag instead of the process-wide one —
    /// tests leak one `AtomicBool` per router so concurrent routers drain
    /// independently.
    pub fn with_stop_flag(mut self, stop: &'static AtomicBool) -> Router {
        self.stop = stop;
        self
    }

    /// The bound address in dialable form: `127.0.0.1:PORT` for TCP
    /// (resolving a port-0 bind), `unix:/path` for Unix sockets.
    pub fn local_addr(&self) -> String {
        self.listener.local_addr()
    }

    /// Serve until drained: accept client connections, forward frames per
    /// placement, and stop on a `shutdown` frame or the drain flag.
    /// Returns once every handler has finished its in-flight request.
    pub fn serve(self) -> std::io::Result<RouterSummary> {
        let Router { listener, config, workers, stop } = self;
        let shared = Arc::new(RouterShared {
            workers: workers
                .into_iter()
                .map(|addr| WorkerState { addr, dead: AtomicBool::new(false) })
                .collect(),
            next_id: AtomicUsize::new(0),
            stopping: AtomicBool::new(false),
            retry: config.worker_retry,
            counters: RouterCounters::default(),
        });

        // Seed the id counter above everything the fleet already holds,
        // so a restarted router never re-allocates a live id. Best-effort:
        // a worker that is down now is healed later by the `already in
        // use` rejection path.
        for (i, w) in shared.workers.iter().enumerate() {
            let mut c =
                WireClient::connect(&w.addr, 0x5eed ^ i as u64).with_policy(shared.retry);
            if let Ok(sessions) = c.list() {
                shared.absorb_ids(&sessions);
            }
        }

        // resurrection probe: fold dead workers back in as they return
        let probe_shared = Arc::clone(&shared);
        let probe_interval = config.probe_interval;
        let probe = std::thread::spawn(move || {
            let mut tick = 0u64;
            while !stop.load(Ordering::SeqCst)
                && !probe_shared.stopping.load(Ordering::SeqCst)
            {
                std::thread::sleep(probe_interval);
                tick += 1;
                probe_shared.probe_dead(0x5eed_0000 ^ tick);
            }
        });

        listener.set_nonblocking();
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut conn_seq = 0u64;
        while !stop.load(Ordering::SeqCst) && !shared.stopping.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok(stream) => {
                    conn_seq += 1;
                    shared.counters.connections.fetch_add(1, Ordering::Relaxed);
                    let shared = Arc::clone(&shared);
                    let net = config.net;
                    let seq = conn_seq;
                    handlers.push(std::thread::spawn(move || {
                        // supervision: a panic in forwarding code reaps
                        // this connection only — the listener and every
                        // other connection keep serving
                        let supervised = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                handle_client(stream, net, &shared, seq);
                            }),
                        );
                        if supervised.is_err() {
                            shared.counters.handler_panics.fetch_add(1, Ordering::Relaxed);
                        }
                    }));
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    std::thread::sleep(config.net.poll_tick);
                }
                // a failed accept must not kill the router; back off one
                // tick and keep accepting
                Err(_) => std::thread::sleep(config.net.poll_tick),
            }
            handlers.retain(|h| !h.is_finished());
        }
        // stop observed: tell the handlers (they break between frames),
        // then wait for each to finish its in-flight request
        shared.stopping.store(true, Ordering::SeqCst);
        for h in handlers {
            let _ = h.join();
        }
        let _ = probe.join();
        listener.cleanup();

        let c = &shared.counters;
        Ok(RouterSummary {
            connections: c.connections.load(Ordering::Relaxed),
            requests: c.requests.load(Ordering::Relaxed),
            opens: c.opens.load(Ordering::Relaxed),
            failovers: c.failovers.load(Ordering::Relaxed),
            worker_deaths: c.worker_deaths.load(Ordering::Relaxed),
            worker_revivals: c.worker_revivals.load(Ordering::Relaxed),
            handler_panics: c.handler_panics.load(Ordering::Relaxed),
        })
    }
}

// ---------------------------------------------------------------------------
// Per-connection forwarding
// ---------------------------------------------------------------------------

/// One client connection's forwarding state: lazily-dialed worker clients
/// (each with the full reconnect/backoff machinery of `WireClient`),
/// owned by this handler thread — handlers never contend on a shared
/// connection pool, which is what lets concurrent clients saturate
/// multiple workers at once.
struct Forwarder<'a> {
    shared: &'a RouterShared,
    clients: HashMap<usize, WireClient>,
    seed: u64,
}

impl<'a> Forwarder<'a> {
    fn new(shared: &'a RouterShared, seed: u64) -> Forwarder<'a> {
        Forwarder { shared, clients: HashMap::new(), seed }
    }

    fn client(&mut self, worker: usize) -> &mut WireClient {
        let shared = self.shared;
        let seed = self.seed;
        self.clients.entry(worker).or_insert_with(|| {
            WireClient::connect(&shared.workers[worker].addr, seed ^ ((worker as u64) << 32))
                .with_policy(shared.retry)
        })
    }

    /// Mark `worker` dead and drop its pooled client so a revival starts
    /// from a fresh dial.
    fn bury(&mut self, worker: usize) {
        self.shared.mark_dead(worker);
        self.clients.remove(&worker);
        self.shared.counters.failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// Forward a session-addressed request to the session's placed
    /// worker. A transport-dead worker is buried and the request is
    /// re-placed among the survivors — the failover path; with every
    /// worker dead, one in-line revival probe gives the fleet a last
    /// chance before the typed `disconnected` gives up.
    fn forward_placed(
        &mut self,
        session: usize,
        req: &ApiRequest,
    ) -> Result<ApiReply, SelectError> {
        let mut probed = false;
        let mut attempts = 0;
        while attempts <= self.shared.workers.len() {
            let Some(worker) = self.shared.place_live(session) else {
                if probed {
                    break;
                }
                probed = true;
                if self.shared.probe_dead(self.seed) == 0 {
                    break;
                }
                continue;
            };
            attempts += 1;
            match self.client(worker).request(req) {
                Err(SelectError::Disconnected) => self.bury(worker),
                other => return other,
            }
        }
        Err(SelectError::Disconnected)
    }

    /// Allocate a global id, place it, and forward the open pinned to
    /// that id. An `already in use` rejection (the id raced a session the
    /// counter had not seen — e.g. after a partial startup seeding)
    /// absorbs the colliding worker's id space and takes a fresh ticket.
    fn open(
        &mut self,
        problem: WireProblem,
        plan: WirePlan,
        driven: bool,
        tenant: Option<String>,
    ) -> Result<ApiReply, SelectError> {
        for _ in 0..(8 + self.shared.workers.len()) {
            let id = self.shared.next_id.fetch_add(1, Ordering::SeqCst);
            let Some(worker) = self.shared.place_live(id) else {
                return Err(SelectError::Disconnected);
            };
            match self.client(worker).open_pinned(
                problem.clone(),
                plan.clone(),
                driven,
                tenant.clone(),
                id,
            ) {
                Ok(session) => {
                    self.shared.counters.opens.fetch_add(1, Ordering::Relaxed);
                    return Ok(ApiReply::Opened { session });
                }
                Err(SelectError::Rejected(msg)) if msg.contains("already in use") => {
                    if let Ok(sessions) = self.client(worker).list() {
                        self.shared.absorb_ids(&sessions);
                    }
                }
                Err(SelectError::Disconnected) => self.bury(worker),
                Err(other) => return Err(other),
            }
        }
        Err(SelectError::Rejected(
            "open gave up: could not allocate a fresh session id across the fleet".into(),
        ))
    }

    /// Broadcast a close: the placed owner holds the live lane, but after
    /// failovers other workers may hold adopted copies, so every live
    /// worker gets the frame (closing also removes the shared durable
    /// record). Any success closes; all-unknown is the typed
    /// unknown-session.
    fn close(&mut self, session: usize) -> Result<ApiReply, SelectError> {
        let mut closed = false;
        let mut hard_error: Option<SelectError> = None;
        for (worker, _) in self.shared.live_addrs() {
            match self.client(worker).request(&ApiRequest::Close { session }) {
                Ok(ApiReply::Closed { .. }) => closed = true,
                Ok(_) => {}
                Err(SelectError::UnknownSession(_)) => {}
                Err(SelectError::Disconnected) => self.bury(worker),
                Err(e) => hard_error = Some(e),
            }
        }
        if closed {
            Ok(ApiReply::Closed { session })
        } else if let Some(e) = hard_error {
            Err(e)
        } else {
            Err(SelectError::UnknownSession(session))
        }
    }

    /// Fan a `list` out to every live worker and merge: one row per
    /// session id, preferring the resident (live-lane) row — a worker
    /// that merely adopted the session at startup still reports a stale
    /// evicted snapshot — then the freshest generation.
    fn list(&mut self) -> Result<ApiReply, SelectError> {
        let mut merged: HashMap<usize, SessionInfo> = HashMap::new();
        let mut reached = 0usize;
        for (worker, _) in self.shared.live_addrs() {
            match self.client(worker).list() {
                Ok(sessions) => {
                    reached += 1;
                    for s in sessions {
                        match merged.get(&s.session) {
                            Some(seen)
                                if (seen.resident, seen.generation)
                                    >= (s.resident, s.generation) => {}
                            _ => {
                                merged.insert(s.session, s);
                            }
                        }
                    }
                }
                Err(SelectError::Disconnected) => self.bury(worker),
                Err(e) => return Err(e),
            }
        }
        if reached == 0 {
            return Err(SelectError::Disconnected);
        }
        let mut sessions: Vec<SessionInfo> = merged.into_values().collect();
        sessions.sort_by_key(|s| s.session);
        Ok(ApiReply::Sessions { sessions })
    }

    /// Forward a shutdown to every live worker (summing their persisted
    /// counts), then drain the router itself.
    fn shutdown(&mut self) -> Result<ApiReply, SelectError> {
        let mut persisted = 0usize;
        for (worker, _) in self.shared.live_addrs() {
            match self.client(worker).shutdown() {
                Ok(n) => persisted += n,
                Err(_) => self.bury(worker),
            }
        }
        self.shared.stopping.store(true, Ordering::SeqCst);
        Ok(ApiReply::Stopping { persisted })
    }

    /// Dispatch one decoded request.
    fn dispatch(&mut self, req: ApiRequest) -> Result<ApiReply, SelectError> {
        match req {
            ApiRequest::Ping => Ok(ApiReply::Pong),
            ApiRequest::Open { session: Some(_), .. } => Err(SelectError::Rejected(
                "the router allocates session ids; open without a session pin".into(),
            )),
            ApiRequest::Open { problem, plan, driven, tenant, session: None } => {
                self.open(problem, plan, driven, tenant)
            }
            ApiRequest::List => self.list(),
            ApiRequest::Close { session } => self.close(session),
            ApiRequest::Shutdown => self.shutdown(),
            ApiRequest::Crash { .. } => Err(SelectError::Rejected(
                "crash is a test-only fault-injection op; the router does not serve it".into(),
            )),
            other @ (ApiRequest::Sweep { .. }
            | ApiRequest::Insert { .. }
            | ApiRequest::Step { .. }
            | ApiRequest::Finish { .. }
            | ApiRequest::Metrics { .. }) => {
                // session-addressed: forward verbatim to the placed worker
                let session = match &other {
                    ApiRequest::Sweep { session, .. }
                    | ApiRequest::Insert { session, .. }
                    | ApiRequest::Step { session }
                    | ApiRequest::Finish { session }
                    | ApiRequest::Metrics { session } => *session,
                    _ => return Err(SelectError::Protocol("unroutable request".into())),
                };
                self.forward_placed(session, &other)
            }
        }
    }
}

/// One client connection: read newline-delimited frames under the
/// idle/frame-cap budget, dispatch each through the [`Forwarder`], write
/// back one reply line per frame, in order. The same framing hygiene as
/// the worker front's handler — the router must shrug off the same slow,
/// huge, or garbled frames.
fn handle_client(stream: Stream, config: NetConfig, shared: &RouterShared, seq: u64) {
    let _ = stream.set_read_timeout(Some(config.poll_tick));
    let _ = stream.set_write_timeout(Some(config.request_deadline));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut fwd = Forwarder::new(shared, 0xc0de_0000 ^ seq);
    let mut buf: Vec<u8> = Vec::new();
    let mut frame_started: Option<Instant> = None;
    let mut last_activity = Instant::now();

    // answer with a typed error frame, then drop the connection
    let refuse = |writer: &mut Stream, buf: &[u8], error: SelectError| {
        let id = readable_frame_id(&String::from_utf8_lossy(buf));
        let line = ApiReply::Error { error }.encode(id);
        let _ = writeln!(writer, "{line}").and_then(|_| writer.flush());
    };

    loop {
        if shared.stopping.load(Ordering::SeqCst) && buf.is_empty() {
            break; // graceful drain: no frame in flight, close
        }
        let before = buf.len();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break, // EOF (a trailing partial frame is dropped)
            Ok(_) if buf.ends_with(b"\n") => {
                last_activity = Instant::now();
                frame_started = None;
                if buf.len() > config.max_frame_len {
                    refuse(
                        &mut writer,
                        &buf,
                        SelectError::Protocol(format!(
                            "frame of {} bytes exceeds the {}-byte cap",
                            buf.len(),
                            config.max_frame_len
                        )),
                    );
                    break;
                }
                let line = String::from_utf8_lossy(&buf).trim().to_string();
                if !line.is_empty() {
                    shared.counters.requests.fetch_add(1, Ordering::Relaxed);
                    let reply = match ApiRequest::decode(&line) {
                        Ok((id, req)) => match fwd.dispatch(req) {
                            Ok(reply) => reply.encode(id),
                            Err(error) => ApiReply::Error { error }.encode(id),
                        },
                        Err(error) => {
                            ApiReply::Error { error }.encode(readable_frame_id(&line))
                        }
                    };
                    if writeln!(writer, "{reply}").and_then(|_| writer.flush()).is_err() {
                        break; // client gone mid-reply
                    }
                }
                buf.clear();
            }
            Ok(_) => {
                // partial frame (no delimiter yet, not EOF); clock it
                if frame_started.is_none() && buf.len() > before {
                    frame_started = Some(Instant::now());
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if !buf.is_empty() && frame_started.is_none() {
                    frame_started = Some(Instant::now());
                }
                if buf.len() > config.max_frame_len {
                    refuse(
                        &mut writer,
                        &buf,
                        SelectError::Protocol(format!(
                            "frame of {} bytes exceeds the {}-byte cap",
                            buf.len(),
                            config.max_frame_len
                        )),
                    );
                    break;
                }
                // slow-loris: a frame trickling in past the deadline is
                // refused without ever reaching a worker
                if let Some(t0) = frame_started {
                    if t0.elapsed() > config.request_deadline {
                        refuse(
                            &mut writer,
                            &buf,
                            SelectError::Deadline(format!(
                                "frame incomplete after the {:?} deadline",
                                config.request_deadline
                            )),
                        );
                        break;
                    }
                }
                if buf.is_empty() && last_activity.elapsed() > config.idle_timeout {
                    break; // idle connection: close without a reply owed
                }
            }
            Err(_) => break, // reset, aborted, …: the connection is gone
        }
    }
    reader.into_inner().shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_deterministic_and_stable_under_reordering() {
        let a = ["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"];
        let b = ["127.0.0.1:7003", "127.0.0.1:7001", "127.0.0.1:7002"];
        for session in 0..200 {
            let pa = place(session, &a).unwrap();
            let pb = place(session, &b).unwrap();
            // keyed by address, not by position: the chosen *address* is
            // identical however the worker list is ordered
            assert_eq!(a[pa], b[pb], "session {session} moved on reorder");
            // and a second evaluation (a restarted router) agrees
            assert_eq!(pa, place(session, &a).unwrap());
        }
    }

    #[test]
    fn removing_one_worker_only_replaces_its_own_sessions() {
        let full = ["u:alpha", "u:beta", "u:gamma"];
        let without_beta = ["u:alpha", "u:gamma"];
        for session in 0..300 {
            let home = full[place(session, &full).unwrap()];
            let fallback = without_beta[place(session, &without_beta).unwrap()];
            if home != "u:beta" {
                // the rendezvous property: survivors keep their sessions
                assert_eq!(home, fallback, "session {session} moved without cause");
            }
        }
    }

    #[test]
    fn placement_spreads_sessions_across_workers() {
        let addrs = ["127.0.0.1:7001", "127.0.0.1:7002"];
        let mut counts = [0usize; 2];
        for session in 0..1000 {
            counts[place(session, &addrs).unwrap()] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            assert!(
                (200..=800).contains(c),
                "worker {i} got {c}/1000 sessions — placement is pathologically skewed"
            );
        }
    }

    #[test]
    fn empty_fleet_has_no_placement() {
        assert_eq!(place(7, &[]), None);
    }
}
