//! Durable session records: the wire front's snapshot-to-disk layer.
//!
//! A [`SessionStore`] is a directory of JSON files, one per wire session
//! id. The [`StdioServer`](crate::coordinator::wire::StdioServer) writes a
//! [`SessionRecord`] when it evicts an idle lane past its resident budget
//! and reads it back on the next request addressed to that session; the
//! record carries everything a restore needs:
//!
//! - the **wire specs** ([`WireProblem`], [`WirePlan`]) to rebuild the
//!   objective — datasets are synthesized deterministically from
//!   `(dataset, scale, seed)`, so the rebuilt objective is bit-identical
//!   to the evicted one;
//! - the **snapshot** ([`SessionSnapshot`]) whose set, replayed in
//!   insertion order, reproduces the session state byte-for-byte
//!   ([`SelectionSession::restore`](crate::coordinator::session::SelectionSession::restore)
//!   verifies the replayed value bits against the recorded ones);
//! - the **final result**, when a driven lane finished before eviction,
//!   so a restored lane answers `finish` exactly as the live one would
//!   have.
//!
//! Records are written atomically (temp file + rename), so a reader never
//! observes a half-written record. Everything rides the same codecs as
//! the v1 wire protocol (`wire::snapshot_to_json`, `wire::result_to_json`),
//! keeping disk and wire provably one schema.

use crate::algorithms::SelectionResult;
use crate::coordinator::api::SelectError;
use crate::coordinator::session::SessionSnapshot;
use crate::coordinator::wire::{
    need, need_bool, need_str, need_u64, need_usize, result_from_json, result_to_json,
    snapshot_from_json, snapshot_to_json, WirePlan, WireProblem,
};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Everything needed to restore one evicted wire session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionRecord {
    /// public wire session id (stable across evict/restore)
    pub session: usize,
    /// quota bucket the session is charged to
    pub tenant: String,
    /// result-label of the lane's algorithm (`sds_ma`, `dash`, …)
    pub algorithm: String,
    pub driven: bool,
    /// the lane's driver had stepped to done when the record was written
    /// (kept explicit so a restarted server's `list` metadata matches the
    /// pre-crash server's exactly — a driver can be done before `finish`
    /// materializes its result)
    pub finished: bool,
    /// driver RNG seed the lane was opened with
    pub seed: u64,
    pub problem: WireProblem,
    pub plan: WirePlan,
    pub snapshot: SessionSnapshot,
    /// final result, iff the lane's driver finished before eviction
    pub result: Option<SelectionResult>,
}

impl SessionRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("session", self.session.into()),
            ("tenant", self.tenant.as_str().into()),
            ("algorithm", self.algorithm.as_str().into()),
            ("driven", self.driven.into()),
            ("finished", self.finished.into()),
            ("seed", self.seed.into()),
            ("problem", self.problem.to_json()),
            ("plan", self.plan.to_json()),
            ("snapshot", snapshot_to_json(&self.snapshot)),
        ];
        if let Some(r) = &self.result {
            pairs.push(("result", result_to_json(r)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<SessionRecord, SelectError> {
        let result = match j.get("result") {
            Some(r) => Some(result_from_json(r)?),
            None => None,
        };
        Ok(SessionRecord {
            session: need_usize(j, "session")?,
            tenant: need_str(j, "tenant")?.to_string(),
            algorithm: need_str(j, "algorithm")?.to_string(),
            driven: need_bool(j, "driven")?,
            // absent in records written before the flag existed: a result
            // is the only evidence of a finished driver
            finished: match j.get("finished") {
                Some(_) => need_bool(j, "finished")?,
                None => result.is_some(),
            },
            seed: need_u64(j, "seed")?,
            problem: WireProblem::from_json(need(j, "problem")?)?,
            plan: WirePlan::from_json(need(j, "plan")?)?,
            snapshot: snapshot_from_json(need(j, "snapshot")?)?,
            result,
        })
    }
}

/// A directory of [`SessionRecord`]s, one file per wire session id.
/// Filesystem failures surface as [`SelectError::Backend`] — an open that
/// triggered an eviction whose persist failed is answered with the error,
/// and the victim lane stays resident.
#[derive(Debug)]
pub struct SessionStore {
    dir: PathBuf,
}

impl SessionStore {
    /// Open (creating if needed) the store directory. Stray `.json.tmp`
    /// files — leftovers of a crash mid-[`SessionStore::save`], before the
    /// atomic rename — are swept here: they were never observable as
    /// records and keeping them would only shadow the next save's temp.
    pub fn open(dir: impl Into<PathBuf>) -> Result<SessionStore, SelectError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).map_err(|e| {
            SelectError::Backend(format!("session store: create {}: {e}", dir.display()))
        })?;
        if let Ok(entries) = std::fs::read_dir(&dir) {
            for entry in entries.flatten() {
                if entry.file_name().to_string_lossy().ends_with(".json.tmp") {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        Ok(SessionStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The record file backing one session id.
    pub fn path(&self, session: usize) -> PathBuf {
        self.dir.join(format!("session-{session}.json"))
    }

    /// Persist one record atomically (temp file + rename): a crash or a
    /// concurrent reader never observes a half-written record.
    pub fn save(&self, record: &SessionRecord) -> Result<(), SelectError> {
        let path = self.path(record.session);
        let tmp = self.dir.join(format!("session-{}.json.tmp", record.session));
        let text = record.to_json().to_string_pretty();
        std::fs::write(&tmp, text).map_err(|e| {
            SelectError::Backend(format!("session store: write {}: {e}", tmp.display()))
        })?;
        std::fs::rename(&tmp, &path).map_err(|e| {
            SelectError::Backend(format!("session store: rename {}: {e}", path.display()))
        })?;
        Ok(())
    }

    /// Load the record for one session id.
    ///
    /// A record that exists but cannot be decoded — truncated by a crash
    /// mid-write, hand-edited, or claiming a different session id — is
    /// **quarantined**: moved to the `.quarantine/` side-directory for
    /// post-mortem and answered with a typed [`SelectError::Backend`] for
    /// *this id only*. The rest of the store keeps serving; the corrupt
    /// record can never wedge every restore behind it.
    pub fn load(&self, session: usize) -> Result<SessionRecord, SelectError> {
        let path = self.path(session);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            SelectError::Backend(format!("session store: read {}: {e}", path.display()))
        })?;
        let corrupt = |why: String| -> SelectError {
            let note = match self.quarantine(session) {
                Some(dest) => format!("; record quarantined to {}", dest.display()),
                None => String::new(),
            };
            SelectError::Backend(format!("session store: {why}{note}"))
        };
        let j = match Json::parse(&text) {
            Ok(j) => j,
            Err(e) => return Err(corrupt(format!("parse {}: {e}", path.display()))),
        };
        let record = match SessionRecord::from_json(&j) {
            Ok(r) => r,
            Err(e) => return Err(corrupt(format!("decode {}: {e}", path.display()))),
        };
        if record.session != session {
            return Err(corrupt(format!(
                "{} records session {}, expected {session}",
                path.display(),
                record.session
            )));
        }
        Ok(record)
    }

    /// Move one record into the `.quarantine/` side-directory, returning
    /// the destination (best-effort: `None` if the move failed — the
    /// caller's typed error stands either way). Destination names are
    /// collision-free: a session id that corrupts again after its slot was
    /// rewritten gets a numbered suffix (`session-N.json`,
    /// `session-N.1.json`, …) instead of silently overwriting the first
    /// piece of evidence.
    fn quarantine(&self, session: usize) -> Option<PathBuf> {
        let qdir = self.dir.join(".quarantine");
        std::fs::create_dir_all(&qdir).ok()?;
        let dest = (0u32..)
            .map(|attempt| match attempt {
                0 => qdir.join(format!("session-{session}.json")),
                n => qdir.join(format!("session-{session}.{n}.json")),
            })
            .find(|candidate| !candidate.exists())?;
        std::fs::rename(self.path(session), &dest).ok()?;
        Some(dest)
    }

    /// Session ids with a record on disk, ascending. Used by
    /// [`WireCore::with_store`](crate::coordinator::wire::WireCore::with_store)
    /// to adopt a previous process's sessions on startup.
    pub fn list(&self) -> Vec<usize> {
        let mut ids = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if let Some(id) = name
                    .strip_prefix("session-")
                    .and_then(|rest| rest.strip_suffix(".json"))
                    .and_then(|id| id.parse::<usize>().ok())
                {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        ids
    }

    /// Whether a record exists for one session id.
    pub fn contains(&self, session: usize) -> bool {
        self.path(session).is_file()
    }

    /// Delete the record for one session id (idempotent; a missing file
    /// is not an error — close after restore is the common case).
    pub fn remove(&self, session: usize) {
        let _ = std::fs::remove_file(self.path(session));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::{Generation, SessionMetrics};

    fn record(session: usize) -> SessionRecord {
        SessionRecord {
            session,
            tenant: "acme".into(),
            algorithm: "sds_ma".into(),
            driven: false,
            finished: false,
            seed: 7,
            problem: WireProblem::new("d1", 5, 1),
            plan: WirePlan::new("greedy"),
            snapshot: SessionSnapshot {
                generation: Generation(3),
                set: vec![4, 9, 2],
                value: 1.25,
                metrics: SessionMetrics {
                    sweeps: 2,
                    swept_candidates: 10,
                    cache_hits: 1,
                    fresh_queries: 9,
                    inserts: 3,
                    sample_rounds: 0,
                    prefix_rounds: 0,
                    fork_sweeps: 0,
                },
            },
            result: None,
        }
    }

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dash-store-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn records_round_trip_through_the_store() {
        let store = SessionStore::open(tempdir("roundtrip")).unwrap();
        let mut rec = record(3);
        store.save(&rec).unwrap();
        assert!(store.contains(3));
        assert_eq!(store.load(3).unwrap(), rec);
        // value bits survive the trip exactly
        rec.snapshot.value = 0.1 + 0.2;
        store.save(&rec).unwrap();
        assert_eq!(
            store.load(3).unwrap().snapshot.value.to_bits(),
            rec.snapshot.value.to_bits()
        );
        // a finished driven lane rides its result along
        rec.result = Some(SelectionResult {
            algorithm: "sds_ma".into(),
            set: vec![4, 9, 2],
            value: rec.snapshot.value,
            rounds: 3,
            queries: 12,
            wall_s: 0.5,
            hit_iteration_cap: false,
            history: Vec::new(),
        });
        store.save(&rec).unwrap();
        assert_eq!(store.load(3).unwrap(), rec);
        store.remove(3);
        assert!(!store.contains(3));
        assert!(store.load(3).is_err());
        store.remove(3); // idempotent
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn mismatched_record_ids_are_backend_errors() {
        let store = SessionStore::open(tempdir("mismatch")).unwrap();
        let rec = record(2);
        // write under a different id than the record claims
        std::fs::write(store.path(5), rec.to_json().to_string_pretty()).unwrap();
        assert!(matches!(store.load(5).unwrap_err(), SelectError::Backend(_)));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn truncated_records_quarantine_and_fail_typed_for_that_id_only() {
        let store = SessionStore::open(tempdir("quarantine")).unwrap();
        store.save(&record(0)).unwrap();
        store.save(&record(1)).unwrap();
        // hand-truncate record 0: the classic crash-during-write leftover
        let full = std::fs::read_to_string(store.path(0)).unwrap();
        std::fs::write(store.path(0), &full[..full.len() / 2]).unwrap();
        // the corrupt id fails typed and its record moves to .quarantine/
        let err = store.load(0).unwrap_err();
        assert!(matches!(err, SelectError::Backend(_)), "{err:?}");
        assert!(err.to_string().contains("quarantined"), "{err}");
        assert!(!store.contains(0), "corrupt record is out of the store");
        let quarantined = store.dir().join(".quarantine").join("session-0.json");
        assert!(quarantined.is_file(), "record kept for post-mortem");
        // a second load of the same id is a plain missing-record error,
        // not a second quarantine
        assert!(store.load(0).is_err());
        // the neighbor record is untouched
        assert_eq!(store.load(1).unwrap(), record(1));
        // list() no longer reports the quarantined id
        assert_eq!(store.list(), vec![1]);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn double_corruption_quarantines_both_copies() {
        let store = SessionStore::open(tempdir("double-quarantine")).unwrap();
        // first corruption: hand-written garbage under id 6
        std::fs::write(store.path(6), "not json at all").unwrap();
        let first = store.load(6).unwrap_err().to_string();
        assert!(first.contains("quarantined"), "{first}");
        // the slot is rewritten with a good record, then corrupts again
        store.save(&record(6)).unwrap();
        std::fs::write(store.path(6), "{\"session\": 6").unwrap();
        let second = store.load(6).unwrap_err().to_string();
        assert!(second.contains("quarantined"), "{second}");
        // both pieces of evidence survive under distinct names
        let qdir = store.dir().join(".quarantine");
        assert_eq!(
            std::fs::read_to_string(qdir.join("session-6.json")).unwrap(),
            "not json at all",
            "the first corruption must not be overwritten"
        );
        assert_eq!(
            std::fs::read_to_string(qdir.join("session-6.1.json")).unwrap(),
            "{\"session\": 6",
            "the second corruption gets a numbered suffix"
        );
        // a third corruption keeps counting
        store.save(&record(6)).unwrap();
        std::fs::write(store.path(6), "third").unwrap();
        assert!(store.load(6).is_err());
        assert!(qdir.join("session-6.2.json").is_file());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn decode_failures_quarantine_too() {
        let store = SessionStore::open(tempdir("decode-quarantine")).unwrap();
        // valid JSON, invalid record (missing every field)
        std::fs::write(store.path(4), "{\"session\": 4}").unwrap();
        let err = store.load(4).unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err}");
        assert!(store.dir().join(".quarantine").join("session-4.json").is_file());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn list_reports_record_ids_and_open_sweeps_stale_tmps() {
        let dir = tempdir("list");
        let store = SessionStore::open(&dir).unwrap();
        assert_eq!(store.list(), Vec::<usize>::new());
        store.save(&record(3)).unwrap();
        store.save(&record(0)).unwrap();
        store.save(&record(11)).unwrap();
        // non-record files are ignored
        std::fs::write(dir.join("notes.txt"), "x").unwrap();
        std::fs::write(dir.join("session-bad.json"), "x").unwrap();
        assert_eq!(store.list(), vec![0, 3, 11]);
        // a crash between write and rename leaves a .json.tmp; reopening
        // the store sweeps it
        let tmp = dir.join("session-7.json.tmp");
        std::fs::write(&tmp, "half a reco").unwrap();
        let store = SessionStore::open(&dir).unwrap();
        assert!(!tmp.exists(), "stale tmp swept on open");
        assert_eq!(store.list(), vec![0, 3, 11]);
        let _ = std::fs::remove_dir_all(store.dir());
    }
}
