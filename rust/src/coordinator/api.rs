//! Typed v1 public API: validating spec builders and the unified error
//! type every public entry point returns.
//!
//! Before this module, the public surface grew organically: `Leader::run`
//! returned `Result<_, String>`, each algorithm exposed its own config
//! struct with `k` duplicated inside, and a malformed job could panic deep
//! inside an objective state. The v1 API fixes the contract:
//!
//! - **[`ProblemSpec`]** — *what* to optimize: dataset, objective, backend,
//!   cardinality `k`, seed. Built through [`ProblemSpec::builder`], which
//!   validates (`k ≥ 1`, `k ≤ n`, objective/backend pairing, A-optimality
//!   priors) and derives the default objective from the dataset's
//!   [`Task`](crate::data::Task).
//! - **[`PlanSpec`]** — *how* to optimize: the algorithm plus its tuning,
//!   subsuming [`AlgorithmChoice`] and the per-algorithm config structs.
//!   Built through [`PlanSpec::builder`] (or the per-algorithm shortcuts
//!   like [`PlanSpec::dash`]); knobs are validated at `build()` and `k` is
//!   resolved from the problem at job-assembly time, so it can never
//!   disagree between the problem and the plan.
//! - **[`SelectError`]** — the one error type. Implements
//!   [`std::error::Error`]; every `Leader` entry point, the serving front,
//!   the wire protocol ([`coordinator::wire`](crate::coordinator::wire)),
//!   and the CLI return it. `From<SelectError> for String` exists so
//!   legacy `Result<_, String>` callers keep composing with `?`.
//!
//! [`SelectionJob::new`] assembles a job from the two specs;
//! [`SelectionJob::validate`] re-checks hand-assembled jobs, and is called
//! by `Leader::run`, `run_many`, and `serve`, so malformed jobs return
//! `Err` — never panic — through every entry point.
//!
//! ```no_run
//! use dash_select::prelude::*;
//! use std::sync::Arc;
//!
//! # fn main() -> Result<(), SelectError> {
//! let mut rng = Pcg64::seed_from(7);
//! let data = Arc::new(synthetic::regression_d1(&mut rng, 400, 500, 100, 0.4));
//! let problem = ProblemSpec::builder(data).k(25).seed(7).build()?;
//! let plan = PlanSpec::dash().epsilon(0.1).alpha(0.75).build()?;
//! let leader = Leader::new();
//! let report = leader.run(&problem.job(&plan))?;
//! println!("f(S) = {:.4} in {} rounds", report.result.value, report.result.rounds);
//! # Ok(())
//! # }
//! ```

use crate::algorithms::{
    AdaptiveSamplingConfig, AdaptiveSequencingConfig, DashConfig, GreedyConfig, LassoConfig,
    OptEstimate,
};
use crate::coordinator::leader::{AlgorithmChoice, Backend, ObjectiveChoice, SelectionJob};
use crate::data::{Dataset, Task};
use std::fmt;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// SelectError
// ---------------------------------------------------------------------------

/// The unified error of the v1 selection API. Every public `Leader`, serve,
/// wire, and CLI entry point returns this; no `Result<_, String>` and no
/// user-input-reachable panic remain on the public surface.
#[derive(Debug, Clone, PartialEq)]
pub enum SelectError {
    /// A spec, builder input, or job failed validation.
    InvalidSpec(String),
    /// A request addressed a session the server does not know.
    UnknownSession(usize),
    /// A generation-pinned request (`insert … if_generation g`) found the
    /// session at a different generation — the client's view was stale.
    StaleGeneration {
        /// generation the request was pinned to
        pinned: u64,
        /// generation the session is actually at
        actual: u64,
    },
    /// The server refused to take on more work (session budget, queue).
    Backpressure(String),
    /// Backend resolution failed (missing artifacts, runtime errors).
    Backend(String),
    /// A structurally valid request was rejected for its target session
    /// (driver-owned lane, out-of-range index, no driver to step, …).
    /// Rejection is per-request: the session and every other client keep
    /// serving.
    Rejected(String),
    /// The caller's serve client closure panicked. The sessions served
    /// and shut down cleanly; the crash is the client's, and is kept
    /// distinct from per-request `Rejected` so retry/alerting logic never
    /// mistakes it for routine traffic rejection.
    ClientPanic(String),
    /// A request exceeded its per-request deadline, or a connection sat
    /// idle past the server's idle timeout. The request fails; the session
    /// itself is untouched and a retry (or a fresh connection) proceeds
    /// normally.
    Deadline(String),
    /// The server loop is gone; all requests fail cleanly, none hang.
    Disconnected,
    /// A wire frame could not be decoded (bad JSON, missing field,
    /// unsupported version, unknown op).
    Protocol(String),
}

impl SelectError {
    /// Shorthand constructor used throughout the builders.
    pub(crate) fn invalid(msg: impl Into<String>) -> SelectError {
        SelectError::InvalidSpec(msg.into())
    }

    /// Stable machine-readable discriminant — the `kind` field of the wire
    /// encoding ([`coordinator::wire`](crate::coordinator::wire)).
    pub fn kind(&self) -> &'static str {
        match self {
            SelectError::InvalidSpec(_) => "invalid_spec",
            SelectError::UnknownSession(_) => "unknown_session",
            SelectError::StaleGeneration { .. } => "stale_generation",
            SelectError::Backpressure(_) => "backpressure",
            SelectError::Backend(_) => "backend",
            SelectError::Rejected(_) => "rejected",
            SelectError::ClientPanic(_) => "client_panic",
            SelectError::Deadline(_) => "deadline",
            SelectError::Disconnected => "disconnected",
            SelectError::Protocol(_) => "protocol",
        }
    }
}

impl fmt::Display for SelectError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SelectError::InvalidSpec(m) => write!(f, "invalid spec: {m}"),
            SelectError::UnknownSession(s) => write!(f, "unknown session {s}"),
            SelectError::StaleGeneration { pinned, actual } => write!(
                f,
                "stale generation: request pinned to generation {pinned}, session is at {actual}"
            ),
            SelectError::Backpressure(m) => write!(f, "backpressure: {m}"),
            SelectError::Backend(m) => write!(f, "backend error: {m}"),
            SelectError::Rejected(m) => write!(f, "request rejected: {m}"),
            SelectError::ClientPanic(m) => write!(f, "serve client closure panicked: {m}"),
            SelectError::Deadline(m) => write!(f, "deadline exceeded: {m}"),
            SelectError::Disconnected => write!(f, "session server disconnected"),
            SelectError::Protocol(m) => write!(f, "protocol error: {m}"),
        }
    }
}

impl std::error::Error for SelectError {}

/// Legacy compatibility: `?` in a `Result<_, String>` context keeps
/// working while callers migrate to the typed error.
impl From<SelectError> for String {
    fn from(e: SelectError) -> String {
        e.to_string()
    }
}

// ---------------------------------------------------------------------------
// ProblemSpec
// ---------------------------------------------------------------------------

/// *What* to optimize: a validated (dataset, objective, backend, k, seed)
/// tuple. Construct through [`ProblemSpec::builder`].
#[derive(Clone)]
pub struct ProblemSpec {
    pub dataset: Arc<Dataset>,
    pub objective: ObjectiveChoice,
    pub backend: Backend,
    pub k: usize,
    pub seed: u64,
}

impl ProblemSpec {
    /// Start building a problem over `dataset`. `k` is required; the
    /// objective defaults to the natural one for the dataset's task
    /// (regression → `Lreg`, binary → `Logistic`, multiclass →
    /// `OvrSoftmax`, design → `Aopt`), backend to native, seed to 1.
    pub fn builder(dataset: Arc<Dataset>) -> ProblemBuilder {
        ProblemBuilder { dataset, objective: None, backend: Backend::Native, k: None, seed: 1 }
    }

    /// Assemble a runnable [`SelectionJob`] from this problem and a plan.
    pub fn job(&self, plan: &PlanSpec) -> SelectionJob {
        SelectionJob::new(self, plan)
    }
}

/// Validating builder for [`ProblemSpec`].
pub struct ProblemBuilder {
    dataset: Arc<Dataset>,
    objective: Option<ObjectiveChoice>,
    backend: Backend,
    k: Option<usize>,
    seed: u64,
}

/// The natural objective for a dataset's task.
pub fn default_objective(ds: &Dataset) -> ObjectiveChoice {
    match ds.task {
        Task::Regression => ObjectiveChoice::Lreg,
        Task::BinaryClassification => ObjectiveChoice::Logistic,
        Task::MultiClassification { .. } => ObjectiveChoice::OvrSoftmax,
        Task::Design => ObjectiveChoice::Aopt { beta_sq: 1.0, sigma_sq: 1.0 },
    }
}

impl ProblemBuilder {
    pub fn objective(mut self, objective: ObjectiveChoice) -> Self {
        self.objective = Some(objective);
        self
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Cardinality constraint (required).
    pub fn k(mut self, k: usize) -> Self {
        self.k = Some(k);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn build(self) -> Result<ProblemSpec, SelectError> {
        let k = self
            .k
            .ok_or_else(|| SelectError::invalid("k (cardinality constraint) is required"))?;
        let objective = self.objective.unwrap_or_else(|| default_objective(&self.dataset));
        validate_problem(&self.dataset, &objective, self.backend, k)?;
        Ok(ProblemSpec { dataset: self.dataset, objective, backend: self.backend, k, seed: self.seed })
    }
}

/// Problem-side checks shared by [`ProblemBuilder::build`] and
/// [`SelectionJob::validate`] — one source of truth, so the two layers can
/// never drift.
pub fn validate_problem(
    dataset: &Dataset,
    objective: &ObjectiveChoice,
    backend: Backend,
    k: usize,
) -> Result<(), SelectError> {
    let n = dataset.n();
    if n == 0 {
        return Err(SelectError::invalid("dataset has no candidate elements"));
    }
    if k == 0 {
        return Err(SelectError::invalid("k must be >= 1"));
    }
    if k > n {
        return Err(SelectError::invalid(format!(
            "k = {k} exceeds the ground set ({n} candidates)"
        )));
    }
    if let ObjectiveChoice::Aopt { beta_sq, sigma_sq } = objective {
        if !(beta_sq.is_finite() && *beta_sq > 0.0) {
            return Err(SelectError::invalid(format!(
                "aopt beta_sq must be finite and > 0, got {beta_sq}"
            )));
        }
        if !(sigma_sq.is_finite() && *sigma_sq > 0.0) {
            return Err(SelectError::invalid(format!(
                "aopt sigma_sq must be finite and > 0, got {sigma_sq}"
            )));
        }
    }
    if backend == Backend::Xla
        && matches!(objective, ObjectiveChoice::R2 | ObjectiveChoice::OvrSoftmax)
    {
        return Err(SelectError::invalid(format!(
            "{objective:?} has no XLA backend (only Lreg, Logistic, Aopt)"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// PlanSpec
// ---------------------------------------------------------------------------

/// The algorithm families of the v1 API. [`PlanKind::parse`] accepts the
/// CLI/wire names (`dash`, `greedy`, `lazy-greedy`, `parallel-greedy`,
/// `topk`, `random`, `lasso`, `adaptive-sampling`, `adaptive-seq`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    Dash,
    Greedy,
    LazyGreedy,
    ParallelGreedy,
    TopK,
    Random,
    Lasso,
    AdaptiveSampling,
    AdaptiveSequencing,
}

impl PlanKind {
    pub fn parse(s: &str) -> Option<PlanKind> {
        match s {
            "dash" => Some(PlanKind::Dash),
            "greedy" => Some(PlanKind::Greedy),
            "lazy-greedy" => Some(PlanKind::LazyGreedy),
            "parallel-greedy" => Some(PlanKind::ParallelGreedy),
            "topk" | "top-k" => Some(PlanKind::TopK),
            "random" => Some(PlanKind::Random),
            "lasso" => Some(PlanKind::Lasso),
            "adaptive-sampling" => Some(PlanKind::AdaptiveSampling),
            "adaptive-seq" => Some(PlanKind::AdaptiveSequencing),
            _ => None,
        }
    }

    /// Canonical CLI/wire name.
    pub fn name(&self) -> &'static str {
        match self {
            PlanKind::Dash => "dash",
            PlanKind::Greedy => "greedy",
            PlanKind::LazyGreedy => "lazy-greedy",
            PlanKind::ParallelGreedy => "parallel-greedy",
            PlanKind::TopK => "topk",
            PlanKind::Random => "random",
            PlanKind::Lasso => "lasso",
            PlanKind::AdaptiveSampling => "adaptive-sampling",
            PlanKind::AdaptiveSequencing => "adaptive-seq",
        }
    }

    /// Whether plans of this kind have a stepwise driver to serve
    /// (`Leader::driver_for`); LASSO and RANDOM only run to completion.
    pub fn has_driver(&self) -> bool {
        !matches!(self, PlanKind::Random | PlanKind::Lasso)
    }

    pub fn all() -> &'static [PlanKind] {
        &[
            PlanKind::Dash,
            PlanKind::Greedy,
            PlanKind::LazyGreedy,
            PlanKind::ParallelGreedy,
            PlanKind::TopK,
            PlanKind::Random,
            PlanKind::Lasso,
            PlanKind::AdaptiveSampling,
            PlanKind::AdaptiveSequencing,
        ]
    }
}

/// *How* to optimize: a validated algorithm + tuning. The cardinality `k`
/// is deliberately absent — it belongs to the [`ProblemSpec`] and is
/// resolved into the per-algorithm config at job assembly, so the two can
/// never disagree.
#[derive(Debug, Clone)]
pub struct PlanSpec {
    kind: PlanKind,
    choice: AlgorithmChoice,
}

impl PlanSpec {
    /// Builder for an explicit kind. Knobs that do not apply to the chosen
    /// algorithm are ignored (documented per knob); values out of range
    /// fail `build()`.
    pub fn builder(kind: PlanKind) -> PlanBuilder {
        PlanBuilder {
            kind,
            epsilon: None,
            alpha: None,
            samples: None,
            r: None,
            max_rounds: None,
            threads: None,
            trials: None,
            serial_prefix: None,
            opt: None,
            min_gain: None,
            lasso: None,
        }
    }

    /// Builder from a CLI/wire algorithm name.
    pub fn parse(name: &str) -> Result<PlanBuilder, SelectError> {
        PlanKind::parse(name)
            .map(PlanSpec::builder)
            .ok_or_else(|| SelectError::invalid(format!("unknown algorithm '{name}'")))
    }

    pub fn dash() -> PlanBuilder {
        PlanSpec::builder(PlanKind::Dash)
    }
    pub fn greedy() -> PlanBuilder {
        PlanSpec::builder(PlanKind::Greedy)
    }
    pub fn lazy_greedy() -> PlanBuilder {
        PlanSpec::builder(PlanKind::LazyGreedy)
    }
    pub fn parallel_greedy() -> PlanBuilder {
        PlanSpec::builder(PlanKind::ParallelGreedy)
    }
    pub fn topk() -> PlanBuilder {
        PlanSpec::builder(PlanKind::TopK)
    }
    pub fn random() -> PlanBuilder {
        PlanSpec::builder(PlanKind::Random)
    }
    pub fn lasso() -> PlanBuilder {
        PlanSpec::builder(PlanKind::Lasso)
    }
    pub fn adaptive_sampling() -> PlanBuilder {
        PlanSpec::builder(PlanKind::AdaptiveSampling)
    }
    pub fn adaptive_seq() -> PlanBuilder {
        PlanSpec::builder(PlanKind::AdaptiveSequencing)
    }

    pub fn kind(&self) -> PlanKind {
        self.kind
    }

    /// The validated algorithm choice (its internal `k` is a placeholder;
    /// [`SelectionJob::new`] resolves the problem's `k` into it).
    pub fn choice(&self) -> &AlgorithmChoice {
        &self.choice
    }

    /// The algorithm choice with the problem's `k` resolved in.
    pub fn algorithm_for(&self, k: usize) -> AlgorithmChoice {
        self.choice.with_k(k)
    }
}

/// Validating builder for [`PlanSpec`]. Every setter is optional; unset
/// knobs take the per-algorithm defaults.
#[derive(Debug, Clone)]
pub struct PlanBuilder {
    kind: PlanKind,
    epsilon: Option<f64>,
    alpha: Option<f64>,
    samples: Option<usize>,
    r: Option<usize>,
    max_rounds: Option<usize>,
    threads: Option<usize>,
    trials: Option<usize>,
    serial_prefix: Option<bool>,
    opt: Option<OptEstimate>,
    min_gain: Option<f64>,
    lasso: Option<LassoConfig>,
}

impl PlanBuilder {
    /// Accuracy parameter ε (DASH, adaptive sampling/sequencing).
    pub fn epsilon(mut self, epsilon: f64) -> Self {
        self.epsilon = Some(epsilon);
        self
    }

    /// Differential-submodularity parameter α (DASH, adaptive sequencing).
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.alpha = Some(alpha);
        self
    }

    /// Expectation-estimate sample count m (DASH, adaptive sampling).
    pub fn samples(mut self, samples: usize) -> Self {
        self.samples = Some(samples);
        self
    }

    /// Outer iterations r; 0 = auto (DASH, adaptive sampling).
    pub fn r(mut self, r: usize) -> Self {
        self.r = Some(r);
        self
    }

    /// Adaptive-round safety cap (DASH, adaptive sampling/sequencing).
    pub fn max_rounds(mut self, max_rounds: usize) -> Self {
        self.max_rounds = Some(max_rounds);
        self
    }

    /// Standalone worker threads (parallel greedy only; a leader's shared
    /// pool supersedes this when the job is served).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Mean-of-trials count (random baseline only).
    pub fn trials(mut self, trials: usize) -> Self {
        self.trials = Some(trials);
        self
    }

    /// Use the reference serial prefix walk (adaptive sequencing only).
    pub fn serial_prefix(mut self, serial: bool) -> Self {
        self.serial_prefix = Some(serial);
        self
    }

    /// OPT estimate: known value or the Appendix G guess ladder (DASH,
    /// adaptive sampling).
    pub fn opt(mut self, opt: OptEstimate) -> Self {
        self.opt = Some(opt);
        self
    }

    /// Early-stop gain threshold (greedy variants only).
    pub fn min_gain(mut self, min_gain: f64) -> Self {
        self.min_gain = Some(min_gain);
        self
    }

    /// Full LASSO path configuration (lasso only).
    pub fn lasso_config(mut self, cfg: LassoConfig) -> Self {
        self.lasso = Some(cfg);
        self
    }

    pub fn build(self) -> Result<PlanSpec, SelectError> {
        let choice = match self.kind {
            PlanKind::Dash => {
                let d = DashConfig::default();
                AlgorithmChoice::Dash(DashConfig {
                    epsilon: self.epsilon.unwrap_or(d.epsilon),
                    alpha: self.alpha.unwrap_or(d.alpha),
                    samples: self.samples.unwrap_or(d.samples),
                    r: self.r.unwrap_or(d.r),
                    max_rounds: self.max_rounds.unwrap_or(d.max_rounds),
                    opt: self.opt.unwrap_or(d.opt),
                    ..d
                })
            }
            PlanKind::Greedy | PlanKind::LazyGreedy => {
                let d = GreedyConfig::default();
                AlgorithmChoice::Greedy(GreedyConfig {
                    min_gain: self.min_gain.unwrap_or(d.min_gain),
                    lazy: self.kind == PlanKind::LazyGreedy,
                    ..d
                })
            }
            PlanKind::ParallelGreedy => {
                let d = GreedyConfig::default();
                AlgorithmChoice::ParallelGreedy {
                    cfg: GreedyConfig {
                        min_gain: self.min_gain.unwrap_or(d.min_gain),
                        lazy: false,
                        ..d
                    },
                    threads: self.threads.unwrap_or(4),
                }
            }
            PlanKind::TopK => AlgorithmChoice::TopK,
            PlanKind::Random => AlgorithmChoice::Random { trials: self.trials.unwrap_or(5) },
            PlanKind::Lasso => AlgorithmChoice::Lasso(self.lasso.unwrap_or_default()),
            PlanKind::AdaptiveSampling => {
                let d = AdaptiveSamplingConfig::default();
                AlgorithmChoice::AdaptiveSampling(AdaptiveSamplingConfig {
                    epsilon: self.epsilon.unwrap_or(d.epsilon),
                    samples: self.samples.unwrap_or(d.samples),
                    r: self.r.unwrap_or(d.r),
                    max_rounds: self.max_rounds.unwrap_or(d.max_rounds),
                    opt: self.opt.unwrap_or(d.opt),
                    ..d
                })
            }
            PlanKind::AdaptiveSequencing => {
                let d = AdaptiveSequencingConfig::default();
                AlgorithmChoice::AdaptiveSequencing(AdaptiveSequencingConfig {
                    epsilon: self.epsilon.unwrap_or(d.epsilon),
                    alpha: self.alpha.unwrap_or(d.alpha),
                    max_rounds: self.max_rounds.unwrap_or(d.max_rounds),
                    serial_prefix: self.serial_prefix.unwrap_or(d.serial_prefix),
                    ..d
                })
            }
        };
        validate_algorithm(&choice)?;
        Ok(PlanSpec { kind: self.kind, choice })
    }
}

/// Range checks for a fully assembled algorithm choice — the single source
/// of truth shared by [`PlanBuilder::build`] and [`SelectionJob::validate`].
pub fn validate_algorithm(alg: &AlgorithmChoice) -> Result<(), SelectError> {
    fn epsilon_in_unit(epsilon: f64) -> Result<(), SelectError> {
        if epsilon.is_finite() && epsilon > 0.0 && epsilon < 1.0 {
            Ok(())
        } else {
            Err(SelectError::invalid(format!("epsilon must be in (0, 1), got {epsilon}")))
        }
    }
    fn alpha_in_unit(alpha: f64) -> Result<(), SelectError> {
        if alpha.is_finite() && alpha > 0.0 && alpha <= 1.0 {
            Ok(())
        } else {
            Err(SelectError::invalid(format!("alpha must be in (0, 1], got {alpha}")))
        }
    }
    fn at_least_one(name: &str, v: usize) -> Result<(), SelectError> {
        if v >= 1 {
            Ok(())
        } else {
            Err(SelectError::invalid(format!("{name} must be >= 1")))
        }
    }
    fn opt_positive(opt: &OptEstimate) -> Result<(), SelectError> {
        match opt {
            OptEstimate::Auto => Ok(()),
            OptEstimate::Known(v) if v.is_finite() && *v > 0.0 => Ok(()),
            OptEstimate::Known(v) => {
                Err(SelectError::invalid(format!("known OPT must be finite and > 0, got {v}")))
            }
        }
    }

    match alg {
        AlgorithmChoice::Dash(c) => {
            epsilon_in_unit(c.epsilon)?;
            alpha_in_unit(c.alpha)?;
            at_least_one("samples", c.samples)?;
            at_least_one("max_rounds", c.max_rounds)?;
            at_least_one("opt_guesses", c.opt_guesses)?;
            opt_positive(&c.opt)
        }
        AlgorithmChoice::Greedy(c) => {
            if c.min_gain.is_finite() && c.min_gain >= 0.0 {
                Ok(())
            } else {
                Err(SelectError::invalid(format!(
                    "min_gain must be finite and >= 0, got {}",
                    c.min_gain
                )))
            }
        }
        AlgorithmChoice::ParallelGreedy { cfg, threads } => {
            at_least_one("threads", *threads)?;
            validate_algorithm(&AlgorithmChoice::Greedy(cfg.clone()))
        }
        AlgorithmChoice::TopK => Ok(()),
        AlgorithmChoice::Random { trials } => at_least_one("trials", *trials),
        AlgorithmChoice::Lasso(c) => {
            at_least_one("path_len", c.path_len)?;
            at_least_one("max_iters", c.max_iters)?;
            if !(c.lambda_min_ratio.is_finite()
                && c.lambda_min_ratio > 0.0
                && c.lambda_min_ratio < 1.0)
            {
                return Err(SelectError::invalid(format!(
                    "lambda_min_ratio must be in (0, 1), got {}",
                    c.lambda_min_ratio
                )));
            }
            if c.tol.is_finite() && c.tol > 0.0 {
                Ok(())
            } else {
                Err(SelectError::invalid(format!("tol must be finite and > 0, got {}", c.tol)))
            }
        }
        AlgorithmChoice::AdaptiveSampling(c) => {
            epsilon_in_unit(c.epsilon)?;
            at_least_one("samples", c.samples)?;
            at_least_one("max_rounds", c.max_rounds)?;
            opt_positive(&c.opt)
        }
        AlgorithmChoice::AdaptiveSequencing(c) => {
            epsilon_in_unit(c.epsilon)?;
            alpha_in_unit(c.alpha)?;
            at_least_one("max_rounds", c.max_rounds)
        }
    }
}

// ---------------------------------------------------------------------------
// SelectionJob assembly + validation
// ---------------------------------------------------------------------------

impl SelectionJob {
    /// Assemble a job from the two validated specs — the one construction
    /// path `Leader::run`, `run_many`, `serve`, the CLI, and the wire
    /// front all share. The problem's `k` is resolved into the plan's
    /// per-algorithm config.
    pub fn new(problem: &ProblemSpec, plan: &PlanSpec) -> SelectionJob {
        SelectionJob {
            dataset: Arc::clone(&problem.dataset),
            objective: problem.objective.clone(),
            backend: problem.backend,
            algorithm: plan.algorithm_for(problem.k),
            k: problem.k,
            seed: problem.seed,
        }
    }

    /// Validate a job (builder-made jobs always pass; hand-assembled
    /// literals are re-checked here, through exactly the builders' own
    /// [`validate_problem`] + [`validate_algorithm`] checks). Called by
    /// every `Leader` entry point, so a malformed job is an `Err`, never
    /// a panic.
    pub fn validate(&self) -> Result<(), SelectError> {
        validate_problem(&self.dataset, &self.objective, self.backend, self.k)?;
        validate_algorithm(&self.algorithm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::rng::Pcg64;

    fn dataset() -> Arc<Dataset> {
        let mut rng = Pcg64::seed_from(1);
        Arc::new(synthetic::regression_d1(&mut rng, 60, 20, 8, 0.3))
    }

    #[test]
    fn problem_builder_validates() {
        let ds = dataset();
        // k required
        let e = ProblemSpec::builder(Arc::clone(&ds)).build().unwrap_err();
        assert!(matches!(e, SelectError::InvalidSpec(_)), "{e}");
        assert!(e.to_string().contains("k"), "{e}");
        // k = 0 and k > n rejected
        assert!(ProblemSpec::builder(Arc::clone(&ds)).k(0).build().is_err());
        let e = ProblemSpec::builder(Arc::clone(&ds)).k(21).build().unwrap_err();
        assert!(e.to_string().contains("exceeds the ground set"), "{e}");
        // defaults: objective from task, native backend, seed 1
        let p = ProblemSpec::builder(Arc::clone(&ds)).k(5).build().unwrap();
        assert_eq!(p.objective, ObjectiveChoice::Lreg);
        assert_eq!(p.backend, Backend::Native);
        assert_eq!(p.seed, 1);
        // invalid aopt priors rejected
        let e = ProblemSpec::builder(Arc::clone(&ds))
            .objective(ObjectiveChoice::Aopt { beta_sq: 0.0, sigma_sq: 1.0 })
            .k(5)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("beta_sq"), "{e}");
        // r2 over xla rejected at build time
        let e = ProblemSpec::builder(ds)
            .objective(ObjectiveChoice::R2)
            .backend(Backend::Xla)
            .k(5)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("no XLA backend"), "{e}");
    }

    #[test]
    fn plan_builder_validates_and_resolves_k() {
        let plan = PlanSpec::dash().epsilon(0.2).alpha(0.5).samples(3).build().unwrap();
        match plan.algorithm_for(7) {
            AlgorithmChoice::Dash(c) => {
                assert_eq!(c.k, 7);
                assert!((c.epsilon - 0.2).abs() < 1e-12);
                assert!((c.alpha - 0.5).abs() < 1e-12);
                assert_eq!(c.samples, 3);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(PlanSpec::dash().epsilon(0.0).build().is_err());
        assert!(PlanSpec::dash().epsilon(1.0).build().is_err());
        assert!(PlanSpec::dash().alpha(1.5).build().is_err());
        assert!(PlanSpec::dash().samples(0).build().is_err());
        assert!(PlanSpec::random().trials(0).build().is_err());
        assert!(PlanSpec::parallel_greedy().threads(0).build().is_err());
        assert!(PlanSpec::adaptive_seq().alpha(0.0).build().is_err());
        // lazy-greedy is the lazy flag, expressed as a kind
        match PlanSpec::lazy_greedy().build().unwrap().algorithm_for(3) {
            AlgorithmChoice::Greedy(c) => assert!(c.lazy),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn plan_parse_covers_every_kind() {
        for kind in PlanKind::all() {
            let b = PlanSpec::parse(kind.name()).unwrap();
            let plan = b.build().unwrap();
            assert_eq!(plan.kind(), *kind);
        }
        let e = PlanSpec::parse("simulated-annealing").unwrap_err();
        assert!(e.to_string().contains("unknown algorithm"), "{e}");
    }

    #[test]
    fn job_assembly_and_validation() {
        let ds = dataset();
        let problem = ProblemSpec::builder(Arc::clone(&ds)).k(5).seed(9).build().unwrap();
        let plan = PlanSpec::greedy().build().unwrap();
        let job = problem.job(&plan);
        assert_eq!(job.k, 5);
        assert_eq!(job.seed, 9);
        job.validate().unwrap();
        // hand-assembled invalid jobs are caught by validate()
        let mut bad = job.clone();
        bad.k = 0;
        assert!(bad.validate().is_err());
        let mut bad = job.clone();
        bad.algorithm = AlgorithmChoice::Random { trials: 0 };
        assert!(bad.validate().is_err());
        // validate applies the builders' full problem checks, pairing
        // included — hand-assembled jobs cannot sidestep them
        let mut bad = job.clone();
        bad.objective = ObjectiveChoice::R2;
        bad.backend = Backend::Xla;
        assert!(bad.validate().unwrap_err().to_string().contains("no XLA backend"));
    }

    #[test]
    fn select_error_is_std_error() {
        fn assert_error<E: std::error::Error>() {}
        assert_error::<SelectError>();
        // String compatibility shim for legacy `?` callers
        let s: String = SelectError::UnknownSession(3).into();
        assert_eq!(s, "unknown session 3");
        assert_eq!(SelectError::Disconnected.kind(), "disconnected");
    }
}
