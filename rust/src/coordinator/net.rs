//! Fault-tolerant socket front for the v1 wire protocol.
//!
//! [`coordinator::wire`](crate::coordinator::wire) defines the protocol
//! and the transport-agnostic [`WireCore`]; this module puts the core on a
//! real socket so selections can be served across process boundaries:
//!
//! - **[`NetServer`]** — a TCP or Unix-socket listener
//!   ([`NetServer::bind`] parses `host:port` and `unix:/path`) serving the
//!   newline-delimited v1 JSON frames. One supervised handler thread per
//!   connection reads frames and forwards them to the single service loop
//!   that owns the [`WireCore`]; replies flow back per-connection, in
//!   order. The core never crosses a thread boundary, so the socket front
//!   and the stdio front are byte-for-byte one code path.
//! - **[`WireClient`]** — a reconnecting client: on a transport fault
//!   (connection refused, reset, truncated reply) it redials with capped
//!   exponential backoff plus seeded jitter and replays the request.
//!   Because wire session ids survive a server restart (the store-backed
//!   core adopts its records on startup), a client that reconnects after a
//!   crash resumes its sessions transparently — selections finish
//!   byte-identical to an uninterrupted run (`tests/net_chaos.rs`,
//!   `tests/net_restart.rs`).
//! - **[`ChaosProxy`]** — a fault-injection TCP forwarder for the test
//!   harness: PCG-seeded schedules of frame truncation, delays, and
//!   mid-request disconnects between a real client and a real server.
//!
//! # Supervision tree and fault model
//!
//! ```text
//! serve() caller thread ── service loop ── owns WireCore (lanes, store)
//!   ├── accept thread ──── nonblocking accept + drain-flag poll
//!   │     ├── handler #1 ─ catch_unwind; frame deadlines; idle timeout
//!   │     ├── handler #2 ─ …
//!   │     └── …
//!   └── mpsc jobs ←──────── (request line, per-request reply channel)
//! ```
//!
//! Per-connection faults are contained at the nearest layer: a malformed
//! frame is answered with a typed `protocol` error; a panic inside request
//! handling is caught by [`WireCore::line`] and answered as `client_panic`;
//! a panic in the handler thread itself is caught by the supervisor
//! wrapper and closes only that connection. A connection that feeds bytes
//! slower than [`NetConfig::request_deadline`] (slow-loris) or goes silent
//! past [`NetConfig::idle_timeout`] is dropped without touching any lane —
//! driven-unfinished lanes stay pinned exactly as under the stdio front.
//!
//! Graceful drain: a `shutdown` frame (or the process's drain flag, see
//! [`drain_flag`]) finishes the in-flight turn, snapshots every evictable
//! lane to the session store, stops accepting, lets each handler finish
//! its current request, and returns — the process exits 0. A fresh server
//! on the same store restores the drained sessions with identical `list`
//! metadata.

use crate::coordinator::api::SelectError;
use crate::coordinator::serve::ServeSummary;
use crate::coordinator::wire::{
    readable_frame_id, ApiReply, ApiRequest, SessionInfo, WireCore, WirePlan, WireProblem,
};
use crate::algorithms::SelectionResult;
use crate::coordinator::session::SessionSnapshot;
use crate::rng::Pcg64;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Address parsing + the transport enums
// ---------------------------------------------------------------------------

/// A bound listening socket: TCP (`host:port`) or Unix (`unix:/path`).
/// Shared by [`NetServer`] and the router front
/// ([`crate::coordinator::router::Router`]) so both fronts bind, accept,
/// and clean up identically.
pub(crate) enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener, PathBuf),
}

impl Listener {
    /// Bind a listener. `unix:/path` binds a Unix socket (an existing
    /// socket file is replaced — stale files from a killed process must
    /// not block restart); anything else is a TCP `host:port` (port `0`
    /// picks a free port).
    pub(crate) fn bind(addr: &str) -> std::io::Result<Listener> {
        match addr.strip_prefix("unix:") {
            Some(path) => {
                let path = PathBuf::from(path);
                if path.exists() {
                    let _ = std::fs::remove_file(&path);
                }
                Ok(Listener::Unix(UnixListener::bind(&path)?, path))
            }
            None => Ok(Listener::Tcp(TcpListener::bind(addr)?)),
        }
    }

    /// The bound address in dialable form: `127.0.0.1:PORT` for TCP
    /// (resolving a port-0 bind), `unix:/path` for Unix sockets.
    pub(crate) fn local_addr(&self) -> String {
        match self {
            Listener::Tcp(l) => match l.local_addr() {
                Ok(a) => a.to_string(),
                Err(_) => "<unbound>".to_string(),
            },
            Listener::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }

    pub(crate) fn set_nonblocking(&self) {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true).ok(),
            Listener::Unix(l, _) => l.set_nonblocking(true).ok(),
        };
    }

    pub(crate) fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }

    /// Remove the socket file of a Unix listener (no-op for TCP) — called
    /// once the accept loop exits so a drained server leaves no stale
    /// socket behind.
    pub(crate) fn cleanup(&self) {
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// One accepted (or dialed) connection over either transport.
pub(crate) enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    pub(crate) fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    pub(crate) fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t),
            Stream::Unix(s) => s.set_read_timeout(t),
        }
    }

    pub(crate) fn set_write_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(t),
            Stream::Unix(s) => s.set_write_timeout(t),
        }
    }

    pub(crate) fn shutdown(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Dial one connection to `addr` (`host:port` or `unix:/path`).
pub(crate) fn dial(addr: &str) -> std::io::Result<Stream> {
    match addr.strip_prefix("unix:") {
        Some(path) => UnixStream::connect(path).map(Stream::Unix),
        None => TcpStream::connect(addr).map(Stream::Tcp),
    }
}

// ---------------------------------------------------------------------------
// Configuration, counters, summary
// ---------------------------------------------------------------------------

/// Robustness knobs of the socket front.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Per-request budget, applied twice per request: a frame whose bytes
    /// trickle in slower than this is dropped (slow-loris), and a request
    /// whose reply takes longer than this is answered with a typed
    /// `deadline` error.
    pub request_deadline: Duration,
    /// A connection with no traffic (not even partial frames) for this
    /// long is closed. Lanes are untouched; the client reconnects and
    /// resumes by session id.
    pub idle_timeout: Duration,
    /// Frames larger than this are answered with a `protocol` error and
    /// the connection is dropped — a byte-flood cannot balloon memory.
    pub max_frame_len: usize,
    /// Poll granularity of the accept loop, handler read loops, and the
    /// service loop's drain check.
    pub poll_tick: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            request_deadline: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(60),
            max_frame_len: 1 << 20,
            poll_tick: Duration::from_millis(20),
        }
    }
}

/// Shared traffic counters (handlers increment, summary reads).
#[derive(Default)]
struct NetCounters {
    connections: AtomicU64,
    requests: AtomicU64,
    deadlines: AtomicU64,
    handler_panics: AtomicU64,
}

/// What a [`NetServer::serve`] loop did before it drained.
#[derive(Debug)]
pub struct NetSummary {
    /// connections accepted over the server's lifetime
    pub connections: u64,
    /// request frames forwarded to the core
    pub requests: u64,
    /// requests answered with a typed `deadline` error (reply overran
    /// the budget) plus slow-loris frame drops
    pub deadlines: u64,
    /// handler threads that panicked and were reaped by the supervisor
    /// (connection closed, server intact)
    pub handler_panics: u64,
    /// panics contained inside request handling ([`WireCore::line`])
    pub contained_panics: u64,
    /// lane evictions over the core's lifetime (drain included)
    pub evictions: u64,
    /// lane restores over the core's lifetime
    pub restores: u64,
    /// the serving core's own traffic summary
    pub serve: ServeSummary,
}

/// The job a handler forwards to the service loop: one raw request line
/// plus the channel its reply line goes back on.
struct Job {
    line: String,
    reply: mpsc::Sender<String>,
}

// ---------------------------------------------------------------------------
// Drain signal plumbing
// ---------------------------------------------------------------------------

static DRAIN: AtomicBool = AtomicBool::new(false);

extern "C" fn drain_on_signal(_sig: i32) {
    DRAIN.store(true, Ordering::SeqCst);
}

/// The process-wide drain flag. [`NetServer::serve`] polls it; once set,
/// the server stops accepting, finishes in-flight turns, snapshots every
/// evictable lane to the store, and returns.
pub fn drain_flag() -> &'static AtomicBool {
    &DRAIN
}

/// Install SIGINT/SIGTERM handlers that set [`drain_flag`] — the signal
/// half of graceful drain (`kill -TERM` behaves like a `shutdown` frame).
/// Uses the raw libc `signal` entry point so no new dependency is needed.
pub fn install_drain_signals() -> &'static AtomicBool {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let handler = drain_on_signal as extern "C" fn(i32) as usize;
    // SAFETY: `signal` is the POSIX entry point with the documented
    // (int, handler) -> handler signature; the handler only stores to an
    // AtomicBool, which is async-signal-safe.
    unsafe {
        signal(2, handler); // SIGINT
        signal(15, handler); // SIGTERM
    }
    &DRAIN
}

// ---------------------------------------------------------------------------
// NetServer
// ---------------------------------------------------------------------------

/// The socket serving front: accepts TCP or Unix-socket connections and
/// pumps their frames through one [`WireCore`] under per-connection
/// supervision — see the module docs for the full fault model.
pub struct NetServer {
    listener: Listener,
    config: NetConfig,
    stop: &'static AtomicBool,
}

impl NetServer {
    /// Bind a listener. `unix:/path` binds a Unix socket (an existing
    /// socket file is replaced — stale files from a killed process must
    /// not block restart); anything else is a TCP `host:port` (port `0`
    /// picks a free port; see [`NetServer::local_addr`]).
    pub fn bind(addr: &str) -> std::io::Result<NetServer> {
        let listener = Listener::bind(addr)?;
        Ok(NetServer { listener, config: NetConfig::default(), stop: drain_flag() })
    }

    /// Replace the robustness knobs (deadlines, idle timeout, frame cap).
    pub fn with_config(mut self, config: NetConfig) -> NetServer {
        self.config = config;
        self
    }

    /// Use a caller-owned drain flag instead of the process-wide
    /// [`drain_flag`] — tests leak one `AtomicBool` per server so
    /// concurrent servers drain independently.
    pub fn with_stop_flag(mut self, stop: &'static AtomicBool) -> NetServer {
        self.stop = stop;
        self
    }

    /// The bound address in dialable form: `127.0.0.1:PORT` for TCP
    /// (resolving a port-0 bind), `unix:/path` for Unix sockets.
    pub fn local_addr(&self) -> String {
        self.listener.local_addr()
    }

    /// Serve until drained: accept connections, pump every frame through
    /// `core`, and stop on a `shutdown` frame or the drain flag. The core
    /// lives on this caller thread for the whole serve — handlers only
    /// ever exchange strings with it — so objective state never crosses a
    /// thread boundary. Returns once every handler has finished its
    /// in-flight request and all evictable lanes are snapshotted.
    pub fn serve(self, mut core: WireCore) -> std::io::Result<NetSummary> {
        let NetServer { listener, config, stop } = self;
        let counters = Arc::new(NetCounters::default());
        let stopping = Arc::new(AtomicBool::new(false));
        let (jobs_tx, jobs_rx) = mpsc::channel::<Job>();

        let accept_counters = Arc::clone(&counters);
        let accept_stopping = Arc::clone(&stopping);
        let accept = std::thread::spawn(move || {
            accept_loop(listener, config, jobs_tx, accept_stopping, accept_counters);
        });

        // the service loop: the single thread that touches the core
        loop {
            if stop.load(Ordering::SeqCst) && !core.draining() {
                core.drain();
            }
            if core.draining() {
                stopping.store(true, Ordering::SeqCst);
            }
            match jobs_rx.recv_timeout(config.poll_tick) {
                Ok(job) => {
                    counters.requests.fetch_add(1, Ordering::Relaxed);
                    let reply = core.line(&job.line);
                    // a dropped receiver (deadline fired, handler gone) is
                    // routine: the reply is stale and falls on the floor
                    let _ = job.reply.send(reply);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                // all handler + accept senders gone: every in-flight turn
                // is finished and queued work is drained
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let _ = accept.join();
        core.drain();

        Ok(NetSummary {
            connections: counters.connections.load(Ordering::Relaxed),
            requests: counters.requests.load(Ordering::Relaxed),
            deadlines: counters.deadlines.load(Ordering::Relaxed),
            handler_panics: counters.handler_panics.load(Ordering::Relaxed),
            contained_panics: core.contained_panics,
            evictions: core.evictions,
            restores: core.restores,
            serve: core.summary(),
        })
    }
}

/// Accept loop: nonblocking accept, polling the stop flag between
/// attempts, one supervised handler thread per connection. Exits (and
/// drops its job sender) once stopping is set.
fn accept_loop(
    listener: Listener,
    config: NetConfig,
    jobs_tx: mpsc::Sender<Job>,
    stopping: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
) {
    listener.set_nonblocking();
    let mut handlers = Vec::new();
    while !stopping.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok(stream) => {
                counters.connections.fetch_add(1, Ordering::Relaxed);
                let tx = jobs_tx.clone();
                let stop = Arc::clone(&stopping);
                let ctr = Arc::clone(&counters);
                handlers.push(std::thread::spawn(move || {
                    // supervision: a panic in our own handler code reaps
                    // this connection only — the listener, the service
                    // loop, and every other connection keep serving
                    let supervised = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || handle_connection(stream, config, tx, stop, Arc::clone(&ctr)),
                    ));
                    if supervised.is_err() {
                        ctr.handler_panics.fetch_add(1, Ordering::Relaxed);
                    }
                }));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                std::thread::sleep(config.poll_tick);
            }
            // a failed accept (fd pressure, aborted handshake) must not
            // kill the listener; back off one tick and keep accepting
            Err(_) => std::thread::sleep(config.poll_tick),
        }
        handlers.retain(|h| !h.is_finished());
    }
    // stop observed: wait for every handler to finish its in-flight
    // request before releasing the job channel
    for h in handlers {
        let _ = h.join();
    }
    listener.cleanup();
}

/// One connection: read newline-delimited frames under the idle/deadline
/// budget, forward each to the service loop, write back one reply line
/// per frame, in order.
fn handle_connection(
    stream: Stream,
    config: NetConfig,
    jobs_tx: mpsc::Sender<Job>,
    stopping: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
) {
    let _ = stream.set_read_timeout(Some(config.poll_tick));
    let _ = stream.set_write_timeout(Some(config.request_deadline));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut frame_started: Option<Instant> = None;
    let mut last_activity = Instant::now();

    // answer with a typed error frame, then drop the connection
    let refuse = |writer: &mut Stream, buf: &[u8], error: SelectError| {
        let id = readable_frame_id(&String::from_utf8_lossy(buf));
        let line = ApiReply::Error { error }.encode(id);
        let _ = writeln!(writer, "{line}").and_then(|_| writer.flush());
    };

    loop {
        if stopping.load(Ordering::SeqCst) && buf.is_empty() {
            break; // graceful drain: no frame in flight, close
        }
        let before = buf.len();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break, // EOF (a trailing partial frame is dropped)
            Ok(_) if buf.ends_with(b"\n") => {
                last_activity = Instant::now();
                frame_started = None;
                if buf.len() > config.max_frame_len {
                    refuse(
                        &mut writer,
                        &buf,
                        SelectError::Protocol(format!(
                            "frame of {} bytes exceeds the {}-byte cap",
                            buf.len(),
                            config.max_frame_len
                        )),
                    );
                    break;
                }
                let line = String::from_utf8_lossy(&buf).trim().to_string();
                if !line.is_empty() {
                    let (reply_tx, reply_rx) = mpsc::channel();
                    if jobs_tx.send(Job { line: line.clone(), reply: reply_tx }).is_err() {
                        break; // service loop gone (drained)
                    }
                    match reply_rx.recv_timeout(config.request_deadline) {
                        Ok(reply) => {
                            if writeln!(writer, "{reply}").and_then(|_| writer.flush()).is_err() {
                                break; // client gone mid-reply
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            counters.deadlines.fetch_add(1, Ordering::Relaxed);
                            refuse(
                                &mut writer,
                                line.as_bytes(),
                                SelectError::Deadline(format!(
                                    "request exceeded the {:?} deadline",
                                    config.request_deadline
                                )),
                            );
                            // the late reply, when it lands, hits a dropped
                            // channel and falls on the floor; this client's
                            // view stays frame-aligned
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                buf.clear();
            }
            Ok(_) => {
                // partial frame (no delimiter yet, not EOF); clock it
                if frame_started.is_none() && buf.len() > before {
                    frame_started = Some(Instant::now());
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if !buf.is_empty() && frame_started.is_none() {
                    frame_started = Some(Instant::now());
                }
                if buf.len() > config.max_frame_len {
                    refuse(
                        &mut writer,
                        &buf,
                        SelectError::Protocol(format!(
                            "frame of {} bytes exceeds the {}-byte cap",
                            buf.len(),
                            config.max_frame_len
                        )),
                    );
                    break;
                }
                // slow-loris: a frame trickling in past the deadline is
                // refused; the lane it would have addressed is untouched
                if let Some(t0) = frame_started {
                    if t0.elapsed() > config.request_deadline {
                        counters.deadlines.fetch_add(1, Ordering::Relaxed);
                        refuse(
                            &mut writer,
                            &buf,
                            SelectError::Deadline(format!(
                                "frame incomplete after the {:?} deadline",
                                config.request_deadline
                            )),
                        );
                        break;
                    }
                }
                if buf.is_empty() && last_activity.elapsed() > config.idle_timeout {
                    break; // idle connection: close without a reply owed
                }
            }
            Err(_) => break, // reset, aborted, …: the connection is gone
        }
    }
    reader.into_inner().shutdown();
}

// ---------------------------------------------------------------------------
// WireClient — reconnecting client with capped backoff + jitter
// ---------------------------------------------------------------------------

/// Retry policy of a [`WireClient`].
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Transport-fault attempts per request before giving up
    /// ([`SelectError::Disconnected`]).
    pub max_attempts: usize,
    /// First backoff sleep; doubles per attempt.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 10,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
        }
    }
}

/// A v1 wire client over TCP or Unix sockets that treats transport faults
/// as retryable: a refused dial, a reset, a truncated or garbled reply
/// each tear the connection down, back off (exponential, capped, with
/// PCG-seeded jitter so reconnect stampedes decorrelate), redial, and
/// replay the request.
///
/// Replay gives **at-least-once** delivery: a request whose reply was lost
/// may have applied. Every v1 op is safe under that contract except
/// `step` — reads (`sweep`/`metrics`/`list`/`ping`) are pure, unpinned
/// `insert` is a set-union no-op on replay, pinned `insert` answers the
/// replay with a typed `stale_generation`, `close` answers
/// `unknown_session`, and `finish` re-serves the recorded result — while a
/// replayed `step` could advance the driver twice. Clients stepping driven
/// lanes through chaos should treat a `step` retry as forking the
/// schedule (the chaos harness drives undriven lanes for exactly this
/// reason).
pub struct WireClient {
    addr: String,
    conn: Option<BufReader<Stream>>,
    next_id: u64,
    policy: RetryPolicy,
    rng: Pcg64,
    /// reconnects performed over this client's lifetime (observability
    /// for the chaos harness and the soak)
    pub reconnects: u64,
}

impl WireClient {
    /// Create a client for `addr` (`host:port` or `unix:/path`). Dialing
    /// is lazy — the first request connects, with the same backoff as any
    /// reconnect, so a client racing a restarting server just works.
    pub fn connect(addr: &str, seed: u64) -> WireClient {
        WireClient {
            addr: addr.to_string(),
            conn: None,
            next_id: 0,
            policy: RetryPolicy::default(),
            rng: Pcg64::seed_from(seed ^ 0x57ff_c1e7),
            reconnects: 0,
        }
    }

    /// Replace the retry policy.
    pub fn with_policy(mut self, policy: RetryPolicy) -> WireClient {
        self.policy = policy;
        self
    }

    /// Sleep the capped-exponential backoff for `attempt` (0-based), with
    /// multiplicative jitter in `[0.5, 1.0)`.
    fn backoff(&mut self, attempt: usize) {
        let exp = self.policy.base_backoff.as_secs_f64() * (1u64 << attempt.min(20)) as f64;
        let capped = exp.min(self.policy.max_backoff.as_secs_f64());
        let jittered = capped * self.rng.gen_range_f64(0.5, 1.0);
        std::thread::sleep(Duration::from_secs_f64(jittered));
    }

    /// One full send/receive exchange over an established connection.
    /// Takes the connection as one borrow for both the write and the read
    /// halves, so there is no re-borrow (and no `expect`) between them.
    fn exchange(
        conn: &mut BufReader<Stream>,
        line: &str,
        id: u64,
    ) -> Result<ApiReply, std::io::Error> {
        let stream = conn.get_mut();
        writeln!(stream, "{line}")?;
        stream.flush()?;
        let mut reply = String::new();
        let n = conn.read_line(&mut reply)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection before replying",
            ));
        }
        let garbled = |what: String| std::io::Error::new(std::io::ErrorKind::InvalidData, what);
        let (reply_id, reply) =
            ApiReply::decode(&reply).map_err(|e| garbled(format!("garbled reply: {e}")))?;
        if reply_id != id {
            // can only happen if a previous reply was half-consumed; the
            // stream is no longer frame-aligned, so treat it as transport
            return Err(garbled(format!("reply id {reply_id} for request {id}")));
        }
        Ok(reply)
    }

    /// One send/receive attempt: dial if disconnected, exchange, and on
    /// **any** I/O or framing error — dial-side, write-side, or read-side
    /// — tear the connection down before returning, so the next attempt
    /// always redials instead of reusing a stream with a half-written
    /// frame on it.
    fn attempt(&mut self, line: &str, id: u64) -> Result<ApiReply, std::io::Error> {
        let result = match self.conn.as_mut() {
            Some(conn) => Self::exchange(conn, line, id),
            None => match dial(&self.addr) {
                Ok(stream) => {
                    let conn = self.conn.insert(BufReader::new(stream));
                    Self::exchange(conn, line, id)
                }
                Err(e) => Err(e),
            },
        };
        if result.is_err() {
            if let Some(conn) = self.conn.take() {
                conn.into_inner().shutdown();
            }
        }
        result
    }

    /// Send one request, reconnect-and-replay on transport faults, and
    /// return the server's typed reply (or the error the server answered
    /// with). Exhausted retries are [`SelectError::Disconnected`].
    pub fn request(&mut self, req: &ApiRequest) -> Result<ApiReply, SelectError> {
        self.next_id += 1;
        let id = self.next_id;
        let line = req.encode(id);
        for attempt in 0..self.policy.max_attempts {
            if attempt > 0 {
                self.backoff(attempt - 1);
            }
            match self.attempt(&line, id) {
                Ok(ApiReply::Error { error }) => return Err(error),
                Ok(reply) => return Ok(reply),
                Err(_) => {
                    // transport fault: `attempt` already tore the
                    // connection down, so the next loop iteration redials
                    self.reconnects += 1;
                }
            }
        }
        Err(SelectError::Disconnected)
    }

    /// Whether the client currently holds an established connection
    /// (observability for tests and the router's worker pool).
    pub fn is_connected(&self) -> bool {
        self.conn.is_some()
    }

    /// `ping` → liveness.
    pub fn ping(&mut self) -> Result<(), SelectError> {
        match self.request(&ApiRequest::Ping)? {
            ApiReply::Pong => Ok(()),
            other => Err(unexpected("pong", &other)),
        }
    }

    /// `open` → new session id.
    pub fn open(
        &mut self,
        problem: WireProblem,
        plan: WirePlan,
        driven: bool,
        tenant: Option<String>,
    ) -> Result<usize, SelectError> {
        match self.request(&ApiRequest::Open { problem, plan, driven, tenant, session: None })? {
            ApiReply::Opened { session } => Ok(session),
            other => Err(unexpected("opened", &other)),
        }
    }

    /// `open` pinned to an exact session id — the router's allocation
    /// token: the server installs the session at `session` or rejects if
    /// the id is already in use (see `ApiRequest::Open`).
    pub fn open_pinned(
        &mut self,
        problem: WireProblem,
        plan: WirePlan,
        driven: bool,
        tenant: Option<String>,
        session: usize,
    ) -> Result<usize, SelectError> {
        let req = ApiRequest::Open { problem, plan, driven, tenant, session: Some(session) };
        match self.request(&req)? {
            ApiReply::Opened { session } => Ok(session),
            other => Err(unexpected("opened", &other)),
        }
    }

    /// `list` → rows for every open session.
    pub fn list(&mut self) -> Result<Vec<SessionInfo>, SelectError> {
        match self.request(&ApiRequest::List)? {
            ApiReply::Sessions { sessions } => Ok(sessions),
            other => Err(unexpected("sessions", &other)),
        }
    }

    /// `close` → drop the session.
    pub fn close(&mut self, session: usize) -> Result<(), SelectError> {
        match self.request(&ApiRequest::Close { session })? {
            ApiReply::Closed { .. } => Ok(()),
            other => Err(unexpected("closed", &other)),
        }
    }

    /// `sweep` → `(gains, generation, fresh)`.
    pub fn sweep(
        &mut self,
        session: usize,
        candidates: Vec<usize>,
    ) -> Result<(Vec<f64>, u64, usize), SelectError> {
        match self.request(&ApiRequest::Sweep { session, candidates })? {
            ApiReply::Swept { gains, generation, fresh } => Ok((gains, generation, fresh)),
            other => Err(unexpected("swept", &other)),
        }
    }

    /// `insert` → `(grew, generation)`.
    pub fn insert(
        &mut self,
        session: usize,
        item: usize,
        if_generation: Option<u64>,
    ) -> Result<(bool, u64), SelectError> {
        match self.request(&ApiRequest::Insert { session, item, if_generation })? {
            ApiReply::Inserted { grew, generation } => Ok((grew, generation)),
            other => Err(unexpected("inserted", &other)),
        }
    }

    /// `step` → `(done, generation)`. Not replay-safe; see the type docs.
    pub fn step(&mut self, session: usize) -> Result<(bool, u64), SelectError> {
        match self.request(&ApiRequest::Step { session })? {
            ApiReply::Stepped { done, generation } => Ok((done, generation)),
            other => Err(unexpected("stepped", &other)),
        }
    }

    /// `finish` → the session's final [`SelectionResult`].
    pub fn finish(&mut self, session: usize) -> Result<SelectionResult, SelectError> {
        match self.request(&ApiRequest::Finish { session })? {
            ApiReply::Finished { result } => Ok(result),
            other => Err(unexpected("finished", &other)),
        }
    }

    /// `metrics` → the session's [`SessionSnapshot`].
    pub fn metrics(&mut self, session: usize) -> Result<SessionSnapshot, SelectError> {
        match self.request(&ApiRequest::Metrics { session })? {
            ApiReply::Snapshot { snapshot } => Ok(snapshot),
            other => Err(unexpected("snapshot", &other)),
        }
    }

    /// `shutdown` → graceful drain; returns how many lanes the server
    /// persisted.
    pub fn shutdown(&mut self) -> Result<usize, SelectError> {
        match self.request(&ApiRequest::Shutdown)? {
            ApiReply::Stopping { persisted } => Ok(persisted),
            other => Err(unexpected("stopping", &other)),
        }
    }
}

fn unexpected(wanted: &str, got: &ApiReply) -> SelectError {
    SelectError::Protocol(format!("expected '{wanted}' reply, got '{}'", got.op()))
}

// ---------------------------------------------------------------------------
// ChaosProxy — fault-injection forwarder for the test harness
// ---------------------------------------------------------------------------

/// Fault probabilities of a [`ChaosProxy`], applied independently per
/// forwarded chunk in each direction.
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    /// chance to truncate the chunk (forward a prefix, then drop the
    /// connection) — produces exactly the half-written frames the server
    /// must refuse or time out
    pub p_truncate: f64,
    /// chance to drop the connection before forwarding the chunk
    /// (mid-request disconnect)
    pub p_disconnect: f64,
    /// chance to delay the chunk
    pub p_delay: f64,
    /// delay magnitude ceiling, milliseconds
    pub max_delay_ms: u64,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig { p_truncate: 0.05, p_disconnect: 0.05, p_delay: 0.15, max_delay_ms: 5 }
    }
}

/// A PCG-seeded fault-injection TCP proxy: accepts connections and pumps
/// bytes to `target`, injecting truncation, delays, and disconnects per
/// [`ChaosConfig`]. The schedule is fully determined by the seed and the
/// connection order, so a failing chaos run replays from its seed.
pub struct ChaosProxy {
    addr: String,
    stopping: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ChaosProxy {
    /// Start a proxy on an ephemeral localhost port forwarding to
    /// `target` (TCP `host:port`).
    pub fn start(target: &str, seed: u64, config: ChaosConfig) -> std::io::Result<ChaosProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stopping = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&stopping);
        let target = target.to_string();
        let accept = std::thread::spawn(move || {
            let mut conn_seq: u64 = 0;
            let mut pumps = Vec::new();
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((client, _)) => {
                        conn_seq += 1;
                        let Ok(server) = TcpStream::connect(&target) else {
                            continue; // server down: refuse by dropping
                        };
                        // independent deterministic schedules per
                        // connection and direction
                        let tx_rng = Pcg64::seed_from(seed ^ (conn_seq << 1));
                        let rx_rng = Pcg64::seed_from(seed ^ (conn_seq << 1) ^ 1);
                        let (Ok(c2), Ok(s2)) = (client.try_clone(), server.try_clone()) else {
                            continue;
                        };
                        pumps.push(std::thread::spawn(move || {
                            pump(client, server, config, tx_rng);
                        }));
                        pumps.push(std::thread::spawn(move || {
                            pump(s2, c2, config, rx_rng);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
                pumps.retain(|p| !p.is_finished());
            }
            for p in pumps {
                let _ = p.join();
            }
        });
        Ok(ChaosProxy { addr, stopping, accept: Some(accept) })
    }

    /// The proxy's dialable `host:port`.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting and reap the pump threads. In-flight connections
    /// are cut.
    pub fn stop(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Pump bytes `from` → `to`, rolling the fault dice per chunk.
fn pump(from: TcpStream, mut to: TcpStream, config: ChaosConfig, mut rng: Pcg64) {
    let mut from = from;
    let _ = from.set_read_timeout(Some(Duration::from_millis(50)));
    let mut chunk = [0u8; 4096];
    loop {
        match from.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                if rng.bernoulli(config.p_disconnect) {
                    break; // cut before the bytes land: mid-request loss
                }
                if rng.bernoulli(config.p_delay) {
                    let ms = rng.gen_range_usize(0, config.max_delay_ms.max(1) as usize + 1);
                    std::thread::sleep(Duration::from_millis(ms as u64));
                }
                if rng.bernoulli(config.p_truncate) {
                    // forward a strict prefix, then cut: a half-frame
                    let cut = rng.gen_range_usize(0, n);
                    if cut > 0 && to.write_all(&chunk[..cut]).is_ok() {
                        let _ = to.flush();
                    }
                    break;
                }
                if to.write_all(&chunk[..n]).is_err() {
                    break;
                }
                let _ = to.flush();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
    let _ = from.shutdown(std::net::Shutdown::Both);
    let _ = to.shutdown(std::net::Shutdown::Both);
}
