//! L3 coordinator: the serving-side orchestration around the selection
//! algorithms — request batching, a leader that owns job lifecycle, worker
//! fan-out for oracle sweeps, and a metrics registry.
//!
//! The paper's contribution is a *parallel query schedule*; this module is
//! the machinery that realizes it as a deployable service: experiment
//! drivers and the CLI submit [`SelectionJob`]s to the [`Leader`], which
//! resolves datasets/objectives/backends, executes the algorithm, and
//! returns a machine-readable [`SelectionReport`].
//!
//! Between the leader and the algorithms sits the [`session`] subsystem:
//! a [`SelectionSession`] owns one objective state behind a monotonic
//! [`Generation`] plus a generation-keyed gain cache, and every algorithm
//! is a stepwise [`SessionDriver`] over it — which is what lets the leader
//! multiplex many concurrent selections over one oracle pool
//! ([`Leader::run_many`]).
//!
//! On top of the sessions sits the [`serve`] subsystem: a [`SessionServer`]
//! serves live sessions to many concurrent clients over cloneable
//! [`SessionClient`] handles, coalescing same-generation sweep requests
//! into single pooled rounds with generation-stamped replies and
//! bounded-queue backpressure ([`Leader::serve`] spins the loop on the
//! shared pool).
//!
//! The public face of all of it is the typed v1 API: the [`api`] module's
//! validating spec builders ([`ProblemSpec`], [`PlanSpec`]) and unified
//! [`SelectError`], and the [`wire`] module's versioned JSON protocol
//! ([`ApiRequest`]/[`ApiReply`]) serving the same turns over
//! `dash serve --stdio` that [`SessionClient`] serves in-process.

pub mod api;
mod batcher;
mod leader;
mod metrics;
pub mod net;
pub mod router;
pub mod serve;
pub mod session;
pub mod store;
pub mod wire;

pub use api::{
    default_objective, validate_algorithm, validate_problem, PlanBuilder, PlanKind, PlanSpec,
    ProblemBuilder, ProblemSpec, SelectError,
};
pub use batcher::{BatchQueue, BatchQueueConfig};
pub use leader::{
    AlgorithmChoice, Backend, Leader, ObjectiveChoice, SelectionJob, SelectionReport, ServeSpec,
};
pub use metrics::MetricsRegistry;
pub use serve::{
    ServeConfig, ServeMetrics, ServeReply, ServeRequest, ServeSummary, SessionClient, SessionId,
    SessionServer, SweptGains,
};
pub use session::{
    drive, Generation, ObjectiveHandle, SelectionSession, SessionDriver, SessionMetrics,
    SessionSnapshot, SessionSweep, StepOutcome,
};
pub use net::{
    drain_flag, install_drain_signals, ChaosConfig, ChaosProxy, NetConfig, NetServer, NetSummary,
    RetryPolicy, WireClient,
};
pub use router::{place, Router, RouterConfig, RouterSummary};
pub use store::{SessionRecord, SessionStore};
pub use wire::{
    ApiReply, ApiRequest, DatasetCache, SessionInfo, StdioServer, WireCore, WirePlan, WireProblem,
    DEFAULT_TENANT, MAX_WIRE_INT, WIRE_VERSION,
};
