//! L3 coordinator: the serving-side orchestration around the selection
//! algorithms — request batching, a leader that owns job lifecycle, worker
//! fan-out for oracle sweeps, and a metrics registry.
//!
//! The paper's contribution is a *parallel query schedule*; this module is
//! the machinery that realizes it as a deployable service: experiment
//! drivers and the CLI submit [`SelectionJob`]s to the [`Leader`], which
//! resolves datasets/objectives/backends, executes the algorithm, and
//! returns a machine-readable [`SelectionReport`].

mod batcher;
mod leader;
mod metrics;

pub use batcher::{BatchQueue, BatchQueueConfig};
pub use leader::{AlgorithmChoice, Backend, Leader, ObjectiveChoice, SelectionJob, SelectionReport};
pub use metrics::MetricsRegistry;
