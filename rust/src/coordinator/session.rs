//! Selection sessions: long-lived, generation-aware serving state between
//! the coordinator and the algorithms.
//!
//! The paper's framework earns its logarithmic parallel runtime only if
//! *every* round of queries — greedy sweeps, DASH's sample/filter rounds,
//! adaptive sequencing's prefix evaluations — hits the batched oracle. The
//! ROADMAP's serving goal additionally needs selection state that outlives
//! a single `run()` call. A [`SelectionSession`] is the abstraction both
//! share:
//!
//! - it owns **one** objective state behind a monotonically increasing
//!   [`Generation`];
//! - it owns a generation-keyed [`GainCache`]: entries are stamped with the
//!   generation they were computed at, and [`SelectionSession::insert`]
//!   bumps the generation, which *logically* invalidates the whole cache in
//!   O(1) — no clearing pass, no queue rebuild — so the session keeps
//!   serving sweeps across inserts;
//! - it shares the process-wide [`BatchExecutor`], so concurrent sessions
//!   multiplexed by the [`Leader`](crate::coordinator::Leader) fan their
//!   sweeps out over one pool;
//! - it records per-session [`SessionMetrics`].
//!
//! # The generation contract
//!
//! Every mutation of the solution set goes through
//! [`SelectionSession::insert`] (or [`SelectionSession::commit`], its batch
//! form). Each successful insert bumps the generation and invalidates the
//! cache, so a gain computed against generation `g` can never be served at
//! generation `g' > g`: stale-generation cache hits are impossible by
//! construction (`tests/session.rs` proves this). Reads
//! ([`SelectionSession::sweep`]) report exactly how many oracle queries
//! they freshly issued, so algorithm-side query accounting stays equal to
//! the oracle-observed count — the same reported == observed invariant
//! `tests/executor_audit.rs` enforces on the engine.
//!
//! # Stepwise drivers
//!
//! Algorithms are [`SessionDriver`]s: instead of owning a closed
//! run-to-completion loop, each drives a session one adaptive round at a
//! time (`sweep() → filter/sample → commit(insert)`), returning
//! [`StepOutcome::Continue`] until it is done. [`drive`] runs a driver to
//! completion (what every algorithm's `run()` does); the `Leader`
//! interleaves `step()` calls across many sessions to multiplex concurrent
//! jobs over one pool ([`Leader::run_many`](crate::coordinator::Leader::run_many)).
//! Drivers expect a fresh (empty) session and are deterministic given the
//! session's objective and their `Pcg64`, so an interleaved schedule is
//! byte-identical to running each session alone.
//!
//! # Prefix-parallel adaptive sequencing
//!
//! [`SelectionSession::prefix_gains`] implements the paper's §1.2 prefix
//! round: materialize the sampled sequence's prefix states `S ∪ seq[..i]`
//! with one incremental left-to-right pass, then evaluate all prefix
//! marginals as a single blocked sweep on the pool
//! ([`BatchExecutor::prefix_gains`]) — one adaptive round, no per-prefix
//! serial oracle calls. [`SelectionSession::prefix_gains_serial`] is the
//! reference serial walk; both issue the same per-prefix `gain` queries on
//! bitwise-identical states, so their results are identical to the bit.

use crate::algorithms::SelectionResult;
use crate::coordinator::api::SelectError;
use crate::objectives::{Objective, ObjectiveState};
use crate::oracle::{BatchExecutor, GainCache};
use crate::rng::Pcg64;
use std::sync::Arc;

/// Monotonically increasing version of a session's solution state. Bumped
/// by every successful [`SelectionSession::insert`]; gains computed at one
/// generation are never served at a later one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Generation(pub u64);

/// Per-session telemetry. Plain counters: a session is single-writer (the
/// driver stepping it); cross-session aggregation happens in the leader's
/// [`MetricsRegistry`](crate::coordinator::MetricsRegistry).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct SessionMetrics {
    /// cached sweeps served ([`SelectionSession::sweep`])
    pub sweeps: usize,
    /// candidates covered by those sweeps
    pub swept_candidates: usize,
    /// sweep candidates answered from the generation cache
    pub cache_hits: usize,
    /// sweep candidates freshly evaluated (oracle queries issued)
    pub fresh_queries: usize,
    /// successful inserts (== generation bumps)
    pub inserts: usize,
    /// whole-set sample rounds ([`SelectionSession::sample_blocks`])
    pub sample_rounds: usize,
    /// prefix rounds ([`SelectionSession::prefix_gains`], serial or blocked)
    pub prefix_rounds: usize,
    /// uncached sweeps over forked states ([`SelectionSession::fork_gains`])
    pub fork_sweeps: usize,
}

impl SessionMetrics {
    /// Fold another session's counters into this one — used by drivers
    /// that run child sessions (DASH's per-guess sessions) so the job
    /// session's metrics cover all work done on the job's behalf.
    pub fn absorb(&mut self, other: &SessionMetrics) {
        self.sweeps += other.sweeps;
        self.swept_candidates += other.swept_candidates;
        self.cache_hits += other.cache_hits;
        self.fresh_queries += other.fresh_queries;
        self.inserts += other.inserts;
        self.sample_rounds += other.sample_rounds;
        self.prefix_rounds += other.prefix_rounds;
        self.fork_sweeps += other.fork_sweeps;
    }
}

/// Point-in-time public view of one live session — what the serving
/// front's `Metrics` requests return ([`coordinator::serve`](crate::coordinator::serve)),
/// in-process and over the v1 wire protocol
/// ([`coordinator::wire`](crate::coordinator::wire)) alike.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    /// generation at snapshot time
    pub generation: Generation,
    /// selected elements (insertion order)
    pub set: Vec<usize>,
    /// `f(S)` at snapshot time
    pub value: f64,
    /// per-session counters at snapshot time
    pub metrics: SessionMetrics,
}

/// Result of one cached gain sweep.
#[derive(Debug, Clone)]
pub struct SessionSweep {
    /// `f_S(a)` per candidate, in candidate order
    pub gains: Vec<f64>,
    /// oracle queries actually issued (cache misses) — report exactly this
    /// to the round tracker so self-reported counts match observed counts
    pub fresh: usize,
    /// generation the sweep was served at
    pub generation: Generation,
}

/// How a session holds its objective: a caller-owned borrow (algorithms,
/// scoped serving) or a shared owner (`Arc`) for lanes whose objective must
/// live and die with the lane (the wire front's open/close lifecycle).
/// Cloning is cheap — a copy of the borrow, or an `Arc` bump — so drivers
/// can open child sessions on the same objective (DASH's
/// logically-parallel OPT guesses) regardless of which way the parent
/// holds it.
pub enum ObjectiveHandle<'o> {
    /// borrowed from the caller; the session must not outlive it
    Borrowed(&'o dyn Objective),
    /// shared ownership; dropped with the last session holding it
    Shared(Arc<dyn Objective>),
}

impl<'o> ObjectiveHandle<'o> {
    /// The objective behind the handle.
    pub fn get(&self) -> &dyn Objective {
        match self {
            ObjectiveHandle::Borrowed(o) => *o,
            ObjectiveHandle::Shared(o) => &**o,
        }
    }
}

impl<'o> Clone for ObjectiveHandle<'o> {
    fn clone(&self) -> Self {
        match self {
            ObjectiveHandle::Borrowed(o) => ObjectiveHandle::Borrowed(*o),
            ObjectiveHandle::Shared(o) => ObjectiveHandle::Shared(Arc::clone(o)),
        }
    }
}

impl<'o> From<&'o dyn Objective> for ObjectiveHandle<'o> {
    fn from(o: &'o dyn Objective) -> Self {
        ObjectiveHandle::Borrowed(o)
    }
}

impl From<Arc<dyn Objective>> for ObjectiveHandle<'static> {
    fn from(o: Arc<dyn Objective>) -> Self {
        ObjectiveHandle::Shared(o)
    }
}

/// One live selection: an objective state behind a generation, its gain
/// cache, and the shared batched-gain engine. See the module docs for the
/// generation contract.
pub struct SelectionSession<'o> {
    obj: ObjectiveHandle<'o>,
    state: Box<dyn ObjectiveState>,
    generation: Generation,
    cache: GainCache,
    exec: BatchExecutor,
    pub metrics: SessionMetrics,
}

impl<'o> SelectionSession<'o> {
    /// Open a session over `obj` with an empty solution set, served by
    /// `exec` (clone of the process-shared engine).
    pub fn new(obj: &'o dyn Objective, exec: BatchExecutor) -> Self {
        Self::with_handle(ObjectiveHandle::Borrowed(obj), exec)
    }

    /// Open a session that co-owns its objective. The `Shared` handle
    /// carries no borrow, so the session is free of the caller's lifetime
    /// (`'o` is unconstrained) and the objective is dropped with the last
    /// session (or handle clone) holding it — this is what lets serving
    /// lanes be closed instead of leaking their objectives.
    pub fn shared(obj: Arc<dyn Objective>, exec: BatchExecutor) -> Self {
        Self::with_handle(ObjectiveHandle::Shared(obj), exec)
    }

    /// Open a session over an existing handle (borrowed or shared).
    pub fn with_handle(obj: ObjectiveHandle<'o>, exec: BatchExecutor) -> Self {
        let state = obj.get().empty_state();
        let cache = GainCache::new(obj.get().n());
        SelectionSession {
            obj,
            state,
            generation: Generation(0),
            cache,
            exec,
            metrics: SessionMetrics::default(),
        }
    }

    /// Rebuild a session from a [`SessionSnapshot`]: replay the snapshot's
    /// set in insertion order onto a fresh state — insertion order fully
    /// determines the state bits, so the rebuilt state is byte-identical
    /// to the one snapshotted — then install the snapshot's generation and
    /// counters. Fails with [`SelectError::Backend`] when the replayed
    /// value diverges from the snapshot's (a corrupted record, or an
    /// objective that is not the one snapshotted).
    pub fn restore(
        obj: ObjectiveHandle<'o>,
        exec: BatchExecutor,
        snap: &SessionSnapshot,
    ) -> Result<Self, SelectError> {
        let mut s = Self::with_handle(obj, exec);
        let n = s.obj.get().n();
        if let Some(&bad) = snap.set.iter().find(|&&a| a >= n) {
            return Err(SelectError::Backend(format!(
                "session restore diverged: snapshot element {bad} outside ground set of {n}"
            )));
        }
        for &a in &snap.set {
            s.state.insert(a);
        }
        if s.state.set() != snap.set.as_slice() {
            return Err(SelectError::Backend(
                "session restore diverged: snapshot set has duplicate elements".into(),
            ));
        }
        if s.state.value().to_bits() != snap.value.to_bits() {
            return Err(SelectError::Backend(format!(
                "session restore diverged: replayed value {} != snapshot value {}",
                s.state.value(),
                snap.value
            )));
        }
        s.generation = snap.generation;
        s.metrics = snap.metrics.clone();
        Ok(s)
    }

    /// The objective this session optimizes.
    pub fn objective(&self) -> &dyn Objective {
        self.obj.get()
    }

    /// A clone of the session's objective handle (a copied borrow or an
    /// `Arc` bump), free of the `&self` borrow — drivers use this to open
    /// child sessions on the same objective (DASH's logically-parallel
    /// OPT guesses).
    pub fn objective_handle(&self) -> ObjectiveHandle<'o> {
        self.obj.clone()
    }

    /// The batched-gain engine serving this session.
    pub fn executor(&self) -> &BatchExecutor {
        &self.exec
    }

    /// Current generation (bumped by every successful insert).
    pub fn generation(&self) -> Generation {
        self.generation
    }

    /// Current `f(S)`.
    pub fn value(&self) -> f64 {
        self.state.value()
    }

    /// Elements currently selected (insertion order).
    pub fn set(&self) -> &[usize] {
        self.state.set()
    }

    /// `|S|`.
    pub fn len(&self) -> usize {
        self.state.set().len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.set().is_empty()
    }

    /// Read access to the live state (for value/set inspection; mutation
    /// must go through [`SelectionSession::insert`]).
    pub fn state(&self) -> &dyn ObjectiveState {
        &*self.state
    }

    /// Point-in-time snapshot (generation, set, value, counters).
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            generation: self.generation,
            set: self.state.set().to_vec(),
            value: self.state.value(),
            metrics: self.metrics.clone(),
        }
    }

    /// Ground-set elements not yet selected, in index order.
    pub fn remaining(&self) -> Vec<usize> {
        let set = self.state.set();
        (0..self.obj.get().n()).filter(|a| !set.contains(a)).collect()
    }

    /// Cached marginal-gain sweep over the current state. Candidates whose
    /// gain is known *at the current generation* are served from the
    /// cache; the misses are evaluated in one (possibly sharded) blocked
    /// sweep through the engine. `fresh` is the number of oracle queries
    /// actually issued. Candidates are assumed distinct.
    pub fn sweep(&mut self, candidates: &[usize]) -> SessionSweep {
        let (gains, fresh) = self.exec.cached_gains(&mut self.cache, &*self.state, candidates);
        self.metrics.sweeps += 1;
        self.metrics.swept_candidates += candidates.len();
        self.metrics.fresh_queries += fresh;
        self.metrics.cache_hits += candidates.len() - fresh;
        SessionSweep { gains, fresh, generation: self.generation }
    }

    /// Uncached blocked sweep over a *forked* state (DASH's filter step
    /// sweeps each sampled `S ∪ R` state). Bypasses the generation cache —
    /// the fork is not the session state — but still runs on the shared
    /// zero-clone engine.
    pub fn fork_gains(&mut self, fork: &dyn ObjectiveState, candidates: &[usize]) -> Vec<f64> {
        self.metrics.fork_sweeps += 1;
        self.exec.gains(fork, candidates)
    }

    /// Whole-set sample gains `f_S(R)` for a batch of blocks, fanned out
    /// over the pool; each block comes back with its constructed `S ∪ R`
    /// state for reuse (one counted oracle query per block).
    pub fn sample_blocks(
        &mut self,
        blocks: &[Vec<usize>],
    ) -> Vec<(f64, Box<dyn ObjectiveState>)> {
        self.metrics.sample_rounds += 1;
        self.exec.sample_blocks(self.obj.get(), &*self.state, blocks)
    }

    /// Grow `S ← S ∪ {a}`. On success (the element was not already
    /// selected) the generation is bumped and the gain cache is logically
    /// invalidated in O(1). Returns whether the set actually grew.
    pub fn insert(&mut self, a: usize) -> bool {
        let before = self.state.set().len();
        self.state.insert(a);
        let grew = self.state.set().len() > before;
        if grew {
            self.generation.0 += 1;
            self.cache.invalidate();
            self.metrics.inserts += 1;
        }
        grew
    }

    /// Insert every element of `items` in order (one generation bump per
    /// successful insert). Returns how many actually entered the set.
    pub fn commit(&mut self, items: &[usize]) -> usize {
        items.iter().filter(|&&a| self.insert(a)).count()
    }

    /// Prefix-parallel round (paper §1.2): for the sampled sequence `seq`,
    /// return the per-step marginals `g_i = f_{S ∪ seq[..i]}(seq[i])`.
    /// The prefix states are materialized by one incremental left-to-right
    /// pass, then **all** marginals are evaluated as a single blocked
    /// sweep on the pool — one adaptive round, no per-prefix serial oracle
    /// calls. Identical to [`SelectionSession::prefix_gains_serial`] to
    /// the bit (same `gain` queries on bitwise-equal states).
    ///
    /// The session state is not mutated; callers commit the accepted
    /// prefix afterwards.
    pub fn prefix_gains(&mut self, seq: &[usize]) -> Vec<f64> {
        if seq.is_empty() {
            return Vec::new();
        }
        self.metrics.prefix_rounds += 1;
        // one incremental pass: P_0 = S, P_{i+1} = P_i ∪ {seq[i]}
        let mut prefixes: Vec<Box<dyn ObjectiveState>> = Vec::with_capacity(seq.len());
        prefixes.push(self.state.clone_box());
        for i in 1..seq.len() {
            let mut next = prefixes[i - 1].clone_box();
            next.insert(seq[i - 1]);
            prefixes.push(next);
        }
        self.exec.prefix_gains(&prefixes, seq)
    }

    /// Reference serial prefix walk: the same per-prefix `gain` queries as
    /// [`SelectionSession::prefix_gains`], issued one after another on a
    /// single incrementally-updated walk state. Kept as the baseline the
    /// blocked prefix round is benchmarked and tested against.
    pub fn prefix_gains_serial(&mut self, seq: &[usize]) -> Vec<f64> {
        if seq.is_empty() {
            return Vec::new();
        }
        self.metrics.prefix_rounds += 1;
        let mut walk = self.state.clone_box();
        let mut out = Vec::with_capacity(seq.len());
        for &a in seq {
            out.push(walk.gain(a));
            walk.insert(a);
        }
        out
    }
}

/// Outcome of one driver step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// more adaptive rounds remain
    Continue,
    /// the driver has terminated; call [`SessionDriver::finish`]
    Done,
}

/// A selection algorithm as a stepwise driver over a [`SelectionSession`].
///
/// One `step` advances the algorithm by (roughly) one adaptive round —
/// a sweep, a sample/filter round, or a prefix round — and commits any
/// state growth through the session (generation bumps). Drivers expect a
/// fresh session and must be deterministic given the session's objective
/// and the provided rng, so a leader interleaving many drivers over one
/// executor reproduces each driver's solo run byte-for-byte.
pub trait SessionDriver {
    /// Algorithm label (matches `SelectionResult::algorithm`).
    fn label(&self) -> &str;

    /// Advance one round. Must be a no-op returning [`StepOutcome::Done`]
    /// once the driver has terminated.
    fn step(&mut self, session: &mut SelectionSession<'_>, rng: &mut Pcg64) -> StepOutcome;

    /// Finalize accounting into a [`SelectionResult`].
    fn finish(self: Box<Self>, session: &mut SelectionSession<'_>) -> SelectionResult;
}

/// Run a driver to completion on one session — the run-to-completion
/// `run()` every algorithm exposes is exactly this.
pub fn drive(
    mut driver: Box<dyn SessionDriver + '_>,
    session: &mut SelectionSession<'_>,
    rng: &mut Pcg64,
) -> SelectionResult {
    while driver.step(session, rng) == StepOutcome::Continue {}
    driver.finish(session)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;
    use crate::objectives::Objective;

    fn obj() -> LinearRegressionObjective {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 80, 30, 8, 0.3);
        LinearRegressionObjective::new(&ds)
    }

    #[test]
    fn insert_bumps_generation_and_invalidates() {
        let o = obj();
        let mut s = SelectionSession::new(&o, BatchExecutor::sequential());
        assert_eq!(s.generation(), Generation(0));
        let cand: Vec<usize> = (0..o.n()).collect();
        let first = s.sweep(&cand);
        assert_eq!(first.fresh, o.n());
        // same generation: all hits
        let again = s.sweep(&cand);
        assert_eq!(again.fresh, 0);
        assert_eq!(again.gains, first.gains);
        assert!(s.insert(3));
        assert_eq!(s.generation(), Generation(1));
        // inserting a member is a no-op: no bump
        assert!(!s.insert(3));
        assert_eq!(s.generation(), Generation(1));
        // new generation: everything re-queried, values match a fresh state
        let after = s.sweep(&cand);
        assert_eq!(after.fresh, o.n());
        assert_eq!(after.gains, o.state_for(&[3]).gains(&cand));
        assert_eq!(s.metrics.inserts, 1);
        assert_eq!(s.metrics.cache_hits, o.n());
    }

    #[test]
    fn prefix_round_matches_serial_walk_bitwise() {
        let o = obj();
        let exec = BatchExecutor::new(3).with_min_parallel(2);
        let mut s = SelectionSession::new(&o, exec);
        s.commit(&[1, 4]);
        let seq = vec![7usize, 2, 19, 11, 28, 5];
        let serial = s.prefix_gains_serial(&seq);
        let blocked = s.prefix_gains(&seq);
        assert_eq!(serial.len(), seq.len());
        for (a, b) in serial.iter().zip(&blocked) {
            assert_eq!(a.to_bits(), b.to_bits(), "prefix marginals must be bit-identical");
        }
        // the session state itself is untouched by prefix rounds
        assert_eq!(s.set(), &[1, 4]);
        assert_eq!(s.metrics.prefix_rounds, 2);
    }

    #[test]
    fn commit_counts_only_new_elements() {
        let o = obj();
        let mut s = SelectionSession::new(&o, BatchExecutor::sequential());
        assert_eq!(s.commit(&[2, 5, 2, 9]), 3);
        assert_eq!(s.set(), &[2, 5, 9]);
        assert_eq!(s.generation(), Generation(3));
        assert_eq!(s.remaining().len(), o.n() - 3);
        assert!(!s.remaining().contains(&5));
    }
}
