//! The leader: owns job lifecycle. Resolves a [`SelectionJob`] into an
//! objective (native or XLA-backed), executes the requested algorithm, and
//! emits a [`SelectionReport`] plus metrics.

use crate::algorithms::{
    AdaptiveSampling, AdaptiveSamplingConfig, AdaptiveSeqDriver, AdaptiveSequencing,
    AdaptiveSequencingConfig, Dash, DashConfig, DashDriver, Greedy, GreedyConfig, Lasso,
    LassoConfig, LassoLogistic, ParallelGreedy, RandomSelect, SelectionResult, TopK, TopKDriver,
};
use crate::coordinator::api::SelectError;
use crate::coordinator::serve::{
    Envelope, ServeConfig, ServeSummary, SessionClient, SessionId, SessionServer,
};
use crate::coordinator::session::{SelectionSession, SessionDriver, StepOutcome};
use crate::coordinator::MetricsRegistry;
use crate::data::{Dataset, Task};
use crate::objectives::{
    AOptimalityObjective, LinearRegressionObjective, LogisticObjective, Objective,
    OvrSoftmaxObjective, R2Objective,
};
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;
use crate::runtime::Manifest;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Which objective to optimize.
#[derive(Debug, Clone, PartialEq)]
pub enum ObjectiveChoice {
    /// `ℓ_reg` variance reduction (Cor. 7)
    Lreg,
    /// Appendix F R²
    R2,
    /// `ℓ_class` binary logistic (Cor. 8)
    Logistic,
    /// one-vs-rest multiclass (D4)
    OvrSoftmax,
    /// Bayesian A-optimality (Cor. 9)
    Aopt { beta_sq: f64, sigma_sq: f64 },
}

/// Gains backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// pure-rust incremental states
    Native,
    /// PJRT-executed AOT artifacts for the batched sweeps
    Xla,
}

impl Backend {
    /// The one name↔backend mapping the CLI and the wire protocol share.
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "native" => Some(Backend::Native),
            "xla" => Some(Backend::Xla),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Xla => "xla",
        }
    }
}

/// Which algorithm to run.
#[derive(Debug, Clone)]
pub enum AlgorithmChoice {
    Dash(DashConfig),
    Greedy(GreedyConfig),
    ParallelGreedy { cfg: GreedyConfig, threads: usize },
    TopK,
    Random { trials: usize },
    Lasso(LassoConfig),
    AdaptiveSampling(AdaptiveSamplingConfig),
    AdaptiveSequencing(AdaptiveSequencingConfig),
}

impl AlgorithmChoice {
    /// The same plan with the cardinality constraint set to `k`. Jobs carry
    /// `k` at the problem level; this resolves it into the per-algorithm
    /// config so the two can never disagree.
    pub fn with_k(&self, k: usize) -> AlgorithmChoice {
        match self {
            AlgorithmChoice::Dash(cfg) => AlgorithmChoice::Dash(DashConfig { k, ..cfg.clone() }),
            AlgorithmChoice::Greedy(cfg) => {
                AlgorithmChoice::Greedy(GreedyConfig { k, ..cfg.clone() })
            }
            AlgorithmChoice::ParallelGreedy { cfg, threads } => AlgorithmChoice::ParallelGreedy {
                cfg: GreedyConfig { k, ..cfg.clone() },
                threads: *threads,
            },
            AlgorithmChoice::TopK => AlgorithmChoice::TopK,
            AlgorithmChoice::Random { trials } => AlgorithmChoice::Random { trials: *trials },
            AlgorithmChoice::Lasso(cfg) => AlgorithmChoice::Lasso(cfg.clone()),
            AlgorithmChoice::AdaptiveSampling(cfg) => {
                AlgorithmChoice::AdaptiveSampling(AdaptiveSamplingConfig { k, ..cfg.clone() })
            }
            AlgorithmChoice::AdaptiveSequencing(cfg) => {
                AlgorithmChoice::AdaptiveSequencing(AdaptiveSequencingConfig { k, ..cfg.clone() })
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AlgorithmChoice::Dash(_) => "dash",
            AlgorithmChoice::Greedy(c) if c.lazy => "sds_ma_lazy",
            AlgorithmChoice::Greedy(_) => "sds_ma",
            AlgorithmChoice::ParallelGreedy { .. } => "parallel_sds_ma",
            AlgorithmChoice::TopK => "top_k",
            AlgorithmChoice::Random { .. } => "random",
            AlgorithmChoice::Lasso(_) => "lasso",
            AlgorithmChoice::AdaptiveSampling(_) => "adaptive_sampling",
            AlgorithmChoice::AdaptiveSequencing(_) => "adaptive_seq",
        }
    }
}

/// One selection job.
#[derive(Clone)]
pub struct SelectionJob {
    pub dataset: Arc<Dataset>,
    pub objective: ObjectiveChoice,
    pub backend: Backend,
    pub algorithm: AlgorithmChoice,
    pub k: usize,
    pub seed: u64,
}

/// One lane of a [`Leader::serve`] session set: the job resolves the
/// objective (and, for driven lanes, the stepwise driver plus the rng
/// seed).
#[derive(Clone)]
pub struct ServeSpec {
    pub job: SelectionJob,
    /// attach the job's stepwise driver (`Step`/`Finish` requests); ad-hoc
    /// lanes (raw sweep/insert traffic) leave this false
    pub driven: bool,
}

impl ServeSpec {
    /// Lane with the job's stepwise driver attached.
    pub fn driven(job: SelectionJob) -> Self {
        ServeSpec { job, driven: true }
    }

    /// Ad-hoc lane: raw sweep/insert traffic, no driver.
    pub fn adhoc(job: SelectionJob) -> Self {
        ServeSpec { job, driven: false }
    }
}

/// Machine-readable job outcome.
#[derive(Debug, Clone)]
pub struct SelectionReport {
    pub algorithm: String,
    pub dataset: String,
    pub objective: String,
    pub backend: &'static str,
    pub k: usize,
    pub result: SelectionResult,
    /// value recomputed under the *native* objective (so XLA- and
    /// native-backend runs are compared on identical ground truth)
    pub native_value: f64,
}

impl SelectionReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algorithm", self.algorithm.as_str().into()),
            ("dataset", self.dataset.as_str().into()),
            ("objective", self.objective.as_str().into()),
            ("backend", self.backend.into()),
            ("k", self.k.into()),
            ("value", self.result.value.into()),
            ("native_value", self.native_value.into()),
            ("rounds", self.result.rounds.into()),
            ("queries", self.result.queries.into()),
            ("wall_s", self.result.wall_s.into()),
            ("modeled_parallel_s_p64", self.result.modeled_parallel_s(Some(64)).into()),
            ("hit_iteration_cap", self.result.hit_iteration_cap.into()),
            ("set", Json::arr_usize(&self.result.set)),
        ])
    }
}

/// Job executor.
///
/// Owns one machine-sized [`ThreadPool`] and one [`BatchExecutor`] backed
/// by it; every served job's gain sweeps fan out over this shared pool
/// instead of each algorithm spawning its own threads.
pub struct Leader {
    pub metrics: Arc<MetricsRegistry>,
    manifest: Option<Manifest>,
    /// `None` when serving sequentially (no worker threads at all)
    pool: Option<Arc<ThreadPool>>,
    exec: BatchExecutor,
}

impl Default for Leader {
    fn default() -> Self {
        Self::new()
    }
}

impl Leader {
    /// Create a leader; loads the artifact manifest when present so XLA
    /// jobs can be served, and brings up the shared oracle pool.
    pub fn new() -> Self {
        Self::with_threads(ThreadPool::default_size())
    }

    /// Leader with an explicit oracle-pool size (1 = sequential sweeps,
    /// no worker threads spawned).
    pub fn with_threads(threads: usize) -> Self {
        let dir = crate::runtime::default_artifacts_dir();
        let manifest = Manifest::load(&dir).ok();
        let (pool, exec) = if threads > 1 {
            let pool = Arc::new(ThreadPool::new(threads));
            let exec = BatchExecutor::with_pool(Arc::clone(&pool));
            (Some(pool), exec)
        } else {
            (None, BatchExecutor::sequential())
        };
        Leader { metrics: Arc::new(MetricsRegistry::new()), manifest, pool, exec }
    }

    pub fn has_artifacts(&self) -> bool {
        self.manifest.is_some()
    }

    /// The shared batched-gain engine served jobs run on.
    pub fn executor(&self) -> &BatchExecutor {
        &self.exec
    }

    /// The shared worker pool (`None` when serving sequentially).
    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// Build the objective for a job (also the wire front's resolution
    /// path). Backend failures — missing artifacts, runtime errors — are
    /// [`SelectError::Backend`]; impossible pairings are
    /// [`SelectError::InvalidSpec`].
    pub fn objective(&self, job: &SelectionJob) -> Result<Box<dyn Objective>, SelectError> {
        let ds = &job.dataset;
        match (&job.objective, job.backend) {
            (ObjectiveChoice::Lreg, Backend::Native) => {
                Ok(Box::new(LinearRegressionObjective::new(ds)))
            }
            (ObjectiveChoice::R2, _) => Ok(Box::new(R2Objective::new(ds))),
            (ObjectiveChoice::Logistic, Backend::Native) => {
                Ok(Box::new(LogisticObjective::new(ds)))
            }
            (ObjectiveChoice::OvrSoftmax, _) => OvrSoftmaxObjective::new(ds)
                .map(|o| Box::new(o) as Box<dyn Objective>)
                .map_err(SelectError::InvalidSpec),
            (ObjectiveChoice::Aopt { beta_sq, sigma_sq }, Backend::Native) => {
                Ok(Box::new(AOptimalityObjective::new(ds, *beta_sq, *sigma_sq)))
            }
            (choice, Backend::Xla) => {
                let manifest = self.manifest.as_ref().ok_or_else(|| {
                    SelectError::Backend("XLA backend requested but artifacts/ not built".into())
                })?;
                match choice {
                    ObjectiveChoice::Lreg => crate::oracle::XlaLregObjective::new(
                        ds,
                        manifest,
                        job.k.max(1),
                    )
                    .map(|o| Box::new(o) as Box<dyn Objective>)
                    .map_err(|e| SelectError::Backend(e.to_string())),
                    ObjectiveChoice::Logistic => {
                        crate::oracle::XlaLogisticObjective::new(ds, manifest)
                            .map(|o| Box::new(o) as Box<dyn Objective>)
                            .map_err(|e| SelectError::Backend(e.to_string()))
                    }
                    ObjectiveChoice::Aopt { beta_sq, sigma_sq } => {
                        crate::oracle::XlaAoptObjective::new(ds, manifest, *beta_sq, *sigma_sq)
                            .map(|o| Box::new(o) as Box<dyn Objective>)
                            .map_err(|e| SelectError::Backend(e.to_string()))
                    }
                    other => Err(SelectError::InvalidSpec(format!(
                        "{other:?} has no XLA backend"
                    ))),
                }
            }
        }
    }

    /// Execute a job. Every gain sweep runs on the leader's shared engine —
    /// the job-level `threads` knob of `ParallelGreedy` is superseded by
    /// the shared pool when served here (standalone use still honors it).
    /// The job is validated first, so a malformed job (hand-assembled or
    /// builder-made) returns `Err`, never panics.
    pub fn run(&self, job: &SelectionJob) -> Result<SelectionReport, SelectError> {
        job.validate()?;
        let mut rng = Pcg64::seed_from(job.seed);
        let obj = self.objective(job)?;
        let sweeps_before = self.exec.stats().sweeps.load(Ordering::Relaxed);
        let sharded_before = self.exec.stats().sharded_sweeps.load(Ordering::Relaxed);
        // the job's k supersedes whatever placeholder the plan carried
        let result = match &job.algorithm.with_k(job.k) {
            AlgorithmChoice::Dash(cfg) => {
                Dash::new(cfg.clone()).with_executor(self.exec.clone()).run(&*obj, &mut rng)
            }
            AlgorithmChoice::Greedy(cfg) => {
                Greedy::new(cfg.clone()).with_executor(self.exec.clone()).run(&*obj)
            }
            AlgorithmChoice::ParallelGreedy { cfg, threads } => {
                // the shared engine supersedes the job's own threads knob
                ParallelGreedy::new(cfg.clone(), *threads)
                    .with_executor(self.exec.clone())
                    .run(&*obj)
            }
            AlgorithmChoice::TopK => {
                TopK::new(job.k).with_executor(self.exec.clone()).run(&*obj)
            }
            AlgorithmChoice::Random { trials } => {
                RandomSelect::new(job.k).run_mean(&*obj, &mut rng, *trials)
            }
            AlgorithmChoice::Lasso(cfg) => match job.dataset.task {
                Task::BinaryClassification => LassoLogistic::new(cfg.clone()).run_for_k(
                    &job.dataset.x,
                    &job.dataset.y,
                    job.k,
                ),
                _ => Lasso::new(cfg.clone()).run_for_k(&job.dataset.x, &job.dataset.y, job.k),
            },
            AlgorithmChoice::AdaptiveSampling(cfg) => {
                AdaptiveSampling::new(cfg.clone())
                    .with_executor(self.exec.clone())
                    .run(&*obj, &mut rng)
            }
            AlgorithmChoice::AdaptiveSequencing(cfg) => {
                AdaptiveSequencing::new(cfg.clone())
                    .with_executor(self.exec.clone())
                    .run(&*obj, &mut rng)
            }
        };

        let sweeps_after = self.exec.stats().sweeps.load(Ordering::Relaxed);
        let sharded_after = self.exec.stats().sharded_sweeps.load(Ordering::Relaxed);
        self.metrics
            .inc("oracle.sweeps", sweeps_after.saturating_sub(sweeps_before) as u64);
        self.metrics.inc(
            "oracle.sharded_sweeps",
            sharded_after.saturating_sub(sharded_before) as u64,
        );
        Ok(self.finalize(job, result))
    }

    /// Native re-evaluation, job metrics, and report assembly shared by
    /// [`Leader::run`] and [`Leader::run_many`].
    fn finalize(&self, job: &SelectionJob, result: SelectionResult) -> SelectionReport {
        // LASSO reports no objective value; evaluate its set. Recompute the
        // native value for every algorithm so backends are comparable. A job
        // that reached finalize already resolved through [`Leader::objective`],
        // so the fallible OvrSoftmax constructor cannot fail here; if it
        // somehow does, keep the value the run reported instead of panicking.
        let native_obj: Option<Box<dyn Objective>> = match &job.objective {
            ObjectiveChoice::Lreg => {
                Some(Box::new(LinearRegressionObjective::new(&job.dataset)))
            }
            ObjectiveChoice::R2 => Some(Box::new(R2Objective::new(&job.dataset))),
            ObjectiveChoice::Logistic => Some(Box::new(LogisticObjective::new(&job.dataset))),
            ObjectiveChoice::OvrSoftmax => OvrSoftmaxObjective::new(&job.dataset)
                .ok()
                .map(|o| Box::new(o) as Box<dyn Objective>),
            ObjectiveChoice::Aopt { beta_sq, sigma_sq } => {
                Some(Box::new(AOptimalityObjective::new(&job.dataset, *beta_sq, *sigma_sq)))
            }
        };
        let mut result = result;
        let native_value = match native_obj {
            Some(obj) => obj.eval(&result.set),
            None => result.value,
        };
        if matches!(job.algorithm, AlgorithmChoice::Lasso(_)) {
            result.value = native_value;
        }

        self.metrics.inc("leader.jobs", 1);
        self.metrics.inc("oracle.queries", result.queries as u64);
        self.metrics.set_gauge("last.value", result.value);
        self.metrics.set_gauge("last.rounds", result.rounds as f64);

        SelectionReport {
            algorithm: result.algorithm.clone(),
            dataset: job.dataset.name.clone(),
            objective: format!("{:?}", job.objective),
            backend: job.backend.name(),
            k: job.k,
            native_value,
            result,
        }
    }

    /// The stepwise [`SessionDriver`] for a job's algorithm, or `None` for
    /// the non-oracle algorithms (LASSO, RANDOM) that have no adaptive
    /// round structure to interleave.
    pub fn driver_for(job: &SelectionJob) -> Option<Box<dyn SessionDriver>> {
        // with_k is the one place the job's k overrides the plan's config
        match job.algorithm.with_k(job.k) {
            AlgorithmChoice::Dash(cfg) => Some(Box::new(DashDriver::new(cfg, "dash"))),
            AlgorithmChoice::Greedy(cfg) => Some(Greedy::driver(cfg, "sds_ma")),
            // the shared engine supersedes the job's own threads knob
            AlgorithmChoice::ParallelGreedy { cfg, .. } => {
                Some(Greedy::driver(cfg, "parallel_sds_ma"))
            }
            AlgorithmChoice::TopK => Some(Box::new(TopKDriver::new(job.k))),
            AlgorithmChoice::AdaptiveSampling(cfg) => {
                Some(Box::new(DashDriver::new(cfg.to_dash(), "adaptive_sampling")))
            }
            AlgorithmChoice::AdaptiveSequencing(cfg) => {
                Some(Box::new(AdaptiveSeqDriver::new(cfg)))
            }
            AlgorithmChoice::Random { .. } | AlgorithmChoice::Lasso(_) => None,
        }
    }

    /// Serve many jobs as concurrent [`SelectionSession`]s multiplexed
    /// over the leader's one pool: drivers are stepped round-robin, one
    /// adaptive round at a time, so every live session's sweeps interleave
    /// on the shared engine. Sessions are independent (own state, own
    /// generation, own rng), so each job's result is byte-identical to
    /// serving it alone. Jobs without a stepwise driver (LASSO, RANDOM)
    /// are served run-to-completion after the multiplexed lanes drain.
    pub fn run_many(&self, jobs: &[SelectionJob]) -> Vec<Result<SelectionReport, SelectError>> {
        let sweeps_before = self.exec.stats().sweeps.load(Ordering::Relaxed);
        let sharded_before = self.exec.stats().sharded_sweeps.load(Ordering::Relaxed);
        // resolve objectives first (the sessions below borrow them) — but
        // only for jobs that get a stepwise driver; Direct lanes resolve
        // inside `Leader::run`, and resolving here too would build each
        // objective twice
        let drivers: Vec<Option<Box<dyn SessionDriver>>> =
            jobs.iter().map(Self::driver_for).collect();
        let validity: Vec<Result<(), SelectError>> =
            jobs.iter().map(|j| j.validate()).collect();
        let resolved: Vec<Option<Result<Box<dyn Objective>, SelectError>>> = jobs
            .iter()
            .zip(&drivers)
            .zip(&validity)
            .map(|((j, d), v)| (d.is_some() && v.is_ok()).then(|| self.objective(j)))
            .collect();

        enum Lane<'o> {
            Live {
                session: SelectionSession<'o>,
                driver: Box<dyn SessionDriver>,
                rng: Pcg64,
                done: bool,
            },
            /// no stepwise driver: served via `Leader::run`
            Direct,
            Failed(SelectError),
        }

        let mut lanes: Vec<Lane<'_>> = Vec::with_capacity(jobs.len());
        for (((job, driver), obj), valid) in
            jobs.iter().zip(drivers).zip(&resolved).zip(validity)
        {
            // a malformed job fails its own lane — never panics, never
            // takes the other lanes down
            if let Err(e) = valid {
                lanes.push(Lane::Failed(e));
                continue;
            }
            lanes.push(match (driver, obj) {
                (None, _) => Lane::Direct,
                (Some(_), Some(Err(e))) => Lane::Failed(e.clone()),
                (Some(driver), Some(Ok(obj))) => Lane::Live {
                    session: SelectionSession::new(&**obj, self.exec.clone()),
                    driver,
                    rng: Pcg64::seed_from(job.seed),
                    done: false,
                },
                // valid driver lanes always resolve an objective; answer
                // with a lane failure rather than aborting the batch if
                // that pairing ever breaks
                (Some(_), None) => Lane::Failed(SelectError::Backend(
                    "driver lane resolved no objective".into(),
                )),
            });
        }

        // round-robin: one step (≈ one adaptive round) per live lane per
        // pass, until every lane is done
        loop {
            let mut progressed = false;
            for lane in lanes.iter_mut() {
                if let Lane::Live { session, driver, rng, done } = lane {
                    if !*done {
                        if driver.step(session, rng) == StepOutcome::Done {
                            *done = true;
                        }
                        progressed = true;
                    }
                }
            }
            if !progressed {
                break;
            }
        }

        // account the multiplexed lanes' sweeps now; Direct lanes below go
        // through `run`, which records its own deltas
        let sweeps_after = self.exec.stats().sweeps.load(Ordering::Relaxed);
        let sharded_after = self.exec.stats().sharded_sweeps.load(Ordering::Relaxed);
        self.metrics
            .inc("oracle.sweeps", sweeps_after.saturating_sub(sweeps_before) as u64);
        self.metrics.inc(
            "oracle.sharded_sweeps",
            sharded_after.saturating_sub(sharded_before) as u64,
        );

        jobs
            .iter()
            .zip(lanes)
            .map(|(job, lane)| match lane {
                Lane::Live { mut session, driver, .. } => {
                    let result = driver.finish(&mut session);
                    self.metrics
                        .inc("session.inserts", session.metrics.inserts as u64);
                    self.metrics
                        .inc("session.fresh_queries", session.metrics.fresh_queries as u64);
                    self.metrics
                        .inc("session.cache_hits", session.metrics.cache_hits as u64);
                    Ok(self.finalize(job, result))
                }
                Lane::Direct => self.run(job),
                Lane::Failed(e) => Err(e),
            })
            .collect()
    }

    /// Serve a set of live sessions to concurrent clients
    /// ([`coordinator::serve`](crate::coordinator::serve)): the caller's
    /// thread becomes the server loop — the lanes borrow leader-built
    /// objectives, which never cross threads — while `f` runs on a scoped
    /// worker thread with one cloneable [`SessionClient`] per spec'd
    /// session (clients are `Send + 'static`; `f` may spawn its own
    /// threads). Requests flow through a bounded queue
    /// ([`ServeConfig::queue_bound`] — backpressure), concurrent
    /// same-generation sweeps coalesce into one pooled round on the
    /// leader's shared engine, and every sweep reply is
    /// generation-stamped.
    ///
    /// Returns `f`'s result plus the serving summary once every client
    /// handle is dropped — `f` must not leak a client into its return
    /// value, or the loop never observes disconnect.
    pub fn serve<R, F>(
        &self,
        specs: &[ServeSpec],
        cfg: ServeConfig,
        f: F,
    ) -> Result<(R, ServeSummary), SelectError>
    where
        R: Send,
        F: FnOnce(Vec<SessionClient>) -> R + Send,
    {
        // validate + resolve objectives first (the server lanes borrow them)
        for spec in specs {
            spec.job.validate()?;
        }
        let objectives = specs
            .iter()
            .map(|s| self.objective(&s.job))
            .collect::<Result<Vec<Box<dyn Objective>>, SelectError>>()?;
        let mut server = SessionServer::new();
        for (spec, obj) in specs.iter().zip(&objectives) {
            if spec.driven {
                let driver = Self::driver_for(&spec.job).ok_or_else(|| {
                    SelectError::InvalidSpec(format!(
                        "{} has no stepwise driver to serve",
                        spec.job.algorithm.label()
                    ))
                })?;
                server.open_driven(&**obj, self.exec.clone(), driver, spec.job.seed);
            } else {
                server.open(&**obj, self.exec.clone());
            }
        }
        let (tx, rx) = std::sync::mpsc::sync_channel::<Envelope>(cfg.queue_bound.max(1));
        let clients: Vec<SessionClient> =
            (0..specs.len()).map(|i| SessionClient::new(tx.clone(), SessionId(i))).collect();
        // the loop exits when every sender is gone; only clients hold one
        drop(tx);
        let (joined, summary) = std::thread::scope(|scope| {
            let client_thread = scope.spawn(move || f(clients));
            let summary = server.run(rx);
            (client_thread.join(), summary)
        });
        self.metrics.inc("serve.requests", summary.metrics.requests as u64);
        self.metrics.inc("serve.sweep_requests", summary.metrics.sweep_requests as u64);
        self.metrics.inc("serve.coalesced_rounds", summary.metrics.coalesced_rounds as u64);
        self.metrics.inc("serve.inserts", summary.metrics.inserts as u64);
        // a panicking client closure surfaces as an error, not a panic of
        // the serving thread (the sessions served fine; the client died);
        // the panic payload rides along so assertion messages survive
        let r = joined.map_err(|payload| {
            let msg = payload
                .downcast_ref::<&'static str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            SelectError::ClientPanic(msg)
        })?;
        Ok((r, summary))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn job(alg: AlgorithmChoice) -> SelectionJob {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 80, 20, 8, 0.3);
        SelectionJob {
            dataset: Arc::new(ds),
            objective: ObjectiveChoice::Lreg,
            backend: Backend::Native,
            algorithm: alg,
            k: 5,
            seed: 7,
        }
    }

    #[test]
    fn leader_runs_every_algorithm() {
        let leader = Leader::new();
        for alg in [
            AlgorithmChoice::Dash(DashConfig::default()),
            AlgorithmChoice::Greedy(GreedyConfig::default()),
            AlgorithmChoice::ParallelGreedy { cfg: GreedyConfig::default(), threads: 2 },
            AlgorithmChoice::TopK,
            AlgorithmChoice::Random { trials: 3 },
            AlgorithmChoice::Lasso(LassoConfig::default()),
            AlgorithmChoice::AdaptiveSequencing(AdaptiveSequencingConfig::default()),
        ] {
            let report = leader.run(&job(alg.clone())).unwrap();
            assert!(report.result.set.len() <= 5, "{}: {:?}", report.algorithm, report.result.set);
            assert!(report.native_value >= 0.0);
            let j = report.to_json();
            assert!(j.get("value").is_some());
            assert!(j.get("rounds").is_some());
        }
        assert_eq!(leader.metrics.counter("leader.jobs"), 7);
    }

    #[test]
    fn xla_backend_when_artifacts_present() {
        let leader = Leader::new();
        if !leader.has_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut j = job(AlgorithmChoice::Dash(DashConfig::default()));
        j.backend = Backend::Xla;
        let report = leader.run(&j).unwrap();
        assert_eq!(report.backend, "xla");
        assert!(report.result.value > 0.0);
        // native re-evaluation close to the backend's own value
        assert!((report.native_value - report.result.value).abs() < 1e-3);
    }

    #[test]
    fn xla_backend_without_artifacts_is_clean_error() {
        let mut leader = Leader::new();
        leader.manifest = None;
        let mut j = job(AlgorithmChoice::TopK);
        j.backend = Backend::Xla;
        let err = leader.run(&j).unwrap_err();
        assert!(matches!(err, SelectError::Backend(_)), "{err:?}");
        assert!(err.to_string().contains("artifacts"), "{err}");
    }

    #[test]
    fn jobs_share_the_leader_pool() {
        let leader = Leader::with_threads(3);
        assert_eq!(leader.executor().threads(), 3);
        assert_eq!(leader.pool().map(|p| p.size()), Some(3));
        let parallel_job =
            job(AlgorithmChoice::ParallelGreedy { cfg: GreedyConfig::default(), threads: 7 });
        let report = leader.run(&parallel_job).unwrap();
        // sweeps were recorded against the shared engine, and the served
        // job ran on the leader pool (not its own 7 threads)
        assert!(leader.metrics.counter("oracle.sweeps") > 0);
        assert!(report.result.set.len() <= 5);
        // a sequential leader produces identical results and accounting
        let seq = Leader::with_threads(1);
        assert!(!seq.executor().is_parallel());
        let r2 = seq.run(&parallel_job).unwrap();
        assert_eq!(report.result.set, r2.result.set);
        assert_eq!(report.result.queries, r2.result.queries);
        assert_eq!(report.result.rounds, r2.result.rounds);
    }

    #[test]
    fn run_many_multiplexes_sessions_byte_identically() {
        let leader = Leader::with_threads(3);
        let jobs = vec![
            job(AlgorithmChoice::Greedy(GreedyConfig::default())),
            job(AlgorithmChoice::Dash(DashConfig::default())),
            job(AlgorithmChoice::AdaptiveSequencing(AdaptiveSequencingConfig::default())),
            job(AlgorithmChoice::TopK),
            job(AlgorithmChoice::Random { trials: 2 }), // direct lane
        ];
        let many = leader.run_many(&jobs);
        assert_eq!(many.len(), jobs.len());
        for (j, r) in jobs.iter().zip(&many) {
            let solo = leader.run(j).unwrap();
            let r = r.as_ref().unwrap();
            assert_eq!(solo.result.set, r.result.set, "{}: set diverged", solo.algorithm);
            assert_eq!(
                solo.result.value.to_bits(),
                r.result.value.to_bits(),
                "{}: value not byte-identical",
                solo.algorithm
            );
            assert_eq!(solo.result.queries, r.result.queries, "{}", solo.algorithm);
            assert_eq!(solo.result.rounds, r.result.rounds, "{}", solo.algorithm);
        }
        // multiplexed lanes reported their per-session metrics
        assert!(leader.metrics.counter("session.inserts") > 0);
        assert!(leader.metrics.counter("session.fresh_queries") > 0);
    }

    #[test]
    fn driver_for_covers_the_oracle_algorithms() {
        for alg in [
            AlgorithmChoice::Dash(DashConfig::default()),
            AlgorithmChoice::Greedy(GreedyConfig::default()),
            AlgorithmChoice::Greedy(GreedyConfig { lazy: true, ..Default::default() }),
            AlgorithmChoice::ParallelGreedy { cfg: GreedyConfig::default(), threads: 2 },
            AlgorithmChoice::TopK,
            AlgorithmChoice::AdaptiveSampling(AdaptiveSamplingConfig::default()),
            AlgorithmChoice::AdaptiveSequencing(AdaptiveSequencingConfig::default()),
        ] {
            assert!(Leader::driver_for(&job(alg)).is_some());
        }
        assert!(Leader::driver_for(&job(AlgorithmChoice::Random { trials: 1 })).is_none());
        assert!(Leader::driver_for(&job(AlgorithmChoice::Lasso(LassoConfig::default()))).is_none());
    }

    #[test]
    fn serve_driven_lane_matches_solo_run_and_records_metrics() {
        let leader = Leader::with_threads(2);
        let greedy = job(AlgorithmChoice::Greedy(GreedyConfig::default()));
        let adhoc = job(AlgorithmChoice::TopK);
        let n = greedy.dataset.n();
        let specs =
            vec![ServeSpec::driven(greedy.clone()), ServeSpec::adhoc(adhoc)];
        let (served, summary) = leader
            .serve(&specs, ServeConfig::default(), move |clients| {
                // grow the ad-hoc lane, then read it back
                let (grew, generation) = clients[1].insert(3).unwrap();
                assert!(grew);
                assert_eq!(generation, 1);
                let sw = clients[1].sweep(&(0..n).collect::<Vec<_>>()).unwrap();
                assert_eq!(sw.generation, 1);
                assert_eq!(sw.gains.len(), n);
                // drive the greedy lane to completion
                clients[0].drive().unwrap()
            })
            .unwrap();
        let solo = leader.run(&greedy).unwrap();
        assert_eq!(served.set, solo.result.set);
        assert_eq!(served.value.to_bits(), solo.result.value.to_bits());
        assert_eq!(served.queries, solo.result.queries);
        assert_eq!(summary.metrics.inserts, 1);
        assert_eq!(summary.metrics.sweep_requests, 1);
        assert_eq!(summary.sessions[1].generation.0, 1);
        assert_eq!(summary.sessions[1].set, vec![3]);
        assert!(leader.metrics.counter("serve.requests") >= 3);
    }

    #[test]
    fn serve_rejects_driverless_algorithms_in_driven_lanes() {
        let leader = Leader::with_threads(1);
        let specs = vec![ServeSpec::driven(job(AlgorithmChoice::Random { trials: 2 }))];
        let err = leader
            .serve(&specs, ServeConfig::default(), |clients| drop(clients))
            .unwrap_err();
        assert!(matches!(err, SelectError::InvalidSpec(_)), "{err:?}");
        assert!(err.to_string().contains("no stepwise driver"), "{err}");
    }

    #[test]
    fn lasso_value_is_objective_eval() {
        let leader = Leader::new();
        let report = leader.run(&job(AlgorithmChoice::Lasso(LassoConfig::default()))).unwrap();
        assert!((report.result.value - report.native_value).abs() < 1e-12);
        assert!(report.result.value > 0.0);
    }

    #[test]
    fn classification_job_uses_logistic_lasso() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::classification_d3(&mut rng, 150, 15, 5, 0.2);
        let leader = Leader::new();
        let j = SelectionJob {
            dataset: Arc::new(ds),
            objective: ObjectiveChoice::Logistic,
            backend: Backend::Native,
            algorithm: AlgorithmChoice::Lasso(LassoConfig {
                max_iters: 100,
                ..Default::default()
            }),
            k: 4,
            seed: 3,
        };
        let report = leader.run(&j).unwrap();
        assert_eq!(report.algorithm, "lasso_logistic");
        assert!(report.result.set.len() <= 4);
    }
}
