//! Dynamic request batching: aggregates small gain queries arriving from
//! concurrent callers into fixed-size batches matched to the XLA
//! artifacts' padded candidate shape, flushing on size or deadline —
//! the same size-or-timeout discipline a serving router applies to
//! incoming requests.
//!
//! The serving constructor [`BatchQueue::for_state`] is a thin
//! *generation-aware* front over one long-lived solution state: flushes
//! answer batched marginal gains through the shared
//! [`BatchExecutor`] with a generation-keyed [`GainCache`] memo in front,
//! and [`BatchQueue::insert`] grows the state in place — bumping the
//! generation and logically invalidating the memo in O(1) — so one queue
//! keeps serving across inserts instead of being rebuilt per state
//! generation.
//!
//! Telemetry (`flushes`, the last-flush deadline stamp) is kept in atomics;
//! the hot submit/flush path takes no lock beyond the pending queue itself.
//!
//! Failure containment: the flush function is caller-supplied code. If it
//! panics, or returns the wrong number of results for the batch it was
//! handed, every submitter waiting on that batch gets a typed
//! [`SelectError`] reply instead of a hung channel or a silently dropped
//! answer — and the queue itself stays serviceable for the next batch
//! (poisoned internal locks are recovered by the `util::sync` wrappers,
//! since every guarded region leaves the data structurally valid).

use crate::coordinator::api::SelectError;
use crate::objectives::ObjectiveState;
use crate::oracle::{BatchExecutor, GainCache};
use crate::util::sync::Mutex;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration for [`BatchQueue`].
#[derive(Debug, Clone)]
pub struct BatchQueueConfig {
    /// flush when this many items are queued (the artifact's nc)
    pub max_batch: usize,
    /// flush a non-empty queue after this long regardless of size
    pub max_wait: Duration,
}

impl Default for BatchQueueConfig {
    fn default() -> Self {
        BatchQueueConfig { max_batch: 256, max_wait: Duration::from_millis(2) }
    }
}

struct Pending {
    item: usize,
    reply: Sender<Result<f64, SelectError>>,
}

/// The served state behind a [`BatchQueue::for_state`] queue. Lock order
/// is state → cache everywhere (flush and insert), so the two never
/// deadlock against each other.
struct ServedState {
    state: Mutex<Box<dyn ObjectiveState>>,
    cache: Mutex<GainCache>,
    /// state generation: bumped by every [`BatchQueue::insert`]
    generation: AtomicU64,
}

/// A size-or-deadline batch queue over candidate indices. The flush
/// function evaluates a whole batch at once (one XLA dispatch) and the
/// results are routed back to the individual submitters.
pub struct BatchQueue {
    cfg: BatchQueueConfig,
    queue: Arc<Mutex<Vec<Pending>>>,
    flush_fn: Arc<dyn Fn(&[usize]) -> Vec<f64> + Send + Sync>,
    /// queue birth; deadline math is done in nanos relative to this
    epoch: Instant,
    /// nanos-since-epoch of the last flush (atomic: no lock on the
    /// deadline check every submit performs)
    last_flush_nanos: AtomicU64,
    /// total batches flushed (telemetry)
    flushes: AtomicUsize,
    /// generation-aware serving state when built with
    /// [`BatchQueue::for_state`]
    served: Option<Arc<ServedState>>,
}

impl BatchQueue {
    pub fn new(
        cfg: BatchQueueConfig,
        flush_fn: impl Fn(&[usize]) -> Vec<f64> + Send + Sync + 'static,
    ) -> Self {
        BatchQueue {
            cfg,
            queue: Arc::new(Mutex::new(Vec::new())),
            flush_fn: Arc::new(flush_fn),
            epoch: Instant::now(),
            last_flush_nanos: AtomicU64::new(0),
            flushes: AtomicUsize::new(0),
            served: None,
        }
    }

    /// Serving-side constructor: a queue whose flushes evaluate batched
    /// marginal gains for one long-lived solution state through the shared
    /// [`BatchExecutor`], with a generation-keyed [`GainCache`] memo in
    /// front so repeated requests for the same candidate are answered
    /// without touching the oracle. The queue is generation-aware:
    /// [`BatchQueue::insert`] grows the state in place and logically
    /// invalidates the memo (O(1) generation bump), so the same queue
    /// keeps serving across inserts. `n` is the objective's ground-set
    /// size.
    pub fn for_state(
        cfg: BatchQueueConfig,
        exec: BatchExecutor,
        state: Box<dyn ObjectiveState>,
        n: usize,
    ) -> Self {
        let served = Arc::new(ServedState {
            state: Mutex::new(state),
            cache: Mutex::new(GainCache::new(n)),
            generation: AtomicU64::new(0),
        });
        let served_for_flush = Arc::clone(&served);
        let mut queue = Self::new(cfg, move |items: &[usize]| {
            // lock order: state → cache (matches `insert`; the wrapper's
            // lock-order detector checks this invariant in instrumented
            // builds)
            let st = served_for_flush.state.lock();
            let mut memo = served_for_flush.cache.lock();
            let (vals, _fresh) = exec.cached_gains(&mut memo, &**st, items);
            vals
        });
        queue.served = Some(served);
        queue
    }

    /// Grow the served solution set: `S ← S ∪ {a}`. Bumps the state
    /// generation and logically invalidates the gain memo (O(1)); the
    /// queue keeps serving — subsequent flushes answer against the new
    /// state. Returns the new generation.
    ///
    /// The pending queue is flushed first as a best-effort courtesy, so
    /// requests that fully queued before the insert are *usually* answered
    /// against the pre-insert state — but this is not a guarantee: a
    /// submitter whose own flush has drained the queue but not yet reached
    /// the state lock can still be answered post-insert. Replies here are
    /// bare gains with no generation stamp; callers that need to know
    /// which generation answered must use the generation-stamped serving
    /// front ([`coordinator::serve`](crate::coordinator::serve)) instead.
    ///
    /// Queues not built with [`BatchQueue::for_state`] have no state to
    /// grow; inserting into one is a typed [`SelectError::Rejected`], never
    /// a panic — the serving stack routes arbitrary client traffic here.
    pub fn insert(&self, a: usize) -> Result<u64, SelectError> {
        let served = self.served.as_ref().ok_or_else(|| {
            SelectError::Rejected(
                "insert requires a for_state queue (this queue serves a bare flush function, \
                 not a solution state)"
                    .into(),
            )
        })?;
        // answer the backlog against the state it was submitted under
        self.flush();
        // lock order: state → cache (matches the flush closure)
        let mut st = served.state.lock();
        st.insert(a);
        served.cache.lock().invalidate();
        Ok(served.generation.fetch_add(1, Ordering::Relaxed) + 1)
    }

    /// Current state generation (0 for plain queues or before any insert).
    pub fn generation(&self) -> u64 {
        self.served
            .as_ref()
            .map(|s| s.generation.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// `(hits, misses)` of the memo layer (0,0 for plain queues).
    pub fn cache_stats(&self) -> (usize, usize) {
        self.served
            .as_ref()
            .map(|s| {
                let c = s.cache.lock();
                (c.hits, c.misses)
            })
            .unwrap_or((0, 0))
    }

    fn nanos_since_epoch(&self) -> u64 {
        u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }

    fn deadline_expired(&self) -> bool {
        let since_flush =
            self.nanos_since_epoch().saturating_sub(self.last_flush_nanos.load(Ordering::Relaxed));
        u128::from(since_flush) >= self.cfg.max_wait.as_nanos()
    }

    /// Submit one candidate; blocks until its batch is evaluated and
    /// returns its gain. Deadline-based flushing happens opportunistically
    /// on submit (no background thread needed for the synchronous callers
    /// this library has).
    ///
    /// A panicking flush function surfaces as
    /// [`SelectError::ClientPanic`]; a flush function that returns the
    /// wrong number of results for its batch surfaces as
    /// [`SelectError::Backend`]. Either way every waiter on that batch is
    /// answered and the queue keeps serving.
    pub fn submit(&self, item: usize) -> Result<f64, SelectError> {
        let (tx, rx): (Sender<Result<f64, SelectError>>, Receiver<Result<f64, SelectError>>) =
            channel();
        let should_flush = {
            let mut q = self.queue.lock();
            q.push(Pending { item, reply: tx });
            q.len() >= self.cfg.max_batch || self.deadline_expired()
        };
        if should_flush {
            self.flush();
        }
        // if our reply hasn't arrived, force a flush (covers the race where
        // another submitter drained the queue without our entry... or the
        // deadline not yet reached with no further traffic)
        match rx.try_recv() {
            Ok(v) => v,
            Err(_) => {
                self.flush();
                rx.recv().unwrap_or_else(|_| {
                    Err(SelectError::Backend("batch flush dropped a reply".into()))
                })
            }
        }
    }

    /// Submit many candidates at once (bypasses the queue when the batch is
    /// already full-size). Fails as a unit: one flush error fails the
    /// whole call.
    pub fn submit_many(&self, items: &[usize]) -> Result<Vec<f64>, SelectError> {
        if items.len() >= self.cfg.max_batch {
            self.flushes.fetch_add(1, Ordering::Relaxed);
            return Self::evaluate(&self.flush_fn, items);
        }
        items.iter().map(|&i| self.submit(i)).collect()
    }

    /// Run the flush function over one batch, containing panics and
    /// validating the result length against the batch it was handed.
    fn evaluate(
        flush_fn: &Arc<dyn Fn(&[usize]) -> Vec<f64> + Send + Sync>,
        items: &[usize],
    ) -> Result<Vec<f64>, SelectError> {
        let results = catch_unwind(AssertUnwindSafe(|| flush_fn(items))).map_err(|payload| {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            SelectError::ClientPanic(msg)
        })?;
        if results.len() != items.len() {
            return Err(SelectError::Backend(format!(
                "batch flush returned {} results for {} items",
                results.len(),
                items.len()
            )));
        }
        Ok(results)
    }

    /// Drain and evaluate the queue. Every drained submitter is answered:
    /// with its gain on success, or with the batch's typed error when the
    /// flush function panicked or returned a short/long result vector.
    pub fn flush(&self) {
        let pending: Vec<Pending> = {
            let mut q = self.queue.lock();
            std::mem::take(&mut *q)
        };
        if pending.is_empty() {
            return;
        }
        self.last_flush_nanos.store(self.nanos_since_epoch(), Ordering::Relaxed);
        self.flushes.fetch_add(1, Ordering::Relaxed);
        let items: Vec<usize> = pending.iter().map(|p| p.item).collect();
        match Self::evaluate(&self.flush_fn, &items) {
            Ok(results) => {
                for (p, v) in pending.into_iter().zip(results) {
                    let _ = p.reply.send(Ok(v));
                }
            }
            Err(e) => {
                for p in pending {
                    let _ = p.reply.send(Err(e.clone()));
                }
            }
        }
    }

    pub fn flush_count(&self) -> usize {
        self.flushes.load(Ordering::Relaxed)
    }

    pub fn queued(&self) -> usize {
        self.queue.lock().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::ThreadPool;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn batches_by_size() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&calls);
        let q = BatchQueue::new(
            BatchQueueConfig { max_batch: 4, max_wait: Duration::from_secs(60) },
            move |items| {
                c2.fetch_add(1, Ordering::SeqCst);
                items.iter().map(|&i| i as f64 * 2.0).collect()
            },
        );
        let out = q.submit_many(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0]);
        // full-size batches bypass: exactly one flush for 8 >= max_batch
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn small_submissions_get_answered() {
        let q = BatchQueue::new(
            BatchQueueConfig { max_batch: 100, max_wait: Duration::from_millis(0) },
            |items| items.iter().map(|&i| i as f64 + 0.5).collect(),
        );
        assert_eq!(q.submit(7).unwrap(), 7.5);
        assert_eq!(q.submit(9).unwrap(), 9.5);
        assert!(q.flush_count() >= 2);
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn concurrent_submitters_all_answered() {
        let evaluated = Arc::new(AtomicUsize::new(0));
        let e2 = Arc::clone(&evaluated);
        let q = Arc::new(BatchQueue::new(
            BatchQueueConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            move |items| {
                e2.fetch_add(items.len(), Ordering::SeqCst);
                items.iter().map(|&i| (i * i) as f64).collect()
            },
        ));
        let pool = ThreadPool::new(4);
        let q2 = Arc::clone(&q);
        let results = pool.parallel_map(64, move |i| q2.submit(i).unwrap());
        for (i, v) in results.iter().enumerate() {
            assert_eq!(*v, (i * i) as f64, "item {i}");
        }
        assert_eq!(evaluated.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn for_state_serves_cached_gains() {
        let mut rng = crate::rng::Pcg64::seed_from(5);
        let ds = crate::data::synthetic::regression_d1(&mut rng, 60, 20, 6, 0.2);
        let obj = crate::objectives::LinearRegressionObjective::new(&ds);
        use crate::objectives::Objective;
        let st = obj.state_for(&[2, 9]);
        let expected = st.gains(&(0..20).collect::<Vec<_>>());
        let q = BatchQueue::for_state(
            BatchQueueConfig { max_batch: 8, max_wait: Duration::from_millis(0) },
            crate::oracle::BatchExecutor::sequential(),
            obj.state_for(&[2, 9]),
            obj.n(),
        );
        // first wave: every candidate is a miss
        let out = q.submit_many(&(0..20).collect::<Vec<_>>()).unwrap();
        for (o, e) in out.iter().zip(&expected) {
            assert!((o - e).abs() < 1e-14);
        }
        let (_, misses_after_first) = q.cache_stats();
        assert_eq!(misses_after_first, 20);
        // second wave over the same state generation: all hits, no new
        // oracle work
        let again = q.submit_many(&[3, 7, 11]).unwrap();
        assert!((again[0] - expected[3]).abs() < 1e-14);
        let (hits, misses) = q.cache_stats();
        assert_eq!(misses, 20, "repeat requests must not re-query");
        assert!(hits >= 3);
    }

    #[test]
    fn queue_keeps_serving_across_inserts() {
        let mut rng = crate::rng::Pcg64::seed_from(9);
        let ds = crate::data::synthetic::regression_d1(&mut rng, 60, 20, 6, 0.2);
        let obj = crate::objectives::LinearRegressionObjective::new(&ds);
        use crate::objectives::Objective;
        let q = BatchQueue::for_state(
            BatchQueueConfig { max_batch: 8, max_wait: Duration::from_millis(0) },
            crate::oracle::BatchExecutor::sequential(),
            obj.empty_state(),
            obj.n(),
        );
        assert_eq!(q.generation(), 0);
        let all: Vec<usize> = (0..obj.n()).collect();
        let before = q.submit_many(&all).unwrap();
        assert_eq!(before, obj.empty_state().gains(&all));
        // grow the served state: the SAME queue must answer for S = {4}
        assert_eq!(q.insert(4).unwrap(), 1);
        let after = q.submit_many(&all).unwrap();
        let expected = obj.state_for(&[4]).gains(&all);
        for (a, e) in after.iter().zip(&expected) {
            assert!((a - e).abs() < 1e-14, "stale-generation answer served");
        }
        let (_, misses) = q.cache_stats();
        assert_eq!(misses, 2 * obj.n(), "insert must invalidate the memo");
        assert_eq!(q.generation(), 1);
    }

    #[test]
    fn insert_on_plain_queue_is_a_typed_rejection() {
        let q = BatchQueue::new(BatchQueueConfig::default(), |items| {
            items.iter().map(|_| 0.0).collect()
        });
        // no panic: the serving stack routes arbitrary traffic here, so a
        // plain queue answers insert with a typed error and keeps serving
        match q.insert(3) {
            Err(SelectError::Rejected(msg)) => assert!(msg.contains("for_state"), "{msg}"),
            other => panic!("expected typed rejection, got {other:?}"),
        }
        assert_eq!(q.generation(), 0, "a rejected insert must not bump the generation");
        assert_eq!(q.submit(5).unwrap(), 0.0, "queue must keep serving after the rejection");
    }

    /// Pin the documented post-insert-answer race note on `insert`: a
    /// submitter racing an insert — its own flush may drain the queue yet
    /// reach the state lock only after the insert — is answered against
    /// *either* the pre- or post-insert state. Always exactly one
    /// generation's value: never a hang, never a panic, never a torn mix,
    /// and once the insert has returned every later answer is post-insert.
    #[test]
    fn racing_inserts_answer_exactly_one_generation() {
        let mut rng = crate::rng::Pcg64::seed_from(11);
        let ds = crate::data::synthetic::regression_d1(&mut rng, 60, 20, 6, 0.2);
        let obj = crate::objectives::LinearRegressionObjective::new(&ds);
        use crate::objectives::Objective;
        let all: Vec<usize> = (0..obj.n()).collect();
        let pre = obj.empty_state().gains(&all);
        let post = obj.state_for(&[4]).gains(&all);

        let q = std::sync::Arc::new(BatchQueue::for_state(
            BatchQueueConfig { max_batch: 2, max_wait: Duration::from_millis(0) },
            crate::oracle::BatchExecutor::sequential(),
            obj.empty_state(),
            obj.n(),
        ));
        let racers: Vec<_> = (0..3)
            .map(|t: usize| {
                let q = std::sync::Arc::clone(&q);
                std::thread::spawn(move || {
                    (0..20)
                        .map(|i| ((t + i) % 20, q.submit((t + i) % 20).unwrap()))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        assert_eq!(q.insert(4).unwrap(), 1);
        for r in racers {
            for (i, got) in r.join().unwrap() {
                let ok = (got - pre[i]).abs() < 1e-14 || (got - post[i]).abs() < 1e-14;
                assert!(
                    ok,
                    "candidate {i}: answer {got} matches neither the pre-insert ({}) nor \
                     the post-insert ({}) generation",
                    pre[i], post[i]
                );
            }
        }
        // the race window is closed once insert has returned: subsequent
        // answers are all post-insert
        let settled = q.submit_many(&all).unwrap();
        for (i, got) in settled.iter().enumerate() {
            assert!((got - post[i]).abs() < 1e-14, "candidate {i} answered stale after insert");
        }
    }

    #[test]
    fn flush_on_empty_is_noop() {
        let q = BatchQueue::new(BatchQueueConfig::default(), |items| {
            items.iter().map(|_| 0.0).collect()
        });
        q.flush();
        assert_eq!(q.flush_count(), 0);
    }

    #[test]
    fn short_flush_results_fail_every_waiter_typed() {
        // flush function drops the last result on its first batch, then
        // behaves: waiters on the bad batch must all get a typed Backend
        // error (not a hang, not a silently missing reply), and the queue
        // must keep serving afterwards.
        let calls = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&calls);
        let q = BatchQueue::new(
            BatchQueueConfig { max_batch: 100, max_wait: Duration::from_millis(0) },
            move |items| {
                let first = c2.fetch_add(1, Ordering::SeqCst) == 0;
                let keep = if first { items.len() - 1 } else { items.len() };
                items.iter().take(keep).map(|&i| i as f64).collect()
            },
        );
        let err = q.submit(5).unwrap_err();
        match &err {
            SelectError::Backend(m) => {
                assert!(m.contains("0 results for 1 items"), "got: {m}")
            }
            other => panic!("expected Backend, got {other:?}"),
        }
        assert_eq!(q.queued(), 0, "failed batch must still drain");
        assert_eq!(q.submit(5).unwrap(), 5.0, "queue must keep serving");
        // the full-size bypass path validates lengths too
        let calls2 = Arc::new(AtomicUsize::new(0));
        let c3 = Arc::clone(&calls2);
        let q2 = BatchQueue::new(
            BatchQueueConfig { max_batch: 2, max_wait: Duration::from_secs(60) },
            move |_items| {
                c3.fetch_add(1, Ordering::SeqCst);
                vec![1.0] // always short for a 2+ batch
            },
        );
        assert!(matches!(q2.submit_many(&[0, 1, 2]), Err(SelectError::Backend(_))));
        assert_eq!(calls2.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn panicking_flush_is_contained_as_client_panic() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&calls);
        let q = BatchQueue::new(
            BatchQueueConfig { max_batch: 100, max_wait: Duration::from_millis(0) },
            move |items| {
                if c2.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("flush backend fell over");
                }
                items.iter().map(|&i| i as f64).collect()
            },
        );
        let err = q.submit(3).unwrap_err();
        match &err {
            SelectError::ClientPanic(m) => {
                assert!(m.contains("fell over"), "panic message must ride along: {m}")
            }
            other => panic!("expected ClientPanic, got {other:?}"),
        }
        // the panic must not poison the queue: later submits still work
        assert_eq!(q.submit(4).unwrap(), 4.0);
        assert_eq!(q.queued(), 0);
    }
}
