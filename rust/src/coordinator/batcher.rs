//! Dynamic request batching: aggregates small gain queries arriving from
//! concurrent callers into fixed-size batches matched to the XLA
//! artifacts' padded candidate shape, flushing on size or deadline —
//! the same size-or-timeout discipline a serving router applies to
//! incoming requests.

use crate::objectives::ObjectiveState;
use crate::oracle::{BatchExecutor, GainCache};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Configuration for [`BatchQueue`].
#[derive(Debug, Clone)]
pub struct BatchQueueConfig {
    /// flush when this many items are queued (the artifact's nc)
    pub max_batch: usize,
    /// flush a non-empty queue after this long regardless of size
    pub max_wait: Duration,
}

impl Default for BatchQueueConfig {
    fn default() -> Self {
        BatchQueueConfig { max_batch: 256, max_wait: Duration::from_millis(2) }
    }
}

struct Pending {
    item: usize,
    reply: Sender<f64>,
}

/// A size-or-deadline batch queue over candidate indices. The flush
/// function evaluates a whole batch at once (one XLA dispatch) and the
/// results are routed back to the individual submitters.
pub struct BatchQueue {
    cfg: BatchQueueConfig,
    queue: Arc<Mutex<Vec<Pending>>>,
    flush_fn: Arc<dyn Fn(&[usize]) -> Vec<f64> + Send + Sync>,
    last_flush: Arc<Mutex<Instant>>,
    /// total batches flushed (telemetry)
    flushes: Arc<Mutex<usize>>,
    /// memo layer when built with [`BatchQueue::for_state`]
    cache: Option<Arc<Mutex<GainCache>>>,
}

impl BatchQueue {
    pub fn new(
        cfg: BatchQueueConfig,
        flush_fn: impl Fn(&[usize]) -> Vec<f64> + Send + Sync + 'static,
    ) -> Self {
        BatchQueue {
            cfg,
            queue: Arc::new(Mutex::new(Vec::new())),
            flush_fn: Arc::new(flush_fn),
            last_flush: Arc::new(Mutex::new(Instant::now())),
            flushes: Arc::new(Mutex::new(0)),
            cache: None,
        }
    }

    /// Serving-side constructor: a queue whose flushes evaluate batched
    /// marginal gains for one frozen solution state through the shared
    /// [`BatchExecutor`], with a [`GainCache`] memo in front so repeated
    /// requests for the same candidate are answered without touching the
    /// oracle. One queue serves one state generation; build a fresh queue
    /// when the solution set changes. `n` is the objective's ground-set
    /// size.
    pub fn for_state(
        cfg: BatchQueueConfig,
        exec: BatchExecutor,
        state: Box<dyn ObjectiveState>,
        n: usize,
    ) -> Self {
        let cache = Arc::new(Mutex::new(GainCache::new(n)));
        let cache_for_flush = Arc::clone(&cache);
        let mut queue = Self::new(cfg, move |items: &[usize]| {
            let mut memo = cache_for_flush.lock().unwrap();
            let (vals, _fresh) = exec.cached_gains(&mut memo, &*state, items);
            vals
        });
        queue.cache = Some(cache);
        queue
    }

    /// `(hits, misses)` of the memo layer (0,0 for plain queues).
    pub fn cache_stats(&self) -> (usize, usize) {
        self.cache
            .as_ref()
            .map(|c| {
                let c = c.lock().unwrap();
                (c.hits, c.misses)
            })
            .unwrap_or((0, 0))
    }

    /// Submit one candidate; blocks until its batch is evaluated and
    /// returns its gain. Deadline-based flushing happens opportunistically
    /// on submit (no background thread needed for the synchronous callers
    /// this library has).
    pub fn submit(&self, item: usize) -> f64 {
        let (tx, rx): (Sender<f64>, Receiver<f64>) = channel();
        let should_flush = {
            let mut q = self.queue.lock().unwrap();
            q.push(Pending { item, reply: tx });
            q.len() >= self.cfg.max_batch
                || self.last_flush.lock().unwrap().elapsed() >= self.cfg.max_wait
        };
        if should_flush {
            self.flush();
        }
        // if our reply hasn't arrived, force a flush (covers the race where
        // another submitter drained the queue without our entry... or the
        // deadline not yet reached with no further traffic)
        match rx.try_recv() {
            Ok(v) => v,
            Err(_) => {
                self.flush();
                rx.recv().expect("batch flush must answer")
            }
        }
    }

    /// Submit many candidates at once (bypasses the queue when the batch is
    /// already full-size).
    pub fn submit_many(&self, items: &[usize]) -> Vec<f64> {
        if items.len() >= self.cfg.max_batch {
            *self.flushes.lock().unwrap() += 1;
            return (self.flush_fn)(items);
        }
        items.iter().map(|&i| self.submit(i)).collect()
    }

    /// Drain and evaluate the queue.
    pub fn flush(&self) {
        let pending: Vec<Pending> = {
            let mut q = self.queue.lock().unwrap();
            std::mem::take(&mut *q)
        };
        if pending.is_empty() {
            return;
        }
        *self.last_flush.lock().unwrap() = Instant::now();
        *self.flushes.lock().unwrap() += 1;
        let items: Vec<usize> = pending.iter().map(|p| p.item).collect();
        let results = (self.flush_fn)(&items);
        debug_assert_eq!(results.len(), items.len());
        for (p, v) in pending.into_iter().zip(results) {
            let _ = p.reply.send(v);
        }
    }

    pub fn flush_count(&self) -> usize {
        *self.flushes.lock().unwrap()
    }

    pub fn queued(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::ThreadPool;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn batches_by_size() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&calls);
        let q = BatchQueue::new(
            BatchQueueConfig { max_batch: 4, max_wait: Duration::from_secs(60) },
            move |items| {
                c2.fetch_add(1, Ordering::SeqCst);
                items.iter().map(|&i| i as f64 * 2.0).collect()
            },
        );
        let out = q.submit_many(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0]);
        // full-size batches bypass: exactly one flush for 8 >= max_batch
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn small_submissions_get_answered() {
        let q = BatchQueue::new(
            BatchQueueConfig { max_batch: 100, max_wait: Duration::from_millis(0) },
            |items| items.iter().map(|&i| i as f64 + 0.5).collect(),
        );
        assert_eq!(q.submit(7), 7.5);
        assert_eq!(q.submit(9), 9.5);
        assert!(q.flush_count() >= 2);
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn concurrent_submitters_all_answered() {
        let evaluated = Arc::new(AtomicUsize::new(0));
        let e2 = Arc::clone(&evaluated);
        let q = Arc::new(BatchQueue::new(
            BatchQueueConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            move |items| {
                e2.fetch_add(items.len(), Ordering::SeqCst);
                items.iter().map(|&i| (i * i) as f64).collect()
            },
        ));
        let pool = ThreadPool::new(4);
        let q2 = Arc::clone(&q);
        let results = pool.parallel_map(64, move |i| q2.submit(i));
        for (i, v) in results.iter().enumerate() {
            assert_eq!(*v, (i * i) as f64, "item {i}");
        }
        assert_eq!(evaluated.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn for_state_serves_cached_gains() {
        let mut rng = crate::rng::Pcg64::seed_from(5);
        let ds = crate::data::synthetic::regression_d1(&mut rng, 60, 20, 6, 0.2);
        let obj = crate::objectives::LinearRegressionObjective::new(&ds);
        use crate::objectives::Objective;
        let st = obj.state_for(&[2, 9]);
        let expected = st.gains(&(0..20).collect::<Vec<_>>());
        let q = BatchQueue::for_state(
            BatchQueueConfig { max_batch: 8, max_wait: Duration::from_millis(0) },
            crate::oracle::BatchExecutor::sequential(),
            obj.state_for(&[2, 9]),
            obj.n(),
        );
        // first wave: every candidate is a miss
        let out = q.submit_many(&(0..20).collect::<Vec<_>>());
        for (o, e) in out.iter().zip(&expected) {
            assert!((o - e).abs() < 1e-14);
        }
        let (_, misses_after_first) = q.cache_stats();
        assert_eq!(misses_after_first, 20);
        // second wave over the same state generation: all hits, no new
        // oracle work
        let again = q.submit_many(&[3, 7, 11]);
        assert!((again[0] - expected[3]).abs() < 1e-14);
        let (hits, misses) = q.cache_stats();
        assert_eq!(misses, 20, "repeat requests must not re-query");
        assert!(hits >= 3);
    }

    #[test]
    fn flush_on_empty_is_noop() {
        let q = BatchQueue::new(BatchQueueConfig::default(), |items| {
            items.iter().map(|_| 0.0).collect()
        });
        q.flush();
        assert_eq!(q.flush_count(), 0);
    }
}
