//! XLA-backed objectives: drop-in [`Objective`] implementations whose
//! batched candidate sweeps — the per-round hot path — execute on the PJRT
//! runtime via the AOT-compiled Pallas kernels, while the O(d·|S|)/O(d²)
//! state updates stay in native rust.
//!
//! Division of labor per query round (n candidates, d samples, |S| = s):
//!
//! | op                | cost      | where                              |
//! |-------------------|-----------|------------------------------------|
//! | batched gains     | O(n·d·s)  | XLA artifact (Pallas kernel)       |
//! | insert (lreg)     | O(d·s)    | rust (incremental QR)              |
//! | insert (aopt)     | O(d²)     | rust (Sherman–Morrison)            |
//! | insert (logistic) | O(d·s²)   | rust (warm-started Newton)         |
//!
//! The logistic XLA oracle serves **one-step (score-test) gains** — the
//! quadratic approximation of the refit gain; inserts still refit exactly.
//! This mirrors the standard expensive-oracle practice and is recorded in
//! DESIGN.md; the native `LogisticObjective` remains the exact-refit
//! reference.

use crate::data::Dataset;
use crate::linalg::{dot, IncrementalQr, Matrix};
use crate::objectives::{Objective, ObjectiveState, SweepScratch, SWEEP_BLOCK};
use crate::runtime::{ArtifactKind, GainExecutor, Manifest};
use anyhow::Result;
use std::sync::Arc;

// ---------------------------------------------------------------- lreg --

struct XlaLregShared {
    x: Matrix,
    y: Vec<f64>,
    y_sq: f64,
    exec: GainExecutor,
    name: String,
}

/// Linear-regression objective with XLA-batched gains.
#[derive(Clone)]
pub struct XlaLregObjective {
    p: Arc<XlaLregShared>,
}

impl XlaLregObjective {
    /// `s_max` bounds the basis size the artifact must accommodate
    /// (usually the cardinality constraint k).
    pub fn new(ds: &Dataset, manifest: &Manifest, s_max: usize) -> Result<Self> {
        let exec = GainExecutor::for_kind(manifest, ArtifactKind::Lreg, ds.d(), s_max)?;
        let y_sq = dot(&ds.y, &ds.y).max(1e-300);
        Ok(XlaLregObjective {
            p: Arc::new(XlaLregShared {
                x: ds.x.clone(),
                y: ds.y.clone(),
                y_sq,
                exec,
                name: format!("xla-lreg[{}]", ds.name),
            }),
        })
    }
}

struct XlaLregState {
    p: Arc<XlaLregShared>,
    qr: IncrementalQr,
    r: Vec<f64>,
    value: f64,
    set: Vec<usize>,
    in_set: Vec<bool>,
}

impl ObjectiveState for XlaLregState {
    fn value(&self) -> f64 {
        self.value
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        if self.in_set[a] {
            return;
        }
        self.in_set[a] = true;
        self.set.push(a);
        let before = self.qr.rank();
        if self.qr.push_col(self.p.x.col(a)) {
            let q = self.qr.basis_col(before);
            let c = dot(q, &self.r);
            crate::linalg::axpy(-c, q, &mut self.r);
            self.value += c * c / self.p.y_sq;
        }
    }

    fn gain(&self, a: usize) -> f64 {
        // single-candidate queries stay native (same math, no batch win)
        if self.in_set[a] {
            return 0.0;
        }
        let x = self.p.x.col(a);
        let num = dot(x, &self.r);
        let den = self.qr.residual_sq(x);
        if den <= 1e-10 * dot(x, x).max(1e-300) {
            return 0.0;
        }
        (num * num / den).max(0.0) / self.p.y_sq
    }

    fn gains(&self, candidates: &[usize]) -> Vec<f64> {
        // basis can exceed the artifact's padded s if k was underestimated;
        // fall back to native math in that case rather than failing
        if self.qr.rank() > self.p.exec.artifact().s {
            return candidates.iter().map(|&a| self.gain(a)).collect();
        }
        match self.p.exec.lreg_gains(self.qr.basis(), &self.r, &self.p.x, candidates) {
            Ok(raw) => raw
                .into_iter()
                .zip(candidates)
                .map(|(g, &a)| if self.in_set[a] { 0.0 } else { (g / self.p.y_sq).max(0.0) })
                .collect(),
            Err(e) => {
                crate::log_warn!("xla lreg gains failed ({e}); native fallback");
                candidates.iter().map(|&a| self.gain(a)).collect()
            }
        }
    }

    fn gains_into(&self, candidates: &[usize], _scratch: &mut SweepScratch, out: &mut [f64]) {
        // the XLA dispatch is already a blocked batch (read-only over the
        // padded artifact shapes); route the engine's blocked sweep
        // straight through it
        out.copy_from_slice(&self.gains(candidates));
    }

    fn sweep_block(&self) -> usize {
        // shard at the artifact's padded candidate shape: smaller blocks
        // would fragment one padded dispatch into many
        self.p.exec.artifact().nc.max(SWEEP_BLOCK)
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(XlaLregState {
            p: Arc::clone(&self.p),
            qr: self.qr.clone(),
            r: self.r.clone(),
            value: self.value,
            set: self.set.clone(),
            in_set: self.in_set.clone(),
        })
    }
}

impl Objective for XlaLregObjective {
    fn n(&self) -> usize {
        self.p.x.cols()
    }

    fn name(&self) -> &str {
        &self.p.name
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(XlaLregState {
            p: Arc::clone(&self.p),
            qr: IncrementalQr::new(self.p.x.rows()),
            r: self.p.y.clone(),
            value: 0.0,
            set: Vec::new(),
            in_set: vec![false; self.p.x.cols()],
        })
    }
}

// ---------------------------------------------------------------- aopt --

struct XlaAoptShared {
    x: Matrix,
    beta_sq: f64,
    sigma_sq_inv: f64,
    prior_trace: f64,
    exec: GainExecutor,
    name: String,
}

/// A-optimality objective with XLA-batched gains.
#[derive(Clone)]
pub struct XlaAoptObjective {
    p: Arc<XlaAoptShared>,
}

impl XlaAoptObjective {
    pub fn new(ds: &Dataset, manifest: &Manifest, beta_sq: f64, sigma_sq: f64) -> Result<Self> {
        let exec = GainExecutor::for_kind(manifest, ArtifactKind::Aopt, ds.d(), 0)?;
        Ok(XlaAoptObjective {
            p: Arc::new(XlaAoptShared {
                beta_sq,
                sigma_sq_inv: 1.0 / sigma_sq,
                prior_trace: ds.d() as f64 / beta_sq,
                x: ds.x.clone(),
                exec,
                name: format!("xla-aopt[{}]", ds.name),
            }),
        })
    }
}

struct XlaAoptState {
    p: Arc<XlaAoptShared>,
    m: Matrix,
    trace: f64,
    set: Vec<usize>,
    in_set: Vec<bool>,
}

impl ObjectiveState for XlaAoptState {
    fn value(&self) -> f64 {
        ((self.p.prior_trace - self.trace) / self.p.prior_trace).max(0.0)
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        if self.in_set[a] {
            return;
        }
        self.in_set[a] = true;
        self.set.push(a);
        let s2 = self.p.sigma_sq_inv;
        let x = self.p.x.col(a);
        let d = self.m.rows();
        let mut mx = vec![0.0; d];
        crate::linalg::gemv(&self.m, x, &mut mx);
        let xmx = dot(x, &mx);
        let scale = s2 / (1.0 + s2 * xmx);
        for j in 0..d {
            let c = scale * mx[j];
            if c == 0.0 {
                continue;
            }
            let col = self.m.col_mut(j);
            for (i, cell) in col.iter_mut().enumerate() {
                *cell -= c * mx[i];
            }
        }
        self.trace -= scale * dot(&mx, &mx);
    }

    fn gain(&self, a: usize) -> f64 {
        if self.in_set[a] {
            return 0.0;
        }
        let s2 = self.p.sigma_sq_inv;
        let x = self.p.x.col(a);
        let mut mx = vec![0.0; self.m.rows()];
        crate::linalg::gemv(&self.m, x, &mut mx);
        let xmx = dot(x, &mx);
        (s2 * dot(&mx, &mx) / (1.0 + s2 * xmx) / self.p.prior_trace).max(0.0)
    }

    fn gains(&self, candidates: &[usize]) -> Vec<f64> {
        match self.p.exec.aopt_gains(&self.m, &self.p.x, candidates, self.p.sigma_sq_inv) {
            Ok(raw) => raw
                .into_iter()
                .zip(candidates)
                .map(|(g, &a)| {
                    if self.in_set[a] {
                        0.0
                    } else {
                        (g / self.p.prior_trace).max(0.0)
                    }
                })
                .collect(),
            Err(e) => {
                crate::log_warn!("xla aopt gains failed ({e}); native fallback");
                candidates.iter().map(|&a| self.gain(a)).collect()
            }
        }
    }

    fn gains_into(&self, candidates: &[usize], _scratch: &mut SweepScratch, out: &mut [f64]) {
        out.copy_from_slice(&self.gains(candidates));
    }

    fn sweep_block(&self) -> usize {
        self.p.exec.artifact().nc.max(SWEEP_BLOCK)
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(XlaAoptState {
            p: Arc::clone(&self.p),
            m: self.m.clone(),
            trace: self.trace,
            set: self.set.clone(),
            in_set: self.in_set.clone(),
        })
    }
}

impl Objective for XlaAoptObjective {
    fn n(&self) -> usize {
        self.p.x.cols()
    }

    fn name(&self) -> &str {
        &self.p.name
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        let d = self.p.x.rows();
        let mut m = Matrix::zeros(d, d);
        let inv = 1.0 / self.p.beta_sq;
        for i in 0..d {
            m.set(i, i, inv);
        }
        Box::new(XlaAoptState {
            p: Arc::clone(&self.p),
            m,
            trace: self.p.prior_trace,
            set: Vec::new(),
            in_set: vec![false; self.p.x.cols()],
        })
    }
}

// ------------------------------------------------------------ logistic --

struct XlaLogisticShared {
    inner: crate::objectives::LogisticObjective,
    exec: GainExecutor,
    d_ln2: f64,
    name: String,
}

/// Logistic objective with XLA-batched *score-test* gains (see module
/// docs); inserts and values delegate to the exact native objective.
#[derive(Clone)]
pub struct XlaLogisticObjective {
    p: Arc<XlaLogisticShared>,
}

impl XlaLogisticObjective {
    pub fn new(ds: &Dataset, manifest: &Manifest) -> Result<Self> {
        let exec = GainExecutor::for_kind(manifest, ArtifactKind::Logistic, ds.d(), 0)?;
        Ok(XlaLogisticObjective {
            p: Arc::new(XlaLogisticShared {
                inner: crate::objectives::LogisticObjective::new(ds),
                exec,
                d_ln2: ds.d() as f64 * std::f64::consts::LN_2,
                name: format!("xla-logistic[{}]", ds.name),
            }),
        })
    }
}

struct XlaLogisticState {
    p: Arc<XlaLogisticShared>,
    inner: Box<dyn ObjectiveState>,
    /// margins X_S w tracked for the score-test residuals
    z: Vec<f64>,
}

impl XlaLogisticState {
    fn recompute_margins(&mut self) {
        let w = self.inner.as_logistic_weights().unwrap_or_default();
        let set = self.inner.set();
        let x = self.p.inner.features();
        self.z = vec![0.0; x.rows()];
        if !set.is_empty() && w.len() == set.len() {
            let xs = x.select_cols(set);
            crate::linalg::gemv(&xs, &w, &mut self.z);
        }
    }
}

impl ObjectiveState for XlaLogisticState {
    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn set(&self) -> &[usize] {
        self.inner.set()
    }

    fn insert(&mut self, a: usize) {
        self.inner.insert(a);
        self.recompute_margins();
    }

    fn gain(&self, a: usize) -> f64 {
        self.inner.gain(a)
    }

    fn gains(&self, candidates: &[usize]) -> Vec<f64> {
        let y = self.p.inner.labels();
        let probs: Vec<f64> = self.z.iter().map(|&z| sigmoid(z)).collect();
        let resid: Vec<f64> = y.iter().zip(&probs).map(|(y, p)| y - p).collect();
        let w: Vec<f64> = probs.iter().map(|p| (p * (1.0 - p)).max(1e-9)).collect();
        match self.p.exec.logistic_gains(self.p.inner.features(), candidates, &resid, &w) {
            Ok(raw) => raw
                .into_iter()
                .zip(candidates)
                .map(|(g, &a)| {
                    if self.inner.set().contains(&a) {
                        0.0
                    } else {
                        (g / self.p.d_ln2).max(0.0)
                    }
                })
                .collect(),
            Err(e) => {
                crate::log_warn!("xla logistic gains failed ({e}); native fallback");
                candidates.iter().map(|&a| self.inner.gain(a)).collect()
            }
        }
    }

    fn gains_into(&self, candidates: &[usize], _scratch: &mut SweepScratch, out: &mut [f64]) {
        out.copy_from_slice(&self.gains(candidates));
    }

    fn sweep_block(&self) -> usize {
        self.p.exec.artifact().nc.max(SWEEP_BLOCK)
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(XlaLogisticState {
            p: Arc::clone(&self.p),
            inner: self.inner.clone_box(),
            z: self.z.clone(),
        })
    }

    fn as_logistic_weights(&self) -> Option<Vec<f64>> {
        self.inner.as_logistic_weights()
    }
}

impl Objective for XlaLogisticObjective {
    fn n(&self) -> usize {
        self.p.inner.n()
    }

    fn name(&self) -> &str {
        &self.p.name
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(1.0)
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        let d = self.p.inner.features().rows();
        Box::new(XlaLogisticState {
            inner: self.p.inner.empty_state(),
            z: vec![0.0; d],
            p: Arc::clone(&self.p),
        })
    }
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::rng::Pcg64;
    use crate::runtime::default_artifacts_dir;

    fn manifest() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn xla_lreg_matches_native_objective() {
        let Some(m) = manifest() else { return };
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 120, 25, 10, 0.3);
        let native = crate::objectives::LinearRegressionObjective::new(&ds);
        let xla = XlaLregObjective::new(&ds, &m, 20).unwrap();
        let set = vec![2usize, 8, 14];
        let ns = native.state_for(&set);
        let xs = xla.state_for(&set);
        assert!((ns.value() - xs.value()).abs() < 1e-10);
        let cand: Vec<usize> = (0..25).filter(|a| !set.contains(a)).collect();
        let ng = ns.gains(&cand);
        let xg = xs.gains(&cand);
        for i in 0..cand.len() {
            assert!(
                (ng[i] - xg[i]).abs() < 1e-4 * (1.0 + ng[i]),
                "cand {}: native {} xla {}",
                cand[i],
                ng[i],
                xg[i]
            );
        }
    }

    #[test]
    fn xla_aopt_matches_native_objective() {
        let Some(m) = manifest() else { return };
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::design_d1(&mut rng, 40, 60, 0.5);
        let native = crate::objectives::AOptimalityObjective::new(&ds, 1.0, 1.0);
        let xla = XlaAoptObjective::new(&ds, &m, 1.0, 1.0).unwrap();
        let set = vec![5usize, 22, 47];
        let ns = native.state_for(&set);
        let xs = xla.state_for(&set);
        assert!((ns.value() - xs.value()).abs() < 1e-10);
        let cand = vec![0usize, 10, 30, 59];
        let ng = ns.gains(&cand);
        let xg = xs.gains(&cand);
        for i in 0..cand.len() {
            assert!((ng[i] - xg[i]).abs() < 1e-5 * (1.0 + ng[i]));
        }
    }

    #[test]
    fn xla_logistic_score_gains_reasonable() {
        let Some(m) = manifest() else { return };
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::classification_d3(&mut rng, 200, 20, 6, 0.2);
        let xla = XlaLogisticObjective::new(&ds, &m).unwrap();
        let st = xla.empty_state();
        let cand: Vec<usize> = (0..20).collect();
        let gains = st.gains(&cand);
        assert_eq!(gains.len(), 20);
        assert!(gains.iter().all(|g| g.is_finite() && *g >= 0.0));
        // score-test ranking should broadly agree with exact refit ranking:
        // the top score-test candidate sits in the top quartile of exact
        let exact: Vec<f64> = cand.iter().map(|&a| st.gain(a)).collect();
        let top_score = (0..20).max_by(|&a, &b| gains[a].partial_cmp(&gains[b]).unwrap()).unwrap();
        let mut order: Vec<usize> = (0..20).collect();
        order.sort_by(|&a, &b| exact[b].partial_cmp(&exact[a]).unwrap());
        let rank = order.iter().position(|&i| i == top_score).unwrap();
        assert!(rank < 5, "score-test top candidate ranks {rank} by exact gains");
    }

    #[test]
    fn dash_runs_on_xla_backend() {
        let Some(m) = manifest() else { return };
        let mut rng = Pcg64::seed_from(4);
        let ds = synthetic::regression_d1(&mut rng, 150, 40, 15, 0.3);
        let xla = XlaLregObjective::new(&ds, &m, 20).unwrap();
        let res = crate::algorithms::Dash::new(crate::algorithms::DashConfig {
            k: 10,
            ..Default::default()
        })
        .run(&xla, &mut rng);
        assert!(res.set.len() >= 8);
        assert!(res.value > 0.0);
    }
}
