//! The batched-gain execution engine.
//!
//! DASH's adaptivity model (paper Definition 3) is "polynomially many
//! independent gain queries per round"; this module is the machinery that
//! actually executes such a round in parallel. A [`BatchExecutor`] takes a
//! candidate set and an [`ObjectiveState`], shards the gain sweep across a
//! shared [`ThreadPool`], and merges the per-shard results back in
//! candidate order, so the output is **bit-identical** to the sequential
//! blocked sweep.
//!
//! The sweep path is **zero-clone**: gain kernels are the read-only
//! [`ObjectiveState::gains_into`] contract, so every shard borrows the
//! *same* state (no `clone_box` of a d×d posterior covariance or an
//! incremental-QR basis per shard) and draws temporaries from its own
//! [`SweepScratch`] arena, handed out by the pool's scratch-carrying
//! `scoped_map_with`.
//!
//! Block-boundary determinism: sweeps are cut at multiples of the state's
//! [`ObjectiveState::sweep_block`] (default
//! [`SWEEP_BLOCK`](crate::objectives::SWEEP_BLOCK); XLA states report
//! their artifact's padded dispatch width), counted from the start of the
//! candidate slice — a function of candidate *index only*, never of shard
//! count. Shards own whole blocks, and `gains_into` implementations block
//! their input the same way, so the sharded sweep decomposes into exactly
//! the block evaluations of the sequential sweep and the merged output is
//! identical to the bit.
//!
//! On top sits a lazy [`GainCache`]: sweeps over a *fixed* state memoize
//! per-element gains, so repeated passes over surviving candidates (DASH's
//! filter iterations, the serving batcher's request stream) skip unchanged
//! work. Cache misses are the only queries actually issued, and the miss
//! count is returned so algorithm-side query accounting stays equal to the
//! oracle-side observed count ([`CountingObjective`](super::CountingObjective)
//! audits exactly this in the test suite).
//!
//! Accounting invariant: for a sweep of `n` distinct candidates the engine
//! issues per-element gain work totalling exactly `n` oracle queries
//! whether it runs sequentially (one `gains` call) or sharded (one `gains`
//! call per shard) — `QueryStats::total_gain_queries()` is identical in
//! both modes, which is what the paper's query counts measure.

use crate::objectives::{Objective, ObjectiveState, SweepScratch};
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Sweeps smaller than this run sequentially — sharding overhead beats the
/// win on tiny batches.
const DEFAULT_MIN_PARALLEL: usize = 32;

/// Telemetry counters shared by all clones of an executor.
#[derive(Debug, Default)]
pub struct ExecutorStats {
    /// total gain sweeps served
    pub sweeps: AtomicUsize,
    /// sweeps that were sharded across the pool
    pub sharded_sweeps: AtomicUsize,
    /// total per-element gain queries issued through the engine
    pub elements: AtomicUsize,
    /// whole-set f(S ∪ R) evaluations issued through the engine
    pub set_evals: AtomicUsize,
    /// prefix rounds (one per adaptive-sequencing sequence) served through
    /// [`BatchExecutor::prefix_gains`]
    pub prefix_sweeps: AtomicUsize,
}

impl ExecutorStats {
    fn bump(counter: &AtomicUsize, by: usize) {
        counter.fetch_add(by, Ordering::Relaxed);
    }
}

/// Shared batched-gain engine. Cheap to clone: clones share the pool and
/// the telemetry, so one executor can be threaded through every algorithm
/// a coordinator serves.
#[derive(Clone)]
pub struct BatchExecutor {
    pool: Option<Arc<ThreadPool>>,
    min_parallel: usize,
    stats: Arc<ExecutorStats>,
}

impl Default for BatchExecutor {
    fn default() -> Self {
        Self::sequential()
    }
}

impl BatchExecutor {
    /// Sequential engine: every sweep is one full-slice `gains_into` call
    /// with a single scratch arena. This is the default every algorithm
    /// starts with, so standalone use runs the same blocked kernels as the
    /// sharded engine — just on one thread.
    pub fn sequential() -> Self {
        BatchExecutor {
            pool: None,
            min_parallel: DEFAULT_MIN_PARALLEL,
            stats: Arc::new(ExecutorStats::default()),
        }
    }

    /// Engine with its own pool of `threads` workers (`<= 1` degrades to
    /// sequential).
    pub fn new(threads: usize) -> Self {
        if threads <= 1 {
            Self::sequential()
        } else {
            Self::with_pool(Arc::new(ThreadPool::new(threads)))
        }
    }

    /// Engine backed by an existing shared pool (the coordinator's).
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        BatchExecutor {
            pool: Some(pool),
            min_parallel: DEFAULT_MIN_PARALLEL,
            stats: Arc::new(ExecutorStats::default()),
        }
    }

    /// Override the sequential-fallback threshold (mainly for tests).
    pub fn with_min_parallel(mut self, min_parallel: usize) -> Self {
        self.min_parallel = min_parallel.max(2);
        self
    }

    /// Worker count backing this engine (1 = sequential).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.size()).unwrap_or(1)
    }

    pub fn is_parallel(&self) -> bool {
        self.threads() > 1
    }

    pub fn stats(&self) -> &ExecutorStats {
        &self.stats
    }

    /// Batched marginal gains `f_S(a)` for every candidate, in candidate
    /// order, via the blocked [`ObjectiveState::gains_into`] kernels.
    /// Sharded across the pool when profitable — shards borrow the *same*
    /// state (zero `clone_box` on this path) and own whole
    /// `SWEEP_BLOCK`-aligned candidate blocks, so the merged output is
    /// bit-identical to the sequential blocked sweep.
    pub fn gains(&self, st: &dyn ObjectiveState, candidates: &[usize]) -> Vec<f64> {
        ExecutorStats::bump(&self.stats.sweeps, 1);
        ExecutorStats::bump(&self.stats.elements, candidates.len());
        let n = candidates.len();
        let pool = match &self.pool {
            Some(p) if p.size() > 1 && n >= self.min_parallel => p,
            _ => {
                // sequential path: the same blocked kernels, one arena
                let mut out = vec![0.0; n];
                st.gains_into(candidates, &mut SweepScratch::default(), &mut out);
                return out;
            }
        };
        ExecutorStats::bump(&self.stats.sharded_sweeps, 1);
        // one task per candidate block; boundaries are multiples of the
        // state's sweep block (default SWEEP_BLOCK; XLA states report
        // their dispatch shape) from the sweep start, independent of pool
        // size
        let block = st.sweep_block().max(1);
        let nblocks = n.div_ceil(block);
        let parts: Vec<Vec<f64>> =
            pool.scoped_map_with(nblocks, SweepScratch::default, |b, scratch| {
                let lo = b * block;
                let hi = ((b + 1) * block).min(n);
                let mut out = vec![0.0; hi - lo];
                st.gains_into(&candidates[lo..hi], scratch, &mut out);
                out
            });
        let mut out = Vec::with_capacity(n);
        for p in parts {
            out.extend(p);
        }
        debug_assert_eq!(out.len(), n);
        out
    }

    /// Memoized sweep: serve each candidate from `cache` when its gain for
    /// the cache's current state generation is known, and issue one (possibly
    /// sharded) sweep for the misses. Returns `(gains, fresh_queries)` where
    /// `fresh_queries` is the number of oracle queries actually issued —
    /// callers must report exactly this to their round tracker so
    /// self-reported counts match the oracle-observed counts.
    ///
    /// Candidates are assumed distinct (all algorithm sweeps are).
    pub fn cached_gains(
        &self,
        cache: &mut GainCache,
        st: &dyn ObjectiveState,
        candidates: &[usize],
    ) -> (Vec<f64>, usize) {
        let misses: Vec<usize> =
            candidates.iter().copied().filter(|&a| !cache.is_known(a)).collect();
        if !misses.is_empty() {
            let vals = self.gains(st, &misses);
            for (&a, &v) in misses.iter().zip(&vals) {
                cache.put(a, v);
            }
        }
        cache.hits += candidates.len() - misses.len();
        cache.misses += misses.len();
        let out = candidates.iter().map(|&a| cache.get(a)).collect();
        (out, misses.len())
    }

    /// One gain query per (prefix state, element) pair, fanned out over the
    /// pool: `out[i] = states[i].gain(items[i])`.
    ///
    /// This is adaptive sequencing's prefix round (paper §1.2): given a
    /// sampled sequence, the marginal of `seq[i]` on top of the prefix
    /// `S ∪ seq[..i]` is independent of every other prefix marginal once
    /// the prefix states are materialized, so the whole walk collapses to
    /// **one** adaptive round on the pool instead of a serial per-prefix
    /// oracle walk. Each query is a scalar [`ObjectiveState::gain`] on its
    /// own borrowed state, merged in index order — the output is identical
    /// to evaluating the pairs one by one, for any pool size.
    pub fn prefix_gains(
        &self,
        states: &[Box<dyn ObjectiveState>],
        items: &[usize],
    ) -> Vec<f64> {
        assert_eq!(states.len(), items.len(), "one prefix state per item");
        ExecutorStats::bump(&self.stats.prefix_sweeps, 1);
        ExecutorStats::bump(&self.stats.elements, items.len());
        match &self.pool {
            Some(pool) if pool.size() > 1 && items.len() > 1 => {
                pool.scoped_map(items.len(), |i| states[i].gain(items[i]))
            }
            _ => states.iter().zip(items).map(|(st, &a)| st.gain(a)).collect(),
        }
    }

    /// Whole-set gains `f_S(R)` for a batch of candidate blocks (DASH's
    /// per-round sample estimates), fanned out over the pool, each paired
    /// with the constructed `S ∪ R` state so callers can adopt or sweep
    /// them without rebuilding. Routed through
    /// [`Objective::set_gain_state`] so oracle-call auditors observe
    /// exactly one set query per block.
    pub fn sample_blocks(
        &self,
        obj: &dyn Objective,
        st: &dyn ObjectiveState,
        blocks: &[Vec<usize>],
    ) -> Vec<(f64, Box<dyn ObjectiveState>)> {
        ExecutorStats::bump(&self.stats.set_evals, blocks.len());
        match &self.pool {
            Some(pool) if pool.size() > 1 && blocks.len() > 1 => {
                pool.scoped_map(blocks.len(), |i| obj.set_gain_state(st, &blocks[i]))
            }
            _ => blocks.iter().map(|b| obj.set_gain_state(st, b)).collect(),
        }
    }

}

/// Generation-keyed per-element gain memo. Every entry is stamped with the
/// generation it was computed at; [`GainCache::invalidate`] bumps the
/// current generation in O(1), which logically forgets every entry — no
/// clearing pass, no queue rebuild — so a long-lived selection session can
/// invalidate on every `insert` for free. Between invalidations, repeated
/// sweeps over surviving candidates are served without re-querying the
/// oracle, and a stale-generation entry can never be served: `is_known`
/// and `get` only accept entries stamped with the *current* generation.
///
/// The cache grows on demand: a [`BatchQueue`](crate::coordinator::BatchQueue)
/// or algorithm reused across datasets may submit indices beyond the ground
/// set it was sized for, and [`GainCache::put`] resizes instead of
/// panicking with an opaque slice-index error (out-of-range reads report
/// unknown / 0.0, matching the documented `get` contract).
#[derive(Debug, Clone)]
pub struct GainCache {
    vals: Vec<f64>,
    /// generation each entry was computed at (0 = never)
    stamp: Vec<u64>,
    /// current generation; starts at 1 so a zero stamp is always stale
    gen: u64,
    /// served-from-memo element count (telemetry)
    pub hits: usize,
    /// freshly evaluated element count (telemetry)
    pub misses: usize,
}

impl GainCache {
    /// Cache over ground set `0..n`.
    pub fn new(n: usize) -> Self {
        GainCache { vals: vec![0.0; n], stamp: vec![0; n], gen: 1, hits: 0, misses: 0 }
    }

    /// Bump the generation, logically forgetting every memoized gain (the
    /// state changed). O(1): entries stay in place but their stamps no
    /// longer match.
    pub fn invalidate(&mut self) {
        self.gen += 1;
    }

    /// The cache's current generation (bumped by every invalidation).
    pub fn generation(&self) -> u64 {
        self.gen
    }

    pub fn is_known(&self, a: usize) -> bool {
        self.stamp.get(a).copied() == Some(self.gen)
    }

    /// Memoized value (0.0 when unknown or stamped with a stale
    /// generation; check [`GainCache::is_known`]).
    pub fn get(&self, a: usize) -> f64 {
        if self.is_known(a) {
            self.vals[a]
        } else {
            0.0
        }
    }

    pub fn put(&mut self, a: usize, v: f64) {
        if a >= self.vals.len() {
            // grow: `is_known` already reported out-of-range indices as
            // unknown, so a silent panic here would only surface deep in a
            // flush; resizing keeps the unknown-⇒-miss contract coherent
            self.vals.resize(a + 1, 0.0);
            self.stamp.resize(a + 1, 0);
        }
        self.vals[a] = v;
        self.stamp[a] = self.gen;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;
    use crate::rng::Pcg64;

    fn setup() -> (LinearRegressionObjective, Vec<usize>) {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 80, 60, 12, 0.3);
        (LinearRegressionObjective::new(&ds), (0..60).collect())
    }

    #[test]
    fn sharded_matches_sequential_exactly() {
        let (obj, cand) = setup();
        let st = obj.state_for(&[3, 17, 42]);
        let seq = BatchExecutor::sequential();
        let par = BatchExecutor::new(4).with_min_parallel(2);
        assert!(par.is_parallel());
        let a = seq.gains(&*st, &cand);
        let b = par.gains(&*st, &cand);
        assert_eq!(a, b, "sharded sweep must be bit-identical");
        assert_eq!(par.stats().sharded_sweeps.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn small_sweeps_stay_sequential() {
        let (obj, _) = setup();
        let st = obj.empty_state();
        let par = BatchExecutor::new(4); // default min_parallel = 32
        let out = par.gains(&*st, &[1, 2, 3]);
        assert_eq!(out.len(), 3);
        assert_eq!(par.stats().sharded_sweeps.load(Ordering::Relaxed), 0);
        assert_eq!(par.stats().sweeps.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn cache_serves_repeat_sweeps_without_queries() {
        let (obj, cand) = setup();
        let st = obj.empty_state();
        let exec = BatchExecutor::sequential();
        let mut cache = GainCache::new(obj.n());
        let (first, fresh1) = exec.cached_gains(&mut cache, &*st, &cand);
        assert_eq!(fresh1, cand.len());
        let (second, fresh2) = exec.cached_gains(&mut cache, &*st, &cand);
        assert_eq!(fresh2, 0, "repeat sweep must be free");
        assert_eq!(first, second);
        assert_eq!(cache.hits, cand.len());
        // partial overlap: only the new element is queried
        let mut subset = vec![0usize, 5, 59];
        let (_, fresh3) = exec.cached_gains(&mut cache, &*st, &subset);
        assert_eq!(fresh3, 0);
        cache.invalidate();
        subset.truncate(2);
        let (_, fresh4) = exec.cached_gains(&mut cache, &*st, &subset);
        assert_eq!(fresh4, 2, "invalidation forgets everything");
    }

    #[test]
    fn cached_values_match_direct() {
        let (obj, cand) = setup();
        let st = obj.state_for(&[7]);
        let exec = BatchExecutor::new(3).with_min_parallel(2);
        let mut cache = GainCache::new(obj.n());
        let (cached, _) = exec.cached_gains(&mut cache, &*st, &cand);
        assert_eq!(cached, st.gains(&cand));
    }

    #[test]
    fn cache_grows_past_initial_ground_set() {
        // regression: a cache sized for one dataset, reused on a larger
        // one, must serve out-of-range indices instead of panicking
        let mut cache = GainCache::new(4);
        assert!(!cache.is_known(10));
        assert_eq!(cache.get(10), 0.0);
        cache.put(10, 2.5);
        assert!(cache.is_known(10));
        assert_eq!(cache.get(10), 2.5);
        // in-range entries unaffected; invalidate covers the grown range
        cache.put(1, 1.0);
        cache.invalidate();
        assert!(!cache.is_known(10) && !cache.is_known(1));

        // end-to-end: cached_gains over candidates beyond the cache's size
        let (obj, _) = setup();
        let st = obj.empty_state();
        let exec = BatchExecutor::sequential();
        let mut small = GainCache::new(3);
        let cand = vec![0usize, 30, 59];
        let (vals, fresh) = exec.cached_gains(&mut small, &*st, &cand);
        assert_eq!(fresh, 3);
        assert_eq!(vals, st.gains(&cand));
        let (_, fresh2) = exec.cached_gains(&mut small, &*st, &cand);
        assert_eq!(fresh2, 0, "grown entries must memoize");
    }

    #[test]
    fn invalidate_is_generation_bump() {
        let mut cache = GainCache::new(8);
        let g0 = cache.generation();
        cache.put(3, 1.5);
        assert!(cache.is_known(3));
        assert_eq!(cache.get(3), 1.5);
        cache.invalidate();
        assert_eq!(cache.generation(), g0 + 1);
        // stale-generation entries are unreachable: neither known nor served
        assert!(!cache.is_known(3));
        assert_eq!(cache.get(3), 0.0, "stale entry must not be served");
        // re-putting at the new generation serves again
        cache.put(3, 2.5);
        assert!(cache.is_known(3));
        assert_eq!(cache.get(3), 2.5);
    }

    #[test]
    fn prefix_gains_match_serial_pairs() {
        let (obj, _) = setup();
        let base = obj.state_for(&[2, 9]);
        let seq: Vec<usize> = vec![5, 11, 30, 41, 57];
        // materialize prefix states: P_i = S ∪ seq[..i]
        let mut prefixes: Vec<Box<dyn crate::objectives::ObjectiveState>> =
            Vec::with_capacity(seq.len());
        prefixes.push(base.clone_box());
        for i in 1..seq.len() {
            let mut next = prefixes[i - 1].clone_box();
            next.insert(seq[i - 1]);
            prefixes.push(next);
        }
        let expected: Vec<f64> =
            prefixes.iter().zip(&seq).map(|(st, &a)| st.gain(a)).collect();
        for exec in [BatchExecutor::sequential(), BatchExecutor::new(3)] {
            let got = exec.prefix_gains(&prefixes, &seq);
            assert_eq!(got, expected, "prefix round must be bit-identical");
        }
    }

    #[test]
    fn sample_blocks_match_manual_evaluation() {
        let (obj, _) = setup();
        let st = obj.state_for(&[1, 2]);
        let blocks = vec![vec![10, 11], vec![20], vec![30, 31, 32]];
        for exec in [BatchExecutor::sequential(), BatchExecutor::new(3)] {
            let got = exec.sample_blocks(&obj, &*st, &blocks);
            for (b, (g, s_new)) in blocks.iter().zip(&got) {
                let mut s2 = st.clone_box();
                let before = s2.value();
                for &a in b {
                    s2.insert(a);
                }
                assert!((g - (s2.value() - before)).abs() < 1e-12);
                // the returned state is the constructed S ∪ R
                assert_eq!(s_new.set(), s2.set());
                assert_eq!(s_new.value(), s2.value());
            }
        }
    }

    #[test]
    fn sample_blocks_leave_base_state_untouched() {
        let (obj, _) = setup();
        let st = obj.state_for(&[4]);
        let blocks = vec![vec![9, 10], vec![25]];
        for exec in [BatchExecutor::sequential(), BatchExecutor::new(2)] {
            let samples = exec.sample_blocks(&obj, &*st, &blocks);
            assert_eq!(samples.len(), 2);
            assert_eq!(samples[0].1.set(), &[4, 9, 10]);
            assert_eq!(samples[1].1.set(), &[4, 25]);
            // original untouched
            assert_eq!(st.set(), &[4]);
        }
    }

    #[test]
    fn clones_share_stats_and_pool() {
        let exec = BatchExecutor::new(2).with_min_parallel(2);
        let clone = exec.clone();
        let (obj, cand) = setup();
        let st = obj.empty_state();
        let _ = clone.gains(&*st, &cand);
        assert_eq!(exec.stats().sweeps.load(Ordering::Relaxed), 1);
        assert_eq!(exec.threads(), clone.threads());
    }
}
