//! Oracle layer: how algorithms obtain objective values.
//!
//! Algorithms consume [`Objective`](crate::objectives::Objective) directly;
//! this module supplies the execution engine, the production backends, and
//! accounting:
//!
//! - [`batch`] — the [`BatchExecutor`]: shards batched gain sweeps across a
//!   shared thread pool and layers a memoized [`GainCache`] on top. Every
//!   algorithm's inner loop issues its gain queries through this engine.
//! - [`xla`] — objectives whose batched gain sweeps execute on the PJRT
//!   runtime (the AOT-compiled Pallas kernels); state updates stay native.
//! - [`CountingObjective`] — transparent wrapper that counts every oracle
//!   interaction (used by tests to audit the algorithms' self-reported
//!   query counts: for greedy, DASH and TOP-k the observed
//!   [`QueryStats::total_oracle_queries`] must equal the algorithm's
//!   reported `SelectionResult::queries`, sequential or parallel).

pub mod batch;
pub mod xla;

pub use batch::{BatchExecutor, ExecutorStats, GainCache};
pub use xla::{XlaAoptObjective, XlaLogisticObjective, XlaLregObjective};

use crate::objectives::{Objective, ObjectiveState, SweepScratch};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Totals observed by a [`CountingObjective`].
#[derive(Debug, Default)]
pub struct QueryStats {
    pub evals: AtomicUsize,
    pub single_gains: AtomicUsize,
    pub batched_gains: AtomicUsize,
    pub batched_elements: AtomicUsize,
    pub inserts: AtomicUsize,
    /// whole-set oracle evaluations: `Objective::eval` + `Objective::set_gain`
    pub set_evals: AtomicUsize,
}

impl QueryStats {
    /// All per-element gain evaluations (singles + batched elements).
    pub fn total_gain_queries(&self) -> usize {
        self.single_gains.load(Ordering::Relaxed)
            + self.batched_elements.load(Ordering::Relaxed)
    }

    /// Every oracle query in the paper's accounting: per-element gains plus
    /// whole-set evaluations. Algorithms' self-reported
    /// `SelectionResult::queries` must equal exactly this.
    pub fn total_oracle_queries(&self) -> usize {
        self.total_gain_queries() + self.set_evals.load(Ordering::Relaxed)
    }
}

/// Wraps an objective and counts every oracle interaction.
pub struct CountingObjective<O: Objective> {
    inner: O,
    pub stats: Arc<QueryStats>,
}

impl<O: Objective> CountingObjective<O> {
    pub fn new(inner: O) -> Self {
        CountingObjective { inner, stats: Arc::new(QueryStats::default()) }
    }
}

struct CountingState {
    inner: Box<dyn ObjectiveState>,
    stats: Arc<QueryStats>,
}

impl ObjectiveState for CountingState {
    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn set(&self) -> &[usize] {
        self.inner.set()
    }

    fn insert(&mut self, a: usize) {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        self.inner.insert(a);
    }

    fn gain(&self, a: usize) -> f64 {
        self.stats.single_gains.fetch_add(1, Ordering::Relaxed);
        self.inner.gain(a)
    }

    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        // the engine's sweep path: one call per candidate block when
        // sharded, one per sweep otherwise — `batched_elements` totals the
        // same `n` either way, which is what the audits compare
        self.stats.batched_gains.fetch_add(1, Ordering::Relaxed);
        self.stats.batched_elements.fetch_add(candidates.len(), Ordering::Relaxed);
        self.inner.gains_into(candidates, scratch, out);
    }

    fn sweep_block(&self) -> usize {
        // transparent: the counted state must shard exactly like the inner
        // one, or counting would change the block decomposition
        self.inner.sweep_block()
    }

    fn gains(&self, candidates: &[usize]) -> Vec<f64> {
        // direct (non-engine) batched calls: count here, once, and hand the
        // sweep to the inner state's own blocked path uncounted
        self.stats.batched_gains.fetch_add(1, Ordering::Relaxed);
        self.stats.batched_elements.fetch_add(candidates.len(), Ordering::Relaxed);
        self.inner.gains(candidates)
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(CountingState {
            inner: self.inner.clone_box(),
            stats: Arc::clone(&self.stats),
        })
    }

    fn as_logistic_weights(&self) -> Option<Vec<f64>> {
        self.inner.as_logistic_weights()
    }
}

impl<O: Objective> Objective for CountingObjective<O> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn upper_bound(&self) -> Option<f64> {
        self.inner.upper_bound()
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        self.stats.evals.fetch_add(1, Ordering::Relaxed);
        Box::new(CountingState {
            inner: self.inner.empty_state(),
            stats: Arc::clone(&self.stats),
        })
    }

    fn eval(&self, set: &[usize]) -> f64 {
        self.stats.set_evals.fetch_add(1, Ordering::Relaxed);
        self.inner.eval(set)
    }

    // `set_gain` inherits the trait default, which delegates here — so both
    // entry points count exactly one whole-set query.
    fn set_gain_state(
        &self,
        state: &dyn ObjectiveState,
        add: &[usize],
    ) -> (f64, Box<dyn ObjectiveState>) {
        self.stats.set_evals.fetch_add(1, Ordering::Relaxed);
        // replicate the default implementation rather than delegating: the
        // incoming `state` is a CountingState, and forking it keeps the
        // insert accounting attached (no inner objective overrides this,
        // so semantics are identical)
        let mut st = state.clone_box();
        let before = st.value();
        for &a in add {
            st.insert(a);
        }
        let gain = st.value() - before;
        (gain, st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Greedy, GreedyConfig};
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;
    use crate::rng::Pcg64;

    #[test]
    fn counts_greedy_queries() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 60, 12, 5, 0.2);
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let res = Greedy::new(GreedyConfig { k: 3, ..Default::default() }).run(&counting);
        // greedy's self-reported queries must equal observed gain queries
        assert_eq!(res.queries, counting.stats.total_gain_queries());
        assert_eq!(res.queries, counting.stats.total_oracle_queries());
        assert_eq!(counting.stats.inserts.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn passthrough_semantics() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::regression_d1(&mut rng, 40, 8, 4, 0.2);
        let base = LinearRegressionObjective::new(&ds);
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        for set in [vec![], vec![1], vec![0, 5, 7]] {
            assert_eq!(base.eval(&set), counting.eval(&set));
        }
        assert_eq!(base.n(), counting.n());
        assert_eq!(base.upper_bound(), counting.upper_bound());
        assert_eq!(counting.stats.set_evals.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn set_gain_counted_and_exact() {
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 50, 10, 4, 0.2);
        let base = LinearRegressionObjective::new(&ds);
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let st_base = base.state_for(&[1]);
        let st_count = counting.state_for(&[1]);
        let add = vec![3usize, 7];
        let g_base = base.set_gain(&*st_base, &add);
        let g_count = counting.set_gain(&*st_count, &add);
        assert!((g_base - g_count).abs() < 1e-14);
        assert_eq!(counting.stats.set_evals.load(Ordering::Relaxed), 1);
    }
}
