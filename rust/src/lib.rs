//! # dash-select
//!
//! Reproduction of *"Fast Parallel Algorithms for Statistical Subset
//! Selection Problems"* (Qian & Singer, NeurIPS 2019): the **DASH**
//! adaptive-sampling algorithm for maximizing *differentially submodular*
//! objectives (feature selection for regression/classification, Bayesian
//! A-optimal experimental design) in `O(log n)` adaptive rounds, plus every
//! baseline the paper evaluates against and the full benchmark harness that
//! regenerates the paper's figures.
//!
//! ## Architecture
//!
//! Three layers, Python never on the request path:
//!
//! - **L3 (this crate)**: the coordinator — DASH round loop, baselines,
//!   oracle batching, datasets, experiments, CLI.
//! - **L2/L1 (python/compile)**: JAX oracle graphs wrapping Pallas gain
//!   kernels, AOT-lowered to HLO text under `artifacts/`.
//! - **runtime**: loads the HLO artifacts via the PJRT CPU client
//!   ([`runtime`]) and serves batched gain queries ([`oracle`]).
//!
//! ## Quickstart (public API v1)
//!
//! Jobs are built through the validating spec builders and run through the
//! [`coordinator::Leader`]; every public entry point returns the unified
//! [`coordinator::SelectError`]:
//!
//! ```no_run
//! use dash_select::prelude::*;
//! use std::sync::Arc;
//!
//! # fn main() -> Result<(), SelectError> {
//! let mut rng = Pcg64::seed_from(7);
//! let data = Arc::new(synthetic::regression_d1(&mut rng, 1000, 500, 100, 0.4));
//! let problem = ProblemSpec::builder(data).k(25).seed(7).build()?;
//! let plan = PlanSpec::dash().epsilon(0.1).alpha(0.75).build()?;
//! let report = Leader::new().run(&problem.job(&plan))?;
//! println!("f(S) = {:.4} in {} rounds", report.result.value, report.result.rounds);
//! # Ok(())
//! # }
//! ```
//!
//! The same API is drivable from outside the process: `dash serve --stdio`
//! speaks the versioned JSON wire protocol of
//! [`coordinator::wire`] — one request frame per line, one reply frame per
//! request, against the same deterministic serving core the in-process
//! [`coordinator::SessionClient`] uses.
//!
//! The crate also audits itself: [`analysis`] implements the `dash audit`
//! invariant checker (no panic paths in library code, audited `unsafe`,
//! wrapper-only locking via [`util::sync`], sorted-key wire frames), run
//! as a hard gate in CI and by `tests/audit.rs`.

pub mod analysis;
pub mod util;
pub mod cli;
pub mod rng;
pub mod linalg;
pub mod data;
pub mod objectives;
pub mod algorithms;
pub mod oracle;
pub mod runtime;
pub mod coordinator;
pub mod experiments;
pub mod bench;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::algorithms::{
        AdaptiveSequencing, AdaptiveSequencingConfig, Dash, DashConfig, Greedy, GreedyConfig,
        Lasso, LassoConfig, ParallelGreedy, RandomSelect, SelectionResult, TopK,
    };
    pub use crate::coordinator::{
        AlgorithmChoice, Backend, Generation, Leader, ObjectiveChoice, PlanKind, PlanSpec,
        ProblemSpec, SelectError, SelectionJob, SelectionReport, SelectionSession, ServeSpec,
        SessionClient, SessionDriver, StepOutcome,
    };
    pub use crate::data::{synthetic, Dataset, Task};
    pub use crate::linalg::Matrix;
    pub use crate::objectives::{
        AOptimalityObjective, LinearRegressionObjective, LogisticObjective, Objective,
        ObjectiveState, R2Objective,
    };
    pub use crate::rng::Pcg64;
}
