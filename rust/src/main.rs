//! `dash` — the leader CLI.
//!
//! ```text
//! dash run        --algo dash --dataset d1 --k 25 [--backend xla] [--seed N]
//! dash experiment fig1|fig2|fig3|fig4|appendix-a|topk-bound [--scale quick|paper]
//! dash artifacts                     # show the AOT artifact inventory
//! dash spectra    --dataset d1 --k 25   # γ / α estimates for a workload
//! dash audit      [--root DIR]       # run the in-tree invariant auditor
//! ```

use dash_select::analysis;
use dash_select::cli::Args;
use dash_select::coordinator::{
    install_drain_signals, Backend, Leader, NetConfig, NetServer, ObjectiveChoice, PlanSpec,
    ProblemSpec, Router, RouterConfig, SelectError, ServeConfig, ServeSpec, SessionStore,
    StdioServer, WireCore,
};
use dash_select::experiments::{self, fig1, figs, appendix, DatasetId, Scale};
use dash_select::objectives::spectra;
use dash_select::rng::Pcg64;
use dash_select::runtime::{default_artifacts_dir, Manifest};
use dash_select::util::logging::{set_level, Level};
use std::sync::Arc;

const USAGE: &str = r#"dash — Fast Parallel Algorithms for Statistical Subset Selection (DASH)

USAGE:
  dash run --algo <A> --dataset <D> --k <K> [options]
      A: dash | greedy | lazy-greedy | parallel-greedy | topk | random |
         lasso | adaptive-sampling | adaptive-seq
      D: d1 | d1-design | d2 | d2-design | d3 | d4
      options: --backend native|xla  --seed N  --scale quick|paper
               --alpha F --epsilon F --r N --samples N  --json

  dash experiment <E> [--scale quick|paper] [--panel rounds|accuracy|time|all]
      E: fig1 | fig2 | fig3 | fig4 | appendix-a | topk-bound

  dash serve [--sessions N] [--clients C] [--sweeps R] [--dataset <D>] [--k K]
      smoke-run the concurrent serving front: N driven sessions plus one
      ad-hoc session, C sweep clients; prints request throughput and
      sweep-coalescing stats

  dash serve --stdio [--max-sessions N] [--store DIR] [--tenant-quota Q]
      speak the v1 JSON wire protocol over stdin/stdout: one request frame
      per line ({"v":1,"id":N,"op":"open"|"list"|"sweep"|"insert"|"step"|
      "finish"|"metrics"|"close",...}), one reply frame per request, until
      EOF. --store DIR makes sessions durable: opens past the resident
      budget snapshot the least-recently-used idle session to DIR and it
      is restored transparently on its next request. --tenant-quota caps
      open sessions per tenant (the open frame's optional "tenant" field)

  dash serve --listen ADDR [--max-sessions N] [--store DIR] [--tenant-quota Q]
             [--request-deadline-ms MS] [--idle-timeout-ms MS] [--fault-ops]
      the same v1 protocol over a socket: ADDR is host:port (TCP; port 0
      picks a free port, printed on stderr) or unix:/path. One supervised
      handler per connection; slow or idle connections are dropped without
      touching their sessions. SIGINT/SIGTERM or a "shutdown" frame drains
      gracefully: in-flight turns finish, evictable sessions persist to
      --store, exit 0 — a restarted server on the same store resumes the
      same session ids. --fault-ops serves the test-only "crash" op

  dash route --listen ADDR --worker ADDR [--worker ADDR ...]
             [--request-deadline-ms MS] [--idle-timeout-ms MS]
             [--probe-interval-ms MS]
      route the v1 protocol across several `dash serve --listen` workers:
      sessions are placed by rendezvous hashing on the session id, opens
      are pinned to router-allocated ids, and a worker that dies is routed
      around — give every worker the same --store DIR and its sessions
      fail over to the survivors byte-identically. A "shutdown" frame
      drains the workers and then the router; SIGINT/SIGTERM drains the
      router alone, leaving the workers serving

  dash artifacts          show the AOT artifact inventory
  dash spectra --dataset <D> --k <K>   sampled γ / α = γ² estimates

  dash audit [--root DIR]
      run the in-tree invariant auditor over rust/src, rust/tests,
      rust/benches, and examples: no-panic (library code), unsafe-code
      (file allowlist + per-block SAFETY comments), raw-lock (util::sync
      wrappers only), lock-unwrap, wire-sorted-keys. Exemptions come from
      audit.allow at the repo root (shrink-only: stale entries fail).
      Exit 0 only on a clean tree — a required CI gate

  global: --log error|warn|info|debug
"#;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Some(lvl) = args.get("log").and_then(Level::parse) {
        set_level(lvl);
    } else {
        set_level(Level::Info);
    }
    let code = match args.subcommand() {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("route") => cmd_route(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("spectra") => cmd_spectra(&args),
        Some("audit") => cmd_audit(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            Err(SelectError::InvalidSpec(format!("unknown subcommand '{other}'\n{USAGE}")))
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dataset_for(args: &Args) -> Result<(DatasetId, Scale), SelectError> {
    let id = DatasetId::parse(args.get_or("dataset", "d1")).ok_or_else(|| {
        SelectError::InvalidSpec(format!("unknown dataset '{}'", args.get_or("dataset", "d1")))
    })?;
    let scale = Scale::parse(args.get_or("scale", "quick")).ok_or_else(|| {
        SelectError::InvalidSpec(format!("unknown scale '{}'", args.get_or("scale", "quick")))
    })?;
    Ok((id, scale))
}

fn objective_for(id: DatasetId) -> ObjectiveChoice {
    match id {
        DatasetId::D1 | DatasetId::D2 => ObjectiveChoice::Lreg,
        DatasetId::D3 | DatasetId::D4 => ObjectiveChoice::Logistic,
        DatasetId::D1Design | DatasetId::D2Design => {
            ObjectiveChoice::Aopt { beta_sq: 1.0, sigma_sq: 1.0 }
        }
    }
}

fn cmd_run(args: &Args) -> Result<(), SelectError> {
    let (id, scale) = dataset_for(args)?;
    let seed = args.get_u64("seed", 1)?;
    let k = args.get_usize("k", 25)?;
    let backend = Backend::parse(args.get_or("backend", "native")).ok_or_else(|| {
        SelectError::InvalidSpec(format!("unknown backend '{}'", args.get_or("backend", "native")))
    })?;
    // one construction path: parse the plan kind, apply the tuning knobs
    // (knobs that do not apply to the chosen algorithm are ignored), and
    // let the builders validate everything before the leader sees the job
    let plan = PlanSpec::parse(args.get_or("algo", "dash"))?
        .epsilon(args.get_f64("epsilon", 0.1)?)
        .alpha(args.get_f64("alpha", 0.75)?)
        .r(args.get_usize("r", 0)?)
        .samples(args.get_usize("samples", 5)?)
        .threads(args.get_usize("threads", 4)?)
        .trials(args.get_usize("trials", 5)?)
        .build()?;

    let ds = Arc::new(id.build(scale, seed));
    eprintln!("dataset {} ({} samples × {} selectable)", ds.name, ds.d(), ds.n());
    let problem = ProblemSpec::builder(Arc::clone(&ds))
        .objective(objective_for(id))
        .backend(backend)
        .k(k)
        .seed(seed)
        .build()?;
    let leader = Leader::new();
    let report = leader.run(&problem.job(&plan))?;
    if args.get_flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!(
            "{}: f(S) = {:.5}  |S| = {}  rounds = {}  queries = {}  wall = {:.3}s  modeled-parallel(64) = {:.4}s",
            report.algorithm,
            report.result.value,
            report.result.set.len(),
            report.result.rounds,
            report.result.queries,
            report.result.wall_s,
            report.result.modeled_parallel_s(Some(64)),
        );
        println!("set: {:?}", report.result.set);
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<(), SelectError> {
    let which = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
        SelectError::InvalidSpec(
            "experiment name required (fig1|fig2|fig3|fig4|appendix-a|topk-bound)".into(),
        )
    })?;
    let scale = Scale::parse(args.get_or("scale", "quick")).ok_or_else(|| {
        SelectError::InvalidSpec(format!("unknown scale '{}'", args.get_or("scale", "quick")))
    })?;
    let seed = args.get_u64("seed", 1)?;
    match which {
        "fig1" => {
            let out = fig1::run_fig1(&fig1::Fig1Config { seed, ..Default::default() });
            println!(
                "fig1: {} scatter points; sampled γ = {:.4}, α = γ² = {:.4}; \
                 Σ-singles/set-gain ratio observed in [{:.3}, {:.3}]",
                out.scatter.rows.len(),
                out.gamma,
                out.alpha,
                out.ratio_lo,
                out.ratio_hi
            );
        }
        "fig2" | "fig3" | "fig4" => {
            let figure = figs::FigureId::parse(which).ok_or_else(|| {
                SelectError::InvalidSpec(format!("unknown figure '{which}'"))
            })?;
            let panel = figs::Panel::parse(args.get_or("panel", "all")).ok_or_else(|| {
                SelectError::InvalidSpec(format!("unknown panel '{}'", args.get_or("panel", "all")))
            })?;
            let backend =
                Backend::parse(args.get_or("backend", "native")).ok_or_else(|| {
                    SelectError::InvalidSpec(format!(
                        "unknown backend '{}'",
                        args.get_or("backend", "native")
                    ))
                })?;
            let cfg = figs::FigureConfig {
                figure,
                scale,
                panel,
                seed,
                backend,
                algo_budget_s: args.get_f64("budget", 120.0)?,
                save: true,
            };
            let outputs = figs::run_figure(&cfg);
            for (label, table) in &outputs.tables {
                println!("\n=== {label} ===");
                println!("{}", table.to_pretty());
                if label.ends_with("_time") {
                    if let Some(s) = figs::speedup_summary(table) {
                        println!("adaptivity speedup (greedy rounds / dash rounds @ max k): {s:.2}×");
                    }
                }
            }
        }
        "appendix-a" => {
            let r = appendix::run_appendix_a2(args.get_usize("k", 2)?, seed);
            println!(
                "appendix A.2 (k={}, OPT={}): plain adaptive sampling failed={} (value {:.2}); \
                 DASH failed={} (value {:.2}, rounds {})",
                args.get_usize("k", 2)?,
                r.opt,
                r.plain_failed,
                r.plain_value,
                r.dash_failed,
                r.dash_value,
                r.dash_rounds
            );
        }
        "topk-bound" => {
            let (table, violations) = appendix::run_topk_bound(args.get_usize("trials", 10)?, seed);
            println!("{}", table.to_pretty());
            println!("bound violations: {violations}");
        }
        other => return Err(SelectError::InvalidSpec(format!("unknown experiment '{other}'"))),
    }
    let _ = experiments::results_dir();
    Ok(())
}

/// Smoke-run the serving front: driven sessions racing ad-hoc sweep
/// traffic over one bounded queue, with throughput + coalescing stats.
fn cmd_serve(args: &Args) -> Result<(), SelectError> {
    if args.get_flag("stdio") {
        return cmd_serve_stdio(args);
    }
    if args.get("listen").is_some() {
        return cmd_serve_listen(args);
    }
    let (id, scale) = dataset_for(args)?;
    let seed = args.get_u64("seed", 1)?;
    let k = args.get_usize("k", 10)?;
    let sessions = args.get_usize("sessions", 2)?.max(1);
    let readers = args.get_usize("clients", 2)?.max(1);
    let sweeps = args.get_usize("sweeps", 32)?.max(1);
    let ds = Arc::new(id.build(scale, seed));
    let n = ds.n();
    let objective = objective_for(id);
    let leader = Leader::new();
    // driven lanes alternate greedy / dash; one ad-hoc lane takes the raw
    // sweep + insert traffic — all assembled through the v1 builders
    let problem = |seed_offset: u64| {
        ProblemSpec::builder(Arc::clone(&ds))
            .objective(objective.clone())
            .k(k)
            .seed(seed + seed_offset)
            .build()
    };
    let greedy = PlanSpec::greedy().build()?;
    let dash = PlanSpec::dash().build()?;
    let topk = PlanSpec::topk().build()?;
    let mut specs: Vec<ServeSpec> = Vec::with_capacity(sessions + 1);
    for i in 0..sessions {
        let plan = if i % 2 == 0 { &greedy } else { &dash };
        specs.push(ServeSpec::driven(problem(i as u64)?.job(plan)));
    }
    specs.push(ServeSpec::adhoc(problem(0)?.job(&topk)));
    eprintln!(
        "serving {sessions} driven + 1 ad-hoc session over {} ({n} candidates); \
         {readers} sweep clients × {sweeps} sweeps",
        ds.name
    );
    let t0 = std::time::Instant::now();
    // the closure returns Result so client failures surface as typed
    // errors through the serve summary instead of panicking the smoke run
    let (outcome, summary) = leader.serve(&specs, ServeConfig::default(), move |clients| {
        let adhoc = clients[sessions].clone();
        std::thread::scope(|s| -> Result<Vec<_>, SelectError> {
            let drivers: Vec<_> = clients[..sessions]
                .iter()
                .map(|c| {
                    let c = c.clone();
                    s.spawn(move || c.drive())
                })
                .collect();
            let mut sweepers = Vec::with_capacity(readers);
            for t in 0..readers {
                let c = adhoc.clone();
                sweepers.push(s.spawn(move || -> Result<(), SelectError> {
                    let cand: Vec<usize> = (0..n).collect();
                    for i in 0..sweeps {
                        let sw = c.sweep(&cand)?;
                        assert_eq!(sw.gains.len(), cand.len());
                        if t == 0 && i % 8 == 7 {
                            c.insert((i * 31) % n)?;
                        }
                    }
                    Ok(())
                }));
            }
            for h in sweepers {
                h.join().map_err(|_| {
                    SelectError::ClientPanic("sweep client thread panicked".into())
                })??;
            }
            drivers
                .into_iter()
                .map(|h| {
                    h.join().map_err(|_| {
                        SelectError::ClientPanic("driver client thread panicked".into())
                    })?
                })
                .collect::<Result<Vec<_>, SelectError>>()
        })
    })?;
    let results = outcome?;
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    for r in &results {
        println!(
            "{}: f(S) = {:.5}  |S| = {}  rounds = {}  queries = {}",
            r.algorithm,
            r.value,
            r.set.len(),
            r.rounds,
            r.queries
        );
    }
    let m = &summary.metrics;
    println!(
        "serve: {} requests in {:.3}s ({:.0} req/s); {} sweep requests → {} coalesced \
         rounds ({:.2} sweeps/round); {} inserts, {} steps, {} turns",
        m.requests,
        dt,
        m.requests as f64 / dt,
        m.sweep_requests,
        m.coalesced_rounds,
        m.sweep_requests as f64 / m.coalesced_rounds.max(1) as f64,
        m.inserts,
        m.steps,
        m.turns
    );
    Ok(())
}

/// The v1 wire front: newline-delimited JSON request/reply frames over
/// stdin/stdout against the deterministic serving core, until EOF.
fn cmd_serve_stdio(args: &Args) -> Result<(), SelectError> {
    let mut server = StdioServer::new(Leader::new())
        .with_max_sessions(args.get_usize("max-sessions", 64)?);
    if let Some(dir) = args.get("store") {
        server = server.with_store(SessionStore::open(dir)?);
    }
    let quota = args.get_usize("tenant-quota", 0)?;
    if quota > 0 {
        server = server.with_tenant_quota(quota);
    }
    let stdin = std::io::stdin().lock();
    let mut stdout = std::io::stdout().lock();
    let summary = server
        .run(stdin, &mut stdout)
        .map_err(|e| SelectError::Protocol(format!("stdio transport: {e}")))?;
    let m = &summary.metrics;
    eprintln!(
        "stdio serve: {} requests over {} turns; {} sweeps → {} coalesced rounds; \
         {} inserts, {} steps, {} finishes, {} rejected",
        m.requests,
        m.turns,
        m.sweep_requests,
        m.coalesced_rounds,
        m.inserts,
        m.steps,
        m.finishes,
        m.rejected
    );
    Ok(())
}

/// The v1 wire front over a real socket (`--listen host:port` or
/// `--listen unix:/path`): supervised connection handlers over one
/// [`WireCore`], graceful drain on SIGINT/SIGTERM or a `shutdown` frame.
fn cmd_serve_listen(args: &Args) -> Result<(), SelectError> {
    let addr = args
        .get("listen")
        .ok_or_else(|| SelectError::InvalidSpec("serve --listen needs an address".into()))?;
    let mut core = WireCore::new(Leader::new())
        .with_max_sessions(args.get_usize("max-sessions", 64)?)
        .with_fault_ops(args.get_flag("fault-ops"));
    if let Some(dir) = args.get("store") {
        core = core.with_store(SessionStore::open(dir)?);
    }
    let quota = args.get_usize("tenant-quota", 0)?;
    if quota > 0 {
        core = core.with_tenant_quota(quota);
    }
    let mut config = NetConfig::default();
    let deadline_ms = args.get_u64("request-deadline-ms", 0)?;
    if deadline_ms > 0 {
        config.request_deadline = std::time::Duration::from_millis(deadline_ms);
    }
    let idle_ms = args.get_u64("idle-timeout-ms", 0)?;
    if idle_ms > 0 {
        config.idle_timeout = std::time::Duration::from_millis(idle_ms);
    }
    let stop = install_drain_signals();
    let server = NetServer::bind(addr)
        .map_err(|e| SelectError::Backend(format!("bind {addr}: {e}")))?
        .with_config(config)
        .with_stop_flag(stop);
    eprintln!("listening on {}", server.local_addr());
    let summary = server
        .serve(core)
        .map_err(|e| SelectError::Protocol(format!("socket transport: {e}")))?;
    let m = &summary.serve.metrics;
    eprintln!(
        "socket serve: {} connections, {} requests ({} deadline-dropped); {} sweeps → \
         {} coalesced rounds; {} evictions, {} restores; {} contained panics, \
         {} handler panics",
        summary.connections,
        summary.requests,
        summary.deadlines,
        m.sweep_requests,
        m.coalesced_rounds,
        summary.evictions,
        summary.restores,
        summary.contained_panics,
        summary.handler_panics
    );
    Ok(())
}

/// The multi-worker router front (`route --listen ADDR --worker ADDR...`):
/// v1 frames in, v1 frames out, sessions placed across the worker fleet
/// with crash-safe failover — see
/// [`dash_select::coordinator::router`] for the full contract.
fn cmd_route(args: &Args) -> Result<(), SelectError> {
    let addr = args
        .get("listen")
        .ok_or_else(|| SelectError::InvalidSpec("route needs --listen ADDR".into()))?;
    let workers = args.get_all("worker");
    if workers.is_empty() {
        return Err(SelectError::InvalidSpec(
            "route needs at least one --worker ADDR (repeat for more)".into(),
        ));
    }
    let mut config = RouterConfig::default();
    let deadline_ms = args.get_u64("request-deadline-ms", 0)?;
    if deadline_ms > 0 {
        config.net.request_deadline = std::time::Duration::from_millis(deadline_ms);
    }
    let idle_ms = args.get_u64("idle-timeout-ms", 0)?;
    if idle_ms > 0 {
        config.net.idle_timeout = std::time::Duration::from_millis(idle_ms);
    }
    let probe_ms = args.get_u64("probe-interval-ms", 0)?;
    if probe_ms > 0 {
        config.probe_interval = std::time::Duration::from_millis(probe_ms);
    }
    let stop = install_drain_signals();
    let router = Router::bind(addr, &workers)
        .map_err(|e| SelectError::Backend(format!("bind {addr}: {e}")))?
        .with_config(config)
        .with_stop_flag(stop);
    eprintln!("listening on {} (routing {} workers)", router.local_addr(), workers.len());
    let summary = router
        .serve()
        .map_err(|e| SelectError::Protocol(format!("router transport: {e}")))?;
    eprintln!(
        "router: {} connections, {} requests, {} opens; {} failovers, \
         {} worker deaths, {} revivals; {} handler panics",
        summary.connections,
        summary.requests,
        summary.opens,
        summary.failovers,
        summary.worker_deaths,
        summary.worker_revivals,
        summary.handler_panics
    );
    Ok(())
}

/// `dash audit [--root DIR]`: run the invariant auditor (see
/// [`dash_select::analysis`]) and exit nonzero unless the tree is clean.
fn cmd_audit(args: &Args) -> Result<(), SelectError> {
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => {
            let cwd = std::env::current_dir()
                .map_err(|e| SelectError::Backend(format!("current dir: {e}")))?;
            analysis::find_repo_root(&cwd).ok_or_else(|| {
                SelectError::InvalidSpec(
                    "no repo root above the current directory (looked for rust/src + \
                     Cargo.toml); pass --root DIR"
                        .into(),
                )
            })?
        }
    };
    let outcome = analysis::audit_root(&root).map_err(SelectError::Backend)?;
    print!("{}", outcome.render());
    if outcome.clean() {
        Ok(())
    } else {
        Err(SelectError::Rejected(format!(
            "audit failed: {} violation(s), {} stale allowlist entr{}",
            outcome.violations.len(),
            outcome.stale.len(),
            if outcome.stale.len() == 1 { "y" } else { "ies" }
        )))
    }
}

fn cmd_artifacts() -> Result<(), SelectError> {
    let dir = default_artifacts_dir();
    let manifest = Manifest::load(&dir)
        .map_err(|e| SelectError::Backend(format!("{e} (run `make artifacts`)")))?;
    println!("artifacts in {:?}:", manifest.dir);
    for a in &manifest.artifacts {
        println!(
            "  {:<32} kind={:<8} d={:<5} s={:<4} nc={:<5} {:?}",
            a.name,
            a.kind.as_str(),
            a.d,
            a.s,
            a.nc,
            a.file.file_name().unwrap_or_default()
        );
    }
    Ok(())
}

fn cmd_spectra(args: &Args) -> Result<(), SelectError> {
    let (id, scale) = dataset_for(args)?;
    let k = args.get_usize("k", 25)?;
    let seed = args.get_u64("seed", 1)?;
    let ds = id.build(scale, seed);
    let mut rng = Pcg64::seed_from(seed + 7);
    let gamma = spectra::regression_gamma(&ds.x, k, 8, &mut rng);
    println!(
        "dataset {} (d={}, n={}): sampled γ(2k={}) = {:.4}, α = γ² = {:.4}; \
         DASH guarantee (ε=0.1): f(S) ≥ {:.4}·OPT",
        ds.name,
        ds.d(),
        ds.n(),
        2 * k,
        gamma,
        gamma * gamma,
        (1.0 - (-gamma * gamma * gamma * gamma).exp() - 0.1).max(0.0)
    );
    Ok(())
}
