//! PCG64 (two PCG-XSH-RR 64/32 halves) with distribution helpers.

/// A 64-bit PCG generator: two independent 64->32 PCG streams combined.
/// Deterministic, seedable, `Clone` (replayable).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: [u64; 2],
    inc: [u64; 2],
    /// cached second gaussian from Box–Muller
    spare_gauss: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Seed via splitmix so nearby seeds give unrelated streams.
    pub fn seed_from(seed: u64) -> Self {
        fn splitmix(z: &mut u64) -> u64 {
            *z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut x = *z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            x ^ (x >> 31)
        }
        let mut z = seed;
        let mut rng = Pcg64 {
            state: [splitmix(&mut z), splitmix(&mut z)],
            inc: [splitmix(&mut z) | 1, splitmix(&mut z) | 1],
            spare_gauss: None,
        };
        // warm up
        rng.next_u64();
        rng
    }

    #[inline]
    fn step(&mut self, i: usize) -> u32 {
        let old = self.state[i];
        self.state[i] = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc[i]);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        self.step(0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let hi = self.step(0) as u64;
        let lo = self.step(1) as u64;
        (hi << 32) | lo
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in `[lo, hi]` inclusive; unbiased via rejection.
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi, "gen_range_usize: lo > hi");
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as usize;
        }
        // rejection sampling to remove modulo bias
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        // avoid log(0)
        let mut u1 = self.next_f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.next_f64();
        }
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_gauss = Some(r * s);
        r * c
    }

    /// Normal with given mean/stddev.
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next_gaussian()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices uniformly from `0..n` (Floyd's algorithm
    /// for small k, partial shuffle otherwise). Result order is random.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        if k == 0 {
            return Vec::new();
        }
        if k * 4 >= n {
            // partial Fisher–Yates
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = self.gen_range_usize(i, n - 1);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            // Floyd's: O(k) expected
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.gen_range_usize(0, j);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            self.shuffle(&mut out);
            out
        }
    }

    /// Sample `k` distinct elements from a slice.
    pub fn sample_from<'a, T>(&mut self, xs: &'a [T], k: usize) -> Vec<&'a T> {
        self.sample_indices(xs.len(), k).into_iter().map(|i| &xs[i]).collect()
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_range_usize(0, xs.len() - 1)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seed_from(1);
        let mut b = Pcg64::seed_from(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from(1);
        let mut b = Pcg64::seed_from(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Pcg64::seed_from(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_usize_inclusive_and_unbiased_ends() {
        let mut r = Pcg64::seed_from(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.gen_range_usize(10, 12);
            assert!((10..=12).contains(&v));
            seen_lo |= v == 10;
            seen_hi |= v == 12;
        }
        assert!(seen_lo && seen_hi);
        assert_eq!(r.gen_range_usize(5, 5), 5);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seed_from(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_both_branches() {
        let mut r = Pcg64::seed_from(9);
        // Floyd branch (k small)
        let s = r.sample_indices(1000, 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
        // partial-shuffle branch (k large)
        let s = r.sample_indices(20, 15);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 15);
        assert!(d.iter().all(|&i| i < 20));
        // edges
        assert!(r.sample_indices(5, 0).is_empty());
        let all = {
            let mut v = r.sample_indices(5, 5);
            v.sort_unstable();
            v
        };
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sample_uniformity_rough() {
        // each index of 0..10 should appear ~equally often in samples of 5
        let mut r = Pcg64::seed_from(13);
        let mut counts = [0usize; 10];
        for _ in 0..2000 {
            for i in r.sample_indices(10, 5) {
                counts[i] += 1;
            }
        }
        for &c in &counts {
            let expected = 1000.0;
            assert!((c as f64 - expected).abs() < 120.0, "count {c}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::seed_from(17);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn choose_and_sample_from() {
        let mut r = Pcg64::seed_from(19);
        let xs = [1, 2, 3];
        assert!(xs.contains(r.choose(&xs).unwrap()));
        assert!(r.choose::<usize>(&[]).is_none());
        let picked = r.sample_from(&xs, 2);
        assert_eq!(picked.len(), 2);
    }
}
