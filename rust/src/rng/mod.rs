//! Deterministic pseudo-randomness: PCG-XSH-RR 64/32-based generator with
//! gaussian sampling, shuffles and subset sampling.
//!
//! Every stochastic component of the library (dataset generation, DASH's
//! uniform set sampling, the experiment harness) takes a `&mut Pcg64` so
//! runs are exactly reproducible from a seed.

mod pcg;

pub use pcg::Pcg64;

/// Derive a stream of child seeds from a parent seed (splitmix64), used to
/// give independent generators to parallel workers.
pub fn split_seed(seed: u64, stream: u64) -> u64 {
    let mut z = seed.wrapping_add(stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_seeds_differ() {
        let s = 12345;
        let a = split_seed(s, 0);
        let b = split_seed(s, 1);
        let c = split_seed(s, 2);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, c);
        // deterministic
        assert_eq!(a, split_seed(s, 0));
    }
}
